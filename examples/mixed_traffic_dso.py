"""DSO demo: implicit-shape recompilation vs explicit-bucket routing under
non-uniform upstream candidate counts (paper §4.2.3 / Table 5).

    PYTHONPATH=src python examples/mixed_traffic_dso.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_climber
from repro.core.dso import split_request
from repro.serving import FlameEngine
from repro.core.pda import RemoteFeatureStore


def main():
    cfg, bundle, params = make_climber(d_model=96, layers=2, blocks=2)
    rng = np.random.default_rng(0)
    counts = [17, 33, 64, 90, 128, 40, 77, 128, 25, 60]

    print("bucket split plans (buckets 128/64/32/16):")
    for m in counts[:5]:
        plan = split_request(m, [128, 64, 32, 16])
        print(f"  M={m:>4} -> " + " + ".join(
            f"{c.bucket}({c.valid})" for c in plan))

    # implicit shape: fresh jit per novel M
    jits = {}
    t0 = time.perf_counter()
    for m in counts:
        batch = {
            "history": jnp.zeros((1, 256), jnp.int32),
            "candidates": jnp.asarray(rng.integers(0, 1000, (1, m)), jnp.int32),
            "side": jnp.zeros((1, 12), jnp.float32),
        }
        if m not in jits:
            jits[m] = jax.jit(lambda b: bundle.prefill(params, b))
        jax.block_until_ready(jits[m](batch))
    t_implicit = time.perf_counter() - t0
    print(f"\nimplicit shape: {t_implicit:.2f}s for {len(counts)} requests "
          f"({len(jits)} in-band compiles)")

    eng = FlameEngine(bundle, params, n_history=256,
                      buckets=(128, 64, 32, 16), n_streams=2,
                      feature_mode="off",
                      store=RemoteFeatureStore(latency_s=0, feature_dim=12))
    t0 = time.perf_counter()
    for m in counts:
        eng.serve(rng.integers(0, 1000, 256), rng.integers(0, 1000, m))
    t_dso = time.perf_counter() - t0
    print(f"DSO routing:    {t_dso:.2f}s "
          f"(AOT pool built off-band in {eng.pool.build_time_s:.1f}s)")
    print(f"-> speedup x{t_implicit / t_dso:.1f}")
    eng.shutdown()


if __name__ == "__main__":
    main()
