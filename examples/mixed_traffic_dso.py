"""DSO demo: implicit-shape recompilation vs explicit-bucket routing vs
cross-request chunk coalescing under non-uniform upstream candidate counts
(paper §4.2.3 / Table 5, extended with the API v2 coalescing dispatcher).

    PYTHONPATH=src:. python examples/mixed_traffic_dso.py
"""
import time

import numpy as np

from benchmarks.common import make_climber
from repro.core.dso import split_request
from repro.core.pda import RemoteFeatureStore
from repro.serving import create_engine
from repro.serving.scheduler import run_workload_async


def main():
    cfg, bundle, params = make_climber(d_model=96, layers=2, blocks=2)
    rng = np.random.default_rng(0)
    counts = [17, 33, 64, 90, 128, 40, 77, 128, 25, 60]
    reqs = [{"history": rng.integers(0, 1000, 256).astype(np.int32),
             "candidates": rng.integers(0, 1000, m).astype(np.int32)}
            for m in counts]

    print("bucket split plans (buckets 128/64/32/16):")
    for m in counts[:5]:
        plan = split_request(m, [128, 64, 32, 16])
        print(f"  M={m:>4} -> " + " + ".join(
            f"{c.bucket}({c.valid})" for c in plan))

    def store():
        return RemoteFeatureStore(latency_s=0, feature_dim=12)

    # implicit shape: fresh jit trace+compile per novel M, in-band
    eng = create_engine("implicit", bundle, params, n_history=256,
                        feature_mode="off", store=store(), n_workers=4)
    t0 = time.perf_counter()
    run_workload_async(eng, reqs)
    t_implicit = time.perf_counter() - t0
    print(f"\nimplicit shape: {t_implicit:.2f}s for {len(counts)} requests "
          f"({eng.metrics()['jit_compiles']} in-band compiles)")
    eng.shutdown()

    for coalesce in (False, True):
        eng = create_engine("flame", bundle, params, n_history=256,
                            buckets=(128, 64, 32, 16), n_streams=2,
                            feature_mode="off", store=store(),
                            coalesce=coalesce, max_batch=4, window_s=0.005,
                            n_workers=4)
        t0 = time.perf_counter()
        run_workload_async(eng, reqs)
        dt = time.perf_counter() - t0
        m = eng.metrics()
        tag = "DSO + coalescing" if coalesce else "DSO routing     "
        print(f"{tag}: {dt:.2f}s "
              f"(AOT pool built off-band in {eng.dso.build_time_s:.1f}s; "
              f"{m['dso_chunks']} chunks in {m['dso_dispatches']} dispatches, "
              f"avg fill {m['dso_avg_fill']:.1f})")
        print(f"-> speedup over implicit x{t_implicit / dt:.1f}")
        eng.shutdown()


if __name__ == "__main__":
    main()
