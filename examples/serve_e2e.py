"""End-to-end driver (the paper's kind: SERVING).

Train a small Climber on synthetic interaction data with planted
preferences, then stand up the full FLAME pipeline — PDA feature cache ->
DSO bucket routing over AOT executors -> SUMI-masked model — and serve a
mixed-traffic workload with batched concurrent requests.  Reports the
paper's metric set (throughput in user-item pairs/s, mean/p99 latency,
cache stats) and verifies the served scores track the planted preferences.

    PYTHONPATH=src python examples/serve_e2e.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import GRInteractionDataset, make_batch_iterator
from repro.models import build_model
from repro.serving import FlameEngine
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload_async)
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig
from repro.types import ClimberConfig

N_ITEMS = 20_000
HISTORY = 64


def main():
    # ---- 1. train ----
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=N_ITEMS, d_model=96, d_ff=384,
        n_heads=4, n_kv_heads=4, head_dim=24,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    ds = GRInteractionDataset(n_items=N_ITEMS, n_users=2_000, seed=0)
    it = make_batch_iterator(ds, 16, n_history=HISTORY, n_candidates=8)
    print("[1/4] training climber on synthetic interactions...")
    params, _, hist = train(bundle, it, 60,
                            AdamWConfig(lr=3e-3, warmup_steps=5),
                            log_every=20, impl="reference",
                            callback=lambda m: print(
                                f"    step {m['step']:>3} loss {m['loss']:.4f}"))

    # ---- 2. serve through the full FLAME pipeline (API v2) ----
    print("[2/4] building FLAME engine (PDA + coalescing DSO + AOT "
          "executors)...")
    eng = FlameEngine(bundle, params, n_history=HISTORY,
                      buckets=(64, 32, 16), n_streams=2, feature_mode="sync",
                      coalesce=True, max_batch=4, n_workers=4)
    print(f"    executor pool AOT-built in {eng.dso.build_time_s:.1f}s "
          f"(batch axis {eng.dso.policy.batch})")
    tc = TrafficConfig(candidate_counts=(16, 32, 64), distribution="jittered",
                       n_requests=24, n_history=HISTORY, seed=1)
    reqs = generate_traffic(tc, n_items=N_ITEMS)
    res = run_workload_async(eng, reqs)
    print(f"    {res['requests']} concurrent requests | "
          f"{res['throughput_items_per_s']:.0f} user-item pairs/s | "
          f"p50 {res['p50_latency_ms']:.1f} ms | "
          f"p99 {res['p99_latency_ms']:.1f} ms")
    m = eng.metrics()
    print(f"    PDA cache: {eng.features.stats}")
    print(f"    DSO: {m['dso_chunks']} chunks in {m['dso_dispatches']} "
          f"dispatches (avg fill {m['dso_avg_fill']:.1f})")

    # ---- 3. quality check: served scores track planted preferences ----
    print("[3/4] verifying served scores track planted preferences...")
    rng = np.random.default_rng(7)
    pos, neg = [], []
    for _ in range(30):
        r = ds.sample_request(rng, HISTORY, 16)
        scores = eng.serve(r["history"], r["candidates"])
        lab = r["labels"][:, 0] > 0.5
        pos.extend(scores[lab, 0].tolist())
        neg.extend(scores[~lab, 0].tolist())
    track_ok = np.mean(pos) > np.mean(neg)
    print(f"    mean score on positives {np.mean(pos):.4f} vs "
          f"negatives {np.mean(neg):.4f} "
          f"({'OK' if track_ok else 'FAIL'})")

    # ---- 4. session re-rank through the history-KV pool ----
    print("[4/4] session re-rank: split forward + history-KV pool...")
    engc = FlameEngine(bundle, params, n_history=HISTORY,
                       buckets=(64, 32, 16), n_streams=2, feature_mode="sync",
                       coalesce=True, max_batch=4, n_workers=4,
                       history_cache=True, pool_slots=64)
    r = ds.sample_request(rng, HISTORY, 16)
    ref = eng.serve(r["history"], r["candidates"])
    for _ in range(4):      # session re-ranks: same user, fresh slates
        engc.serve(r["history"], rng.integers(0, N_ITEMS, 16).astype(np.int32),
                   user_id=1)
    first = engc.serve(r["history"], r["candidates"], user_id=1)
    m = engc.metrics()
    # full-pass and cached scores come from different AOT executables, so
    # the contract is tight allclose (<= 2e-3 on sigmoids), not bitwise
    same = np.allclose(np.asarray(ref, np.float32),
                       np.asarray(first, np.float32), atol=2e-3, rtol=2e-3)
    print(f"    pool: {m['pool_hits']} hits / {m['pool_misses']} miss "
          f"({m['pool_bytes']} bytes cached); cached scores == full pass: "
          f"{'OK' if same else 'FAIL'}")
    engc.shutdown()
    eng.shutdown()
    if not (track_ok and same):
        raise SystemExit("serve_e2e correctness checks FAILED")


if __name__ == "__main__":
    main()
