"""Model-zoo serving: prefill + KV-cache decode for the assigned text
architectures (reduced configs on CPU; the pod-scale shapes are exercised by
launch/dryrun.py).

    PYTHONPATH=src python examples/text_serving.py [--arch gemma3-12b]
"""
import argparse

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import build_model
from repro.serving import TextServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    print(f"serving reduced {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"pattern={cfg.layer_pattern}")
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    eng = TextServingEngine(bundle, params, batch=2, max_len=128)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
               rng.integers(0, cfg.vocab_size, 16).astype(np.int32)]
    outs = eng.generate(prompts, n_tokens=args.tokens)
    for i, o in enumerate(outs):
        print(f"request {i}: prompt {prompts[i][:6].tolist()}... -> "
              f"generated {o.tolist()}")


if __name__ == "__main__":
    main()
