"""Quickstart: build a Climber GR model and score candidates through the
SUMI mask in one forward pass.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.types import ClimberConfig


def main():
    # a laptop-sized Climber (the paper's structure: 2 blocks, SUMI scoring,
    # adaptive temperature, gating fusion, multi-task expert head)
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=10_000, d_model=128, d_ff=512,
        n_heads=4, n_kv_heads=4, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2, num_tasks=3))
    bundle = build_model(cfg)
    params, specs = bundle.init(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {
        "history": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 128)),
                               jnp.int32),
        "candidates": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 32)),
                                  jnp.int32),
        "side": jnp.asarray(rng.standard_normal((1, 12)), jnp.float32),
    }
    scores = bundle.prefill(params, batch)      # [1, 32 candidates, 3 tasks]
    print(f"scored {scores.shape[1]} candidates x {scores.shape[2]} tasks "
          f"in one SUMI pass")
    top5 = np.argsort(-np.asarray(scores[0, :, 0]))[:5]
    print("top-5 candidates by task-0 score:", top5.tolist())
    print("their scores:", np.round(np.asarray(scores[0, top5, 0]), 3).tolist())


if __name__ == "__main__":
    main()
