"""Training example: Climber (~100M params) on the synthetic GR interaction
pipeline for a few hundred steps, with checkpointing.

The ~100M configuration keeps the paper's structure (2 blocks x 12 layers)
with the embedding table carrying most parameters, as in production recsys.
Use --small for a quick CPU run.

    PYTHONPATH=src python examples/train_climber.py --small
    PYTHONPATH=src python examples/train_climber.py --steps 300   # ~100M
"""
import argparse
import dataclasses
import os

import jax

from repro.configs import get_config
from repro.data import GRInteractionDataset, make_batch_iterator
from repro.models import build_model
from repro.training import checkpoint
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig
from repro.types import ClimberConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/climber_ckpt.msgpack")
    args = ap.parse_args()

    if args.small:
        cfg = dataclasses.replace(
            get_config("climber"), vocab_size=20_000, d_model=64, d_ff=256,
            n_heads=2, n_kv_heads=2, head_dim=32,
            climber=ClimberConfig(num_blocks=2, layers_per_block=2))
        steps, batch, n_hist, n_cand = min(args.steps, 60), 16, 32, 8
    else:
        # ~100M params: 512k-item catalog x 192d embedding (~98M) + 2x12
        # transformer layers
        cfg = dataclasses.replace(
            get_config("climber"), vocab_size=512_000, d_model=192,
            d_ff=768, n_heads=4, n_kv_heads=4, head_dim=48,
            climber=ClimberConfig(num_blocks=2, layers_per_block=12))
        steps, batch, n_hist, n_cand = args.steps, 8, 64, 16

    bundle = build_model(cfg)
    n_params = cfg.param_count()
    print(f"[train_climber] params ~{n_params/1e6:.0f}M "
          f"({cfg.climber.num_blocks} blocks x "
          f"{cfg.climber.layers_per_block} layers, d={cfg.d_model})")

    ds = GRInteractionDataset(n_items=cfg.vocab_size, n_users=10_000, seed=0)
    it = make_batch_iterator(ds, batch, n_history=n_hist,
                             n_candidates=n_cand)
    params, _, hist = train(
        bundle, it, steps, AdamWConfig(lr=2e-3, warmup_steps=20),
        log_every=max(1, steps // 15), impl="reference",
        callback=lambda m: print(
            f"  step {m['step']:>4} loss {m['loss']:.4f} "
            f"({m['wall_s']:.0f}s)"))
    checkpoint.save(args.ckpt, params, step=steps)
    print(f"[train_climber] loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}; checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
