"""Paper Table 5 — DSO ablation under simulated mixed-traffic workloads.

Candidate counts uniform over {128, 256, 512, 1024} (+ a jittered variant
with non-bucket-aligned counts), history fixed.  Two configurations:

  Default (Implicit Shape) — plain jax.jit: every novel candidate count
      triggers a fresh trace + XLA compile, the analogue of TensorRT
      implicit-shape dynamic (re)allocation;
  DSO (Explicit Shape)     — pre-built AOT executors per bucket, descending
      bucket routing, executor index queue.

Measured for real on CPU: recompilation/retrace overhead is host-side and
reproduces the paper's effect faithfully.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_climber
from repro.core.climber import climber_forward
from repro.serving import FlameEngine
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload, run_workload_async)
from repro.core.pda import RemoteFeatureStore

HISTORY = 256
COUNTS = (32, 64, 128, 256)      # scaled-down mixed traffic (CPU feasible)
N_REQUESTS = 24
CONCURRENCY = 4


def run_implicit(cfg, bundle, params, reqs):
    """Fresh jit per request shape — XLA retraces/compiles for novel M."""
    fns = {}

    def serve(history, candidates):
        m = len(candidates)
        batch = {
            "history": jnp.asarray(history[None, :HISTORY], jnp.int32),
            "candidates": jnp.asarray(candidates[None], jnp.int32),
            "side": jnp.zeros((1, 12), jnp.float32),
        }
        if m not in fns:
            fns[m] = jax.jit(lambda b: bundle.prefill(params, b))
        out = fns[m](batch)
        jax.block_until_ready(out)
        return out

    return run_workload(serve, reqs, concurrency=CONCURRENCY), len(fns)


def run_dso(cfg, bundle, params, reqs, buckets=(256, 128, 64, 32),
            coalesce=False):
    eng = FlameEngine(bundle, params, n_history=HISTORY, buckets=buckets,
                      n_streams=2, feature_mode="off",
                      store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                      coalesce=coalesce, max_batch=4, window_s=0.004,
                      n_workers=CONCURRENCY)
    res = run_workload_async(eng, reqs)
    res.pop("outputs")
    res["build_s"] = eng.dso.build_time_s
    res["chunks"] = eng.dso.chunk_count
    res["dispatches"] = eng.dso.dispatch_count
    eng.shutdown()
    return res


def main(csv=True):
    cfg, bundle, params = make_climber(d_model=128, layers=2, blocks=2)
    print("\n=== Table 5 analogue: DSO ablation (mixed traffic) ===")
    for dist in ("uniform", "jittered"):
        tc = TrafficConfig(candidate_counts=COUNTS, distribution=dist,
                           n_requests=N_REQUESTS, n_history=HISTORY,
                           seed=3)
        reqs = generate_traffic(tc, n_items=cfg.vocab_size)
        imp, n_compiles = run_implicit(cfg, bundle, params, reqs)
        dso = run_dso(cfg, bundle, params, reqs)
        coal = run_dso(cfg, bundle, params, reqs, coalesce=True)
        print(f"\n--- {dist} traffic, M in {sorted(set(len(r['candidates']) for r in reqs))} ---")
        print(f"{'config':<26}{'items/s':>10}{'mean ms':>9}{'p99 ms':>9}")
        print(f"{'Default (Implicit Shape)':<26}"
              f"{imp['throughput_items_per_s']:>10.0f}"
              f"{imp['mean_latency_ms']:>9.1f}{imp['p99_latency_ms']:>9.1f}"
              f"   ({n_compiles} jit compiles in-band)")
        print(f"{'DSO (Explicit Shape)':<26}"
              f"{dso['throughput_items_per_s']:>10.0f}"
              f"{dso['mean_latency_ms']:>9.1f}{dso['p99_latency_ms']:>9.1f}"
              f"   (AOT build {dso['build_s']:.1f}s off-band, "
              f"{dso['chunks']} chunks)")
        print(f"{'DSO + coalescing':<26}"
              f"{coal['throughput_items_per_s']:>10.0f}"
              f"{coal['mean_latency_ms']:>9.1f}{coal['p99_latency_ms']:>9.1f}"
              f"   ({coal['chunks']} chunks in {coal['dispatches']} "
              f"dispatches)")
        print(f"-> DSO vs implicit: throughput x"
              f"{dso['throughput_items_per_s']/imp['throughput_items_per_s']:.2f}, "
              f"latency x{imp['mean_latency_ms']/dso['mean_latency_ms']:.2f} "
              f"(paper: 1.3x / 2.3x on uniform)")
        if csv:
            print(f"dso/{dist}/implicit,{imp['mean_latency_ms']*1e3:.1f},"
                  f"tput={imp['throughput_items_per_s']:.0f}")
            print(f"dso/{dist}/explicit,{dso['mean_latency_ms']*1e3:.1f},"
                  f"tput={dso['throughput_items_per_s']:.0f}")
    bucket_sensitivity()



def bucket_sensitivity():
    """Beyond-paper analysis: bucket-set choice vs padding waste + executor
    count (informs profile selection for TensorRT/AOT builds)."""
    import itertools
    from repro.core.dso import padded_fraction
    import numpy as np
    rng = np.random.default_rng(0)
    # zipf-ish candidate count distribution 1..1024
    ms = np.clip((rng.zipf(1.4, 4000) * 16) % 1024 + 1, 1, 1024)
    sets = {
        "pow2 {1024..128}": [1024, 512, 256, 128],
        "pow2 {1024..32}": [1024, 512, 256, 128, 64, 32],
        "pow2 {1024..8}": [1024, 512, 256, 128, 64, 32, 16, 8],
        "coarse {1024,256}": [1024, 256],
        "single {1024}": [1024],
        "fine linear 128s": list(range(128, 1025, 128)),
    }
    print("\n=== DSO bucket-set sensitivity (zipf traffic, M in [1,1024]) ===")
    print(f"{'bucket set':<22}{'executors':>10}{'mean pad %':>12}{'p95 pad %':>11}")
    for name, bs in sets.items():
        pads = np.array([padded_fraction(int(m), bs) for m in ms])
        print(f"{name:<22}{len(bs):>10}{100*pads.mean():>11.1f}%"
              f"{100*np.percentile(pads, 95):>10.1f}%")
    print("-> more buckets cut padding but multiply AOT build time and "
          "executor memory; {1024..32} is the knee for this traffic.")

if __name__ == "__main__":
    main()
