"""Roofline table from dry-run artifacts (results/dryrun/*.json).

One row per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_all(baselines_only=True):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if baselines_only and \
                r.get("tag") != f"{r.get('mesh')}_{r.get('arch')}_{r.get('shape')}":
            continue
        recs.append(r)
    return recs


def main(csv=True, mesh_filter="pod16x16"):
    recs = load_all()
    if not recs:
        print("no dry-run results found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    print(f"\n=== Roofline table ({mesh_filter}, seconds per step) ===")
    print(f"{'arch':<27}{'shape':<13}{'compute':>9}{'mem_est':>9}"
          f"{'collective':>11}{'dominant':>11}{'useful':>7}")
    for r in recs:
        if r.get("mesh") != mesh_filter:
            continue
        if r.get("status") == "skipped":
            print(f"{r['arch']:<27}{r['shape']:<13}{'skip: ' + r['reason'][:45]}")
            continue
        rl = r["roofline"]
        print(f"{r['arch']:<27}{r['shape']:<13}"
              f"{float(rl['compute_s']):>9.4f}{float(rl['memory_s_est']):>9.4f}"
              f"{float(rl['collective_s']):>11.4f}{rl['dominant']:>11}"
              f"{float(rl['useful_ratio']):>7.2f}")
        if csv:
            print(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']},"
                  f"{float(rl['compute_s'])*1e6:.1f},"
                  f"dom={rl['dominant']};useful={float(rl['useful_ratio']):.2f};"
                  f"coll_s={float(rl['collective_s']):.4f}")
    ok = sum(1 for r in recs if r.get("status") == "ok")
    sk = sum(1 for r in recs if r.get("status") == "skipped")
    print(f"\n{ok} compiled, {sk} skipped (sub-quadratic rule), "
          f"{len(recs)} total records")


if __name__ == "__main__":
    main()
