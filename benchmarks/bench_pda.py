"""Paper Table 3 — PDA ablation: -Cache/-MemOpt vs +Cache vs Full PDA.

Bypass-traffic simulation: zipf-popular items against a simulated remote
feature store (RPC latency + per-item serialization).  Real wall-clock on
CPU — the cache/packed-transfer effects are host-side and reproduce
faithfully.  Columns mirror the paper: throughput (items/s), mean latency,
P99 latency, network bytes.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.core.pda import (BucketedLRUCache, FeatureQueryEngine,
                            RemoteFeatureStore, packed_transfer,
                            unpacked_transfer)

N_REQUESTS = 120
ITEMS_PER_REQ = 64
N_ITEMS = 20_000
ZIPF_A = 1.3
CONCURRENCY = 8


def _traffic(seed=0):
    rng = np.random.default_rng(seed)
    return [((rng.zipf(ZIPF_A, ITEMS_PER_REQ) - 1) % N_ITEMS).tolist()
            for _ in range(N_REQUESTS)]


def run_config(name: str, mode: str, packed: bool, n_buckets: int = 16,
               seed: int = 0):
    store = RemoteFeatureStore(feature_dim=64, latency_s=0.0015,
                               per_item_s=2e-5, seed=seed)
    cache = None if mode == "off" else BucketedLRUCache(
        capacity=N_ITEMS, ttl_s=60.0, n_buckets=n_buckets)
    eng = FeatureQueryEngine(store, cache, mode=mode)
    traffic = _traffic(seed)
    lat = []
    transfer = packed_transfer if packed else unpacked_transfer

    zero = np.zeros(64, np.float32)

    def serve(ids):
        t0 = time.perf_counter()
        feats = eng.query(ids)
        # fixed per-request layout: one feature vector per requested item
        got = [feats.get(i) if feats.get(i) is not None else zero for i in ids]
        transfer(got)           # host->device of the assembled features
        return time.perf_counter() - t0

    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=CONCURRENCY) as tp:
        for dt in tp.map(serve, traffic):
            lat.append(dt)
    total = time.perf_counter() - t0
    eng.shutdown()
    la = np.array(lat)
    return {
        "config": name,
        "throughput_items_s": N_REQUESTS * ITEMS_PER_REQ / total,
        "mean_latency_ms": la.mean() * 1e3,
        "p99_latency_ms": np.percentile(la, 99) * 1e3,
        "network_mb": store.bytes_sent / 1e6,
        "rpcs": store.requests,
    }


def main(csv=True):
    rows = [
        run_config("-Cache,-MemOpt", mode="off", packed=False),
        run_config("+Cache,-MemOpt", mode="sync", packed=False),
        run_config("+Cache,+MemOpt (Full PDA)", mode="sync", packed=True),
        run_config("+AsyncCache,+MemOpt", mode="async", packed=True),
    ]
    base = rows[0]
    print(f"\n=== Table 3 analogue: PDA ablation "
          f"({N_REQUESTS} req x {ITEMS_PER_REQ} items, zipf {ZIPF_A}) ===")
    hdr = f"{'config':<28}{'items/s':>10}{'mean ms':>9}{'p99 ms':>8}{'net MB':>8}"
    print(hdr)
    for r in rows:
        print(f"{r['config']:<28}{r['throughput_items_s']:>10.0f}"
              f"{r['mean_latency_ms']:>9.2f}{r['p99_latency_ms']:>8.2f}"
              f"{r['network_mb']:>8.2f}")
    full = rows[2]
    print(f"-> Full PDA vs baseline: throughput x"
          f"{full['throughput_items_s']/base['throughput_items_s']:.2f}, "
          f"latency x{base['mean_latency_ms']/full['mean_latency_ms']:.2f} "
          f"(paper: 1.9x / 1.7x)")
    if csv:
        for r in rows:
            print(f"pda/{r['config']},{r['mean_latency_ms']*1e3:.1f},"
                  f"tput={r['throughput_items_s']:.0f};net_mb={r['network_mb']:.2f}")
    return rows


if __name__ == "__main__":
    main()
