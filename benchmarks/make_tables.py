"""Emit the EXPERIMENTS.md markdown tables from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")
ARCH_ORDER = ["h2o-danube-3-4b", "llava-next-mistral-7b", "rwkv6-7b",
              "seamless-m4t-large-v2", "qwen2-72b", "qwen1.5-32b",
              "kimi-k2-1t-a32b", "gemma3-12b", "jamba-v0.1-52b",
              "llama4-maverick-400b-a17b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh):
    recs = {}
    for path in glob.glob(os.path.join(RESULTS_DIR, f"{mesh}_*.json")):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag") != f"{mesh}_{r['arch']}_{r['shape']}":
            continue   # skip §Perf-tagged variants; baselines only
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_s(x):
    x = float(x)
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    x = float(x)
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(mesh):
    recs = load(mesh)
    print(f"\n### Dry-run ({mesh})\n")
    print("| arch | shape | status | compile | args/device | temp/device | HLO flops | collective bytes |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                print(f"| {a} | {s} | MISSING | | | | | |")
                continue
            if r["status"] == "skipped":
                print(f"| {a} | {s} | skip (full-attention, sub-quadratic "
                      f"rule) | | | | | |")
                continue
            m = r["memory_analysis"]
            rl = r["roofline"]
            chips = r["chips"]
            print(f"| {a} | {s} | ok | {r['compile_s']}s | "
                  f"{fmt_b(m.get('argument_size_in_bytes', 0)/1)} | "
                  f"{fmt_b(m.get('temp_size_in_bytes', 0))} | "
                  f"{float(rl['hlo_flops']):.2e} | "
                  f"{fmt_b(float(rl['collective_bytes']))} |")


def roofline_table(mesh):
    recs = load(mesh)
    print(f"\n### Roofline ({mesh})\n")
    print("| arch | shape | compute | memory(est) | memory(xla-UB) | "
          "collective | dominant | MODEL/HLO flops | bottleneck note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None or r["status"] == "skipped":
                continue
            rl = r["roofline"]
            print(f"| {a} | {s} | {fmt_s(rl['compute_s'])} | "
                  f"{fmt_s(rl['memory_s_est'])} | {fmt_s(rl['memory_s'])} | "
                  f"{fmt_s(rl['collective_s'])} | {rl['dominant']} | "
                  f"{float(rl['useful_ratio']):.2f} | |")


def collective_breakdown(mesh, arch, shape):
    recs = load(mesh)
    r = recs.get((arch, shape))
    if not r or r["status"] != "ok":
        return
    det = r["roofline"].get("collective_detail") or {}
    print(f"\n{arch} x {shape} ({mesh}) collective breakdown: " + ", ".join(
        f"{k}={fmt_b(float(v)*r['chips'])}" for k, v in det.items()
        if k != 'total' and float(v) > 0))


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        dryrun_table("pod16x16")
        dryrun_table("pod2x16x16")
    if which in ("all", "roofline"):
        roofline_table("pod16x16")
    if which == "coll":
        collective_breakdown("pod16x16", sys.argv[2], sys.argv[3])
