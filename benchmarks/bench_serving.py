"""Serving API v2 benchmark — dispatch + history-cache A/Bs.

Profile 1 (mixed traffic): coalesced vs per-request dispatch.  Drives
concurrent jittered traffic (non-bucket-aligned candidate counts, the
DSO's hard case) through two FlameEngine configurations that differ only
in the coalescing policy:

  uncoalesced   executors (1, bucket); every chunk dispatches alone
  coalesced     executors (max_batch, bucket); same-bucket chunks from
                different in-flight requests share one dispatch

Profile 2 (repeat-user / session re-rank): history-KV pool on vs off.
A fixed population of users each re-ranks several fresh candidate slates
against a stable history — the MTServe regime.  With the pool on, scoring
runs candidate-only executors against cached per-layer history K/V
(O(M) tokens instead of O(n_history + M) per block); misses pay one
batched encode.  Measured at steady state (pool warmed by a first sweep).

Both profiles run against a warmed PDA cache (hot steady state) so the
measurement reflects dispatch economics, not feature-fetch cost.

Correctness gates before any throughput claim:
  1. coalesced concurrent scores are bitwise-identical to the same engine
     serving the same requests sequentially (same executable — guaranteed
     by per-row independence, hard assert);
  2. coalesced scores are bitwise-identical to the uncoalesced baseline
     (cross-executable; holds for this config and asserted so a future
     XLA codegen change fails loudly rather than silently);
  3. pooled-history scores match the full-pass engine at tight tolerance
     (the split forward is mathematically exact; the two AOT executables
     fuse differently, so isolated bf16 lanes may round differently —
     the gate admits <= 2e-3 absolute on sigmoid outputs, ~half a bf16
     ulp at 0.5, and reports the bitwise-identical request fraction).

Emits ``BENCH_serving.json`` at the repo root so future PRs have a perf
trajectory to compare against.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import make_climber
from repro.core.pda import RemoteFeatureStore
from repro.serving import create_engine
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload_async)

HISTORY = 64
COUNTS = (16, 32, 64)
N_REQUESTS = 64
N_ITEMS = 5_000
BUCKETS = (32, 16)
MAX_BATCH = 4
N_WORKERS = 8
# repeat-user profile: longer history (the term the pool amortizes away)
REPEAT_HISTORY = 128
REPEAT_USERS = 8
POOL_SLOTS = 32
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")


def _run(bundle, params, reqs, *, coalesce: bool, sequential_ref: bool):
    eng = create_engine(
        "flame", bundle, params, n_history=HISTORY, buckets=BUCKETS,
        n_streams=2, feature_mode="sync",
        store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
        coalesce=coalesce, max_batch=MAX_BATCH, window_s=0.008,
        n_workers=N_WORKERS)
    # warm the feature cache and the executors (steady-state measurement)
    eng.features.query(list(range(N_ITEMS)))
    for r in reqs[:4]:
        eng.serve(r["history"], r["candidates"])
    seq = [eng.serve(r["history"], r["candidates"]) for r in reqs] \
        if sequential_ref else None
    m0 = eng.metrics()
    res = run_workload_async(eng, reqs)
    outputs = res.pop("outputs")
    m1 = eng.metrics()
    chunks = m1["dso_chunks"] - m0["dso_chunks"]
    dispatches = m1["dso_dispatches"] - m0["dso_dispatches"]
    res.update(build_s=eng.dso.build_time_s, chunks=chunks,
               dispatches=dispatches,
               avg_fill=chunks / max(dispatches, 1),
               batch_axis=m1["dso_batch_axis"])
    eng.shutdown()
    return res, outputs, seq


def _run_repeat(bundle, params, reqs, *, history_cache: bool):
    """Repeat-user profile: one engine config, steady state (hot pool)."""
    eng = create_engine(
        "flame", bundle, params, n_history=REPEAT_HISTORY, buckets=BUCKETS,
        n_streams=2, feature_mode="sync",
        store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
        coalesce=True, max_batch=MAX_BATCH, window_s=0.008,
        n_workers=N_WORKERS, history_cache=history_cache,
        pool_slots=POOL_SLOTS)
    eng.features.query(list(range(N_ITEMS)))
    # warm sweep: compiles executors and (when enabled) populates the pool —
    # session re-rank steady state, not cold start
    run_workload_async(eng, reqs)
    m0 = eng.metrics()
    res = run_workload_async(eng, reqs)
    outputs = res.pop("outputs")
    m1 = eng.metrics()
    res.update(dispatches=m1["dso_dispatches"] - m0["dso_dispatches"],
               encode_dispatches=(m1.get("dso_dispatches_encode", 0)
                                  - m0.get("dso_dispatches_encode", 0)),
               pool_hits=m1.get("pool_hits", 0) - m0.get("pool_hits", 0),
               pool_misses=m1.get("pool_misses", 0) - m0.get("pool_misses", 0),
               pool_bytes=m1.get("pool_bytes", 0))
    eng.shutdown()
    return res, outputs


def main(csv=True):
    cfg, bundle, params = make_climber(d_model=64, layers=2, blocks=2)
    tc = TrafficConfig(candidate_counts=COUNTS, distribution="jittered",
                       n_requests=N_REQUESTS, n_history=HISTORY, seed=11)
    reqs = generate_traffic(tc, n_items=N_ITEMS)

    print("\n=== Serving API v2: coalesced vs per-request dispatch "
          "(jittered traffic, hot cache) ===")
    base, out_base, _ = _run(bundle, params, reqs, coalesce=False,
                             sequential_ref=False)
    coal, out_coal, seq_ref = _run(bundle, params, reqs, coalesce=True,
                                   sequential_ref=True)

    bitwise_seq = all(np.array_equal(a, b)
                      for a, b in zip(seq_ref, out_coal))
    bitwise_base = all(np.array_equal(a, b)
                       for a, b in zip(out_base, out_coal))
    print(f"{'config':<26}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'dispatches':>12}{'fill':>6}")
    for name, r in (("per-request (B=1)", base),
                    (f"coalesced (B={MAX_BATCH})", coal)):
        print(f"{name:<26}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['dispatches']:>12}{r['avg_fill']:>6.1f}")
    speedup = (coal["throughput_items_per_s"]
               / max(base["throughput_items_per_s"], 1e-9))
    print(f"-> coalescing: throughput x{speedup:.2f}; bitwise vs sequential "
          f"self: {bitwise_seq}; bitwise vs B=1 baseline: {bitwise_base}")
    if csv:
        print(f"serving/uncoalesced,{base['p50_latency_ms'] * 1e3:.1f},"
              f"tput={base['throughput_items_per_s']:.0f}")
        print(f"serving/coalesced,{coal['p50_latency_ms'] * 1e3:.1f},"
              f"tput={coal['throughput_items_per_s']:.0f}")

    print("\n=== History-KV pool: repeat-user / session re-rank "
          f"({REPEAT_USERS} users, history {REPEAT_HISTORY}, hot pool) ===")
    rtc = TrafficConfig(candidate_counts=COUNTS, distribution="jittered",
                        n_requests=N_REQUESTS, n_history=REPEAT_HISTORY,
                        seed=13, n_users=REPEAT_USERS)
    rreqs = generate_traffic(rtc, n_items=N_ITEMS)
    full, out_full = _run_repeat(bundle, params, rreqs, history_cache=False)
    pooled, out_pool = _run_repeat(bundle, params, rreqs, history_cache=True)
    bitwise_frac = np.mean([np.array_equal(a, b)
                            for a, b in zip(out_full, out_pool)])
    pool_max_diff = max(
        float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
        for a, b in zip(out_full, out_pool))
    print(f"{'config':<26}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'hits':>6}{'miss':>6}")
    for name, r in (("full pass (pool off)", full),
                    ("history pool (hot)", pooled)):
        print(f"{name:<26}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['pool_hits']:>6}{r['pool_misses']:>6}")
    pool_speedup = (pooled["throughput_items_per_s"]
                    / max(full["throughput_items_per_s"], 1e-9))
    print(f"-> history pool: throughput x{pool_speedup:.2f}; vs full pass: "
          f"max |diff| {pool_max_diff:.2e}, bitwise on "
          f"{bitwise_frac:.0%} of requests; "
          f"pool bytes {pooled['pool_bytes']}")
    if csv:
        print(f"serving/repeat_full,{full['p50_latency_ms'] * 1e3:.1f},"
              f"tput={full['throughput_items_per_s']:.0f}")
        print(f"serving/repeat_pooled,{pooled['p50_latency_ms'] * 1e3:.1f},"
              f"tput={pooled['throughput_items_per_s']:.0f}")

    report = {
        "workload": {"distribution": "jittered", "counts": list(COUNTS),
                     "n_requests": N_REQUESTS, "history": HISTORY,
                     "buckets": list(BUCKETS), "max_batch": MAX_BATCH,
                     "n_workers": N_WORKERS},
        "uncoalesced": base,
        "coalesced": coal,
        "speedup_items_per_s": speedup,
        "bitwise_identical": bool(bitwise_base),
        "bitwise_vs_sequential_self": bool(bitwise_seq),
        "repeat_user": {
            "workload": {"distribution": "jittered", "counts": list(COUNTS),
                         "n_requests": N_REQUESTS, "history": REPEAT_HISTORY,
                         "n_users": REPEAT_USERS, "pool_slots": POOL_SLOTS},
            "full_pass": full,
            "history_pool": pooled,
            "speedup_items_per_s": pool_speedup,
            "max_abs_diff_vs_full": pool_max_diff,
            "bitwise_fraction": float(bitwise_frac),
        },
    }
    path = os.path.abspath(OUT_PATH)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not (bitwise_seq and bitwise_base):
        raise AssertionError("coalesced scores diverged from per-request "
                             "reference — correctness gate failed")
    if pool_max_diff > 2e-3:
        raise AssertionError(
            f"pooled-history scores diverged from the full pass by "
            f"{pool_max_diff:.2e} (> 2e-3) — correctness gate failed")
    if pool_speedup < 1.5:
        raise AssertionError(
            f"history pool speedup x{pool_speedup:.2f} < 1.5 on the "
            f"repeat-user profile — perf gate failed")
    return report


if __name__ == "__main__":
    main()
