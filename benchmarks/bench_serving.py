"""Serving API v2 benchmark — dispatch + history-cache A/Bs.

Profile 1 (mixed traffic): coalesced vs per-request dispatch.  Drives
concurrent jittered traffic (non-bucket-aligned candidate counts, the
DSO's hard case) through two FlameEngine configurations that differ only
in the coalescing policy:

  uncoalesced   executors (1, bucket); every chunk dispatches alone
  coalesced     executors (max_batch, bucket); same-bucket chunks from
                different in-flight requests share one dispatch

Profile 2 (repeat-user / session re-rank): history-KV pool on vs off.
A fixed population of users each re-ranks several fresh candidate slates
against a stable history — the MTServe regime.  With the pool on, scoring
runs candidate-only executors against cached per-layer history K/V
(O(M) tokens instead of O(n_history + M) per block); misses pay one
batched encode.  Measured at steady state (pool warmed by a first sweep).

Profile 3 (PDA v2 hot path): the PR 2-style pool (host-resident entries,
KV rows restacked once per chunk) vs PDA v2 (device-resident entries +
KV-row dedup in the dispatcher) on the same repeat-user workload — the
"device-resident pool entries" ROADMAP item, isolated.

Profile 4 (suffix extension): stale-sweep workload — every user's history
tail-appends between sweeps, so every request is a stale hit.  Full
re-encode (incremental off) vs incremental suffix extension (re-encode one
token per block against the cached prefix).  Same seed on both sides, so
outputs are compared pairwise at the pool tolerance.

Profile 5 (quantized pool): int8 pool entries vs native on the hot
repeat-user path — bytes/entry ratio (users-per-replica capacity) and the
measured score drift.

Profile 6 (fke): the fused candidate-scoring engine (``impl="fused"``,
kernels/fused_score) vs the framework-composed ``impl="chunked"`` on the
repeat-user workload over a quantized (int8) pool — the paper-scale FKE
configuration.  The fused executors read the pool's stored int8 rows and
the dedup row index in-kernel, so a hit skips the host dequantize AND the
``kv[idx]`` materialization; KV-row dedup auto-enables even on the CPU
backend because the gather is free.  Run standalone with
``python -m benchmarks.bench_serving --profile fke`` (the CI gate).

Profile 7 (dso_nonuniform): DSO v2 segment-packed ragged dispatch vs the
PR-4 coalescing dispatcher under non-uniform candidate traffic (zipf +
lognormal over tiny counts — nearly every request is one partial tail
chunk).  The packed engine fills shared rows with candidate segments from
many requests (each steered to its own user's pooled KV by the per-
candidate seg index), so ``padded_fraction`` collapses and items/s rises
with no score change beyond the cross-executable tolerance.  Run
standalone with ``--profile dso_nonuniform`` (a CI gate).

Profile 8 (sharded): mesh-sharded serving (data=2, model=2) vs
single-device on the repeat-user workload, A/B-interleaved inside a
subprocess whose host platform is forced to 4 devices (XLA_FLAGS must be
set before jax imports, so the parent cannot host the mesh itself).
Records the per-shard pool byte split; the throughput gate is a PARITY
floor, not a speedup — emulated devices time-slice one CPU and
multi-device dispatches serialize.  Run standalone with
``--profile sharded`` (a CI gate).

Profile 9 (decode): generative candidate decode (ISSUE 8) — DSO-packed
beam rows (``pack_tails=True``) vs per-request decode dispatch on zipf
repeat-user traffic with alternating top-k and beam requests over tiny
token universes.  Each autoregressive step scores every beam's token
universe against pooled history KV; the packed side merges beam segments
from many in-flight requests into shared executor rows.  Sequences must
match bitwise across the two engines (same AOT executables, row-wise
batch-invariant) and the gen-tokens/s gate is cpu-count-aware: speedup
on multi-core, parity floor on a single core.  Run standalone with
``--profile decode`` (a CI gate).

Profile 10 (overload): SLO-tiered EDF admission + load shedding vs the
PR-1 FIFO discipline under sustained overload (every request submitted at
once against a small worker pool), gated on interactive-tier
goodput-under-SLO (median per-round, cpu-count-aware floor); plus a chaos
pass under deterministic fault injection (transient dispatch failures,
worker stalls, pool eviction storms) gated on ZERO hung futures — every
submission resolves, result or error.  Run standalone with
``--profile overload`` (a CI gate).

All profiles run against a warmed PDA cache (hot steady state) so the
measurement reflects dispatch economics, not feature-fetch cost.

Correctness gates before any throughput claim:
  1. coalesced concurrent scores are bitwise-identical to the same engine
     serving the same requests sequentially (same executable — guaranteed
     by per-row independence, hard assert);
  2. coalesced scores are bitwise-identical to the uncoalesced baseline
     (cross-executable; holds for this config and asserted so a future
     XLA codegen change fails loudly rather than silently);
  3. pooled-history scores match the full-pass engine at tight tolerance
     (the split forward is mathematically exact; the two AOT executables
     fuse differently, so isolated bf16 lanes may round differently —
     the gate admits <= 2e-3 absolute on sigmoid outputs, ~half a bf16
     ulp at 0.5, and reports the bitwise-identical request fraction);
  4. suffix-extension scores match the full re-encode run at the same
     tolerance, and int8 pool drift stays under its stated bound (5e-2).

Perf gates (explicit, enforced on every run): pool >= 1.5x full pass;
suffix extension >= 1.1x full re-encode on the stale-sweep profile;
FKE >= 1.3x chunked on the int8 repeat-user profile (with nonzero
dedup_rows_saved on the fused side — the CPU backend included);
PDA v2 >= 0.9x the v1-style pool.  The last one is a parity guard, not a
victory lap: on the CPU backend "device" and "host" placement are the same
memory, so the v2 machinery must simply cost nothing — its wins
(HBM-resident entries skipping the per-dispatch H2D copy, dedup skipping
one transfer per duplicate row) are transfer-bound and materialize on
accelerator backends, where kv_dedup auto-enables.  The forced-dedup row
records the dedup machinery live (rows saved -> modeled transfer bytes).

Emits ``BENCH_serving.json`` at the repo root so future PRs have a perf
trajectory to compare against (see benchmarks/README.md for every field).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import make_climber
from repro.core.pda import RemoteFeatureStore
from repro.serving import create_engine
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload_async)

HISTORY = 64
COUNTS = (16, 32, 64)
N_REQUESTS = 64
N_ITEMS = 5_000
BUCKETS = (32, 16)
MAX_BATCH = 4
N_WORKERS = 8
# repeat-user profile: longer history (the term the pool amortizes away),
# multi-chunk candidate counts (the regime where KV-row dedup bites: a
# m=96 request splits into three bucket-32 chunks that share one KV row),
# and a deeper batch axis so co-batched same-user rows dedup too
REPEAT_HISTORY = 128
REPEAT_USERS = 8
REPEAT_COUNTS = (48, 64, 96)
REPEAT_MAX_BATCH = 8
POOL_SLOTS = 32
# stale-sweep profile: longer history still, so the full re-encode the
# extension path avoids dominates dispatch overhead even at bench scale
STALE_HISTORY = 256
# fke profile: paper-scale FKE configuration — int8 pool (the capacity
# setting), history long enough that cached scoring (not dispatch) is the
# cost, multi-chunk candidate counts so the dedup row index engages.
# Fewer pipeline workers than the other profiles: the gate is a wall-clock
# ratio, and 8 workers on a 2-core CI box drown it in scheduler noise
FKE_HISTORY = 512
FKE_WORKERS = 4
FKE_ROUNDS = 5
# The fused engine's wall-clock win comes from work it REMOVES per dispatch
# (host dequantize, the kv[idx] restack) — savings that overlap with other
# requests' compute only when there is more than one core to overlap on.
# On a single-core box every engine serializes onto the same core and the
# fused path's margin collapses into scheduler noise, so the gate degrades
# to a PARITY floor there: fused must not be slower, but is not required to
# win.  Multi-core boxes keep the 1.3x gate (measured 1.5-1.8x on 2 cores).
FKE_SPEEDUP_MIN = 1.3 if (os.cpu_count() or 1) > 1 else 1.0
FKE_TOL = 1e-2      # chunked dequantizes, fused folds the scale in-kernel:
                    # same stored rows, reassociated math (~3e-3 measured)
# dso_nonuniform profile: DSO v2 segment packing vs PR-4 coalescing under
# non-uniform candidate traffic (paper Fig 10 / Table 5's regime).  Counts
# are tiny and skewed (zipf mostly draws the smallest; lognormal is the
# heavy-tailed continuous variant) against a single 32-bucket, so nearly
# every request is ONE partial tail chunk padded up to its covering bucket
# (padded_fraction ~0.7-0.8) — the packer fills shared rows with segments
# from many requests instead, and pack_rows (max_batch/4 = 2) compiles a
# quarter of the unpacked row capacity: the same chunk fill rides a (2,
# 32) executor instead of an (8, 32) one.  Users <= the batch axis so one
# packed dispatch can stack every user's KV; one stream per bucket so a
# single collector sees the whole pending queue.
DSO_HISTORY = 192
DSO_BUCKETS = (32,)
DSO_COUNTS = (3, 5, 9, 15)
DSO_STREAMS = 1
DSO_ROUNDS = 7
DSO_SPEEDUP_MIN = 1.2   # packed >= 1.2x items/s (median per-round, zipf)
DSO_PAD_RATIO_MIN = 2.0  # unpacked padded_fraction >= 2x the packed one
DSO_TOL = 2e-3           # cross-AOT-executable tolerance (see profile 2)
# the v2 engine carries an explicit byte budget (active accounting; sized
# far above the working set so the hot path is budget-checked, not evicted)
V2_BUDGET_BYTES = 64 << 20
# sharded profile: mesh-sharded serving vs single-device on the repeat-user
# workload, run in a subprocess with XLA's host platform forced to 4
# devices (the flag must be set before jax imports, so the parent process
# cannot host the mesh itself).  The mesh is (data=2, model=2): the request
# batch splits over "data" and the KV heads split over "model", so each
# shard holds half the pool bytes (the per-shard budget) — recorded from
# the pool_bytes_used_shard{i} gauges.  The gate is a PARITY floor, not a
# speedup: all 4 "devices" are slices of the same CPU, so sharding buys no
# cycles here and the host collectives cost real time — the floor asserts
# the mesh machinery (sharded executors, per-shard pool, coalesced global
# batch) doesn't tax the hot path beyond CPU-emulation overhead.  Real
# wins (N× KV-head bandwidth, N× pool capacity) need N physical devices.
# The emulation overhead is real and stable: emulated devices time-slice
# one CPU's cores, per-layer TP collectives run through XLA's in-process
# rendezvous, and multi-device dispatches serialize (see
# CoalescingOrchestrator.serialize_dispatch) — measured x0.31-0.34 per
# round.  The 0.2 floor catches pathological regressions (a reshard per
# dispatch, a pool republish per hit) that land far below it, without
# flaking on scheduler noise.
# Tolerance: the TP out-projection all-reduce reassociates sums through
# the block stack (~1e-3 observed); the bitwise criterion lives in
# tests/test_sharded_serving.py on the pure-data (4, 1) mesh, where local
# per-device shapes match single-device exactly.
SHARDED_DEVICES = 4
SHARDED_MODEL_PARALLEL = 2
SHARDED_ROUNDS = 5
SHARDED_PARITY_MIN = 0.2
SHARDED_TOL = 5e-3
# decode profile: generative beam/top-k decode, DSO-packed beam rows vs
# per-request dispatch.  Tiny zipf-skewed token universes (most requests
# decode over a handful of ids), so every decode step is one partial chunk
# per request on the unpacked side; the packer fills shared rows with beam
# segments from many in-flight requests instead.  The gate follows the FKE
# rule: packing removes per-dispatch overhead whose win needs cores to
# overlap on — a multi-core box must show the speedup, a single-core box
# must hold parity (the packer must at least pay for itself).
DECODE_HISTORY = 96
DECODE_COUNTS = (4, 6, 10, 14)
DECODE_STEPS = 5
DECODE_BEAM = 4
DECODE_ROUNDS = 5
DECODE_WORKERS = 4
DECODE_REQUESTS = 24
DECODE_SPEEDUP_MIN = 1.1 if (os.cpu_count() or 1) > 1 else 0.9
# decode_fused profile (ISSUE 10, FKE v2): fused generative decode — the
# lengths-masked fused kernel scores every decode step in one executor
# call against stored pool KV — vs the chunked per-pass formulation, both
# packed and over the same int8 pool, so the only delta is the decode
# formulation itself.  Correctness is gated on a NATIVE-pool parity pass
# (exact f32 math on both sides: sequences must match token for token);
# the timed A/B runs on int8 where the fused side's in-kernel dequant
# pays off.  Multi-core boxes must show the speedup; a single-core box
# holds parity (the fused formulation must at least pay for itself).
DECODE_FUSED_SPEEDUP_MIN = 1.2 if (os.cpu_count() or 1) > 1 else 0.9
# chaos arm shared by the decode profiles: dispatch faults retrying
# decode-step dispatches plus pool eviction storms that evict PARKED beam
# caches mid-generation — the liveness gate is zero hung futures and the
# recovery gate is gen_replays > 0 (evicted beams re-decoded from the
# root, not failed).  Storms roll per decode round (the engine fires the
# evict arm between a round's beam parks and the next round's lookups —
# the only window where an eviction can force a replay), so a modest
# probability still lands many mid-generation evictions
DECODE_FAULT_SPEC = "dispatch:0.1,evict:0.15"
# overload profile (ISSUE 9): sustained arrival rate > service rate —
# every request submits at once against a small worker pool, so the
# admission queue stays saturated and ordering policy decides who makes
# their SLO.  A/B: FIFO admission + blocking backpressure (the PR-1
# discipline) vs EDF admission + tiered shedding.  The gate is
# goodput-under-SLO on the INTERACTIVE tier (requests completing inside
# their deadline, from the goodput_interactive counter): EDF serves the
# tight-deadline work first while FIFO makes it wait behind bulk.  The
# ratio smooths +1 on both sides (rounds where FIFO strands every
# interactive request would otherwise divide by zero) and gates on the
# median per-round value.  Single-core boxes keep a reduced floor: the
# ordering win survives serialization, but one poisoned round of two
# workers time-slicing one core adds noise the multicore floor would
# flake on.
OVERLOAD_HISTORY = 96
OVERLOAD_COUNTS = (8, 16, 32)
OVERLOAD_REQUESTS = 48
OVERLOAD_ROUNDS = 5
OVERLOAD_WORKERS = 2
OVERLOAD_PENDING = 16
OVERLOAD_TIER_MIX = {"interactive": 0.3, "standard": 0.4, "bulk": 0.3}
# interactive SLO sits between EDF's interactive-clear time (~0.3x the
# full-round wall time: EDF front-runs the ~30% interactive slice) and
# FIFO's full-round wall time (~0.11 s here), so FIFO strands most
# late-arriving interactive work past deadline while EDF meets all of it
OVERLOAD_TIER_SLO = {"interactive": 0.04, "standard": 1.5, "bulk": 10.0}
OVERLOAD_GOODPUT_MIN = 1.2 if (os.cpu_count() or 1) > 1 else 1.05
# chaos arm of the overload profile: transient dispatch faults (exercising
# the DSO retry loop), worker stalls (exercising the watchdog), and pool
# eviction storms (forcing re-encodes) — the gate is LIVENESS: zero hung
# futures, every submission resolves (result or error) inside the timeout
OVERLOAD_FAULT_SPEC = "dispatch:0.15,stall:0.1:0.005,evict:0.1"
OVERLOAD_WATCHDOG_GRACE_S = 2.0
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")


def _run(bundle, params, reqs, *, coalesce: bool, sequential_ref: bool):
    eng = create_engine(
        "flame", bundle, params, n_history=HISTORY, buckets=BUCKETS,
        n_streams=2, feature_mode="sync",
        store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
        coalesce=coalesce, max_batch=MAX_BATCH, window_s=0.008,
        n_workers=N_WORKERS)
    # warm the feature cache and the executors (steady-state measurement)
    eng.features.query(list(range(N_ITEMS)))
    for r in reqs[:4]:
        eng.serve(r["history"], r["candidates"])
    seq = [eng.serve(r["history"], r["candidates"]) for r in reqs] \
        if sequential_ref else None
    m0 = eng.metrics()
    res = run_workload_async(eng, reqs)
    outputs = res.pop("outputs")
    m1 = eng.metrics()
    chunks = m1["dso_chunks"] - m0["dso_chunks"]
    dispatches = m1["dso_dispatches"] - m0["dso_dispatches"]
    res.update(build_s=eng.dso.build_time_s, chunks=chunks,
               dispatches=dispatches,
               avg_fill=chunks / max(dispatches, 1),
               batch_axis=m1["dso_batch_axis"])
    eng.shutdown()
    return res, outputs, seq


def _repeat_engine(bundle, params, *, history_cache: bool, **engine_kw):
    """Build + warm one repeat-profile engine (hot features, hot pool)."""
    eng = create_engine(
        "flame", bundle, params, n_history=REPEAT_HISTORY, buckets=BUCKETS,
        n_streams=2, feature_mode="sync",
        store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
        coalesce=True, max_batch=REPEAT_MAX_BATCH, window_s=0.008,
        n_workers=N_WORKERS, history_cache=history_cache,
        pool_slots=POOL_SLOTS, **engine_kw)
    eng.features.query(list(range(N_ITEMS)))
    return eng


def _pool_delta(m0, m1):
    return dict(
        dispatches=m1["dso_dispatches"] - m0["dso_dispatches"],
        encode_dispatches=(m1.get("dso_dispatches_encode", 0)
                           - m0.get("dso_dispatches_encode", 0)),
        pool_hits=m1.get("pool_hits", 0) - m0.get("pool_hits", 0),
        pool_misses=m1.get("pool_misses", 0) - m0.get("pool_misses", 0),
        pool_bytes=m1.get("pool_bytes", 0),
        dedup_rows_saved=(m1.get("dso_dedup_rows_saved", 0)
                          - m0.get("dso_dedup_rows_saved", 0)))


def _ab_interleaved(eng_a, eng_b, reqs, rounds: int = 5):
    """Interleaved A/B throughput measurement.

    CPU CI boxes drift by integer factors across seconds and single passes
    jitter +-25%, so measuring config A start-to-finish and then config B
    bakes both into the ratio.  Alternating measured passes and aggregating
    each side's items/time over all rounds cancels the drift (every A pass
    sits adjacent to a B pass) and averages the jitter — the perf gates
    below are hard asserts, so the ratio must be honest *and* stable.
    Both engines are warmed by one untimed pass first."""
    a, out_a, b, out_b, _ = _ab_interleaved_ratios(eng_a, eng_b, reqs,
                                                   rounds)
    return a, out_a, b, out_b


def _ab_interleaved_ratios(eng_a, eng_b, reqs, rounds: int = 5):
    """Like :func:`_ab_interleaved`, but additionally returns the per-round
    B/A throughput ratios, so gates can use the median ratio (robust to a
    single load-spiked round) instead of the aggregate-time ratio."""
    run_workload_async(eng_a, reqs)
    run_workload_async(eng_b, reqs)
    m0 = [eng_a.metrics(), eng_b.metrics()]
    items_per_pass = sum(len(r["candidates"]) for r in reqs)
    agg = [dict(t=0.0, p50=[], p99=[]), dict(t=0.0, p50=[], p99=[])]
    outs = [None, None]
    ratios = []
    for _ in range(rounds):
        pair_t = [0.0, 0.0]
        for i, eng in enumerate((eng_a, eng_b)):
            r = run_workload_async(eng, reqs)
            outs[i] = r.pop("outputs")
            agg[i]["t"] += r["total_s"]
            pair_t[i] = r["total_s"]
            agg[i]["p50"].append(r["p50_latency_ms"])
            agg[i]["p99"].append(r["p99_latency_ms"])
        ratios.append(pair_t[0] / max(pair_t[1], 1e-9))
    res = []
    for i, eng in enumerate((eng_a, eng_b)):
        res.append({
            "requests": len(reqs) * rounds,
            "throughput_items_per_s": rounds * items_per_pass / agg[i]["t"],
            "p50_latency_ms": float(np.median(agg[i]["p50"])),
            "p99_latency_ms": float(np.median(agg[i]["p99"])),
            **_pool_delta(m0[i], eng.metrics()),
        })
    return res[0], outs[0], res[1], outs[1], ratios


def _run_stale_sweeps_interleaved(bundle, params, n_sweeps: int = 16,
                                  seed: int = 17):
    """Suffix-extension profile: every user's history tail-appends between
    sweeps, so every request arrives as a stale hit.  The re-encode engine
    pays a full window re-encode per request; the incremental engine
    extends the cached prefix (one token per block).  Both engines consume
    identical request streams (same seed) with sweeps interleaved, so the
    outputs are comparable pairwise and machine drift cancels out of the
    throughput ratio."""
    import time as _time
    from repro.serving import ServeRequest

    engines = {}
    for name, inc in (("reencode", False), ("incremental", True)):
        eng = create_engine(
            "flame", bundle, params, n_history=STALE_HISTORY,
            buckets=BUCKETS, n_streams=2, feature_mode="sync",
            store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
            coalesce=True, max_batch=REPEAT_MAX_BATCH, window_s=0.008,
            n_workers=N_WORKERS, history_cache=True, pool_slots=POOL_SLOTS,
            incremental_history=inc)
        eng.features.query(list(range(N_ITEMS)))
        rng = np.random.default_rng(seed)
        hists = {u: rng.integers(0, N_ITEMS,
                                 STALE_HISTORY + 16).astype(np.int32)
                 for u in range(REPEAT_USERS)}
        engines[name] = dict(eng=eng, rng=rng, hists=hists, outputs=[],
                             lat=[], items=0, time=0.0)

    def one_sweep(state, timed):
        eng, rng, hists = state["eng"], state["rng"], state["hists"]
        if timed:
            for u in range(REPEAT_USERS):         # tail-append => stale
                hists[u] = np.concatenate(
                    [hists[u], rng.integers(0, N_ITEMS, 4).astype(np.int32)])
        t0 = _time.perf_counter()
        futs = []
        for u in range(REPEAT_USERS):
            m = int(rng.choice(REPEAT_COUNTS))
            cand = rng.integers(0, N_ITEMS, m).astype(np.int32)
            futs.append(eng.submit(ServeRequest(history=hists[u],
                                                candidates=cand,
                                                user_id=u)))
        resps = [f.result() for f in futs]
        if timed:
            state["time"] += _time.perf_counter() - t0
            for r in resps:
                state["outputs"].append(r.output)
                state["lat"].append(r.latency_s)
                state["items"] += len(r.output)

    for state in engines.values():                # warm: encode all users
        one_sweep(state, timed=False)
        state["m0"] = state["eng"].metrics()      # counter deltas below
    for _ in range(n_sweeps):
        for state in engines.values():
            one_sweep(state, timed=True)

    results = {}
    for name, state in engines.items():
        m, m0 = state["eng"].metrics(), state["m0"]
        results[name] = ({
            "requests": n_sweeps * REPEAT_USERS,
            "throughput_items_per_s": state["items"] / state["time"],
            "p50_latency_ms": float(np.percentile(state["lat"], 50) * 1e3),
            "p99_latency_ms": float(np.percentile(state["lat"], 99) * 1e3),
            "pool_stale": m["pool_stale"] - m0["pool_stale"],
            "pool_extensions": m["pool_extensions"] - m0["pool_extensions"],
            "encode_dispatches": (m.get("dso_dispatches_encode", 0)
                                  - m0.get("dso_dispatches_encode", 0)),
            "extend_dispatches": (m.get("dso_dispatches_extend", 0)
                                  - m0.get("dso_dispatches_extend", 0)),
        }, state["outputs"])
        state["eng"].shutdown()
    return results["reencode"] + results["incremental"]


def run_fke_profile(bundle, params, csv=True):
    """Profile 6: FKE (impl=fused) vs framework (impl=chunked), both over
    an int8 history pool on the repeat-user workload.  Returns the report
    section and hard-asserts its gates (correctness, >= 1.3x items/s,
    dedup engaged on the fused side)."""
    print("\n=== FKE: fused candidate-scoring engine vs chunked "
          f"(int8 pool, history {FKE_HISTORY}, hot repeat users) ===")
    ftc = TrafficConfig(candidate_counts=REPEAT_COUNTS,
                        distribution="jittered", n_requests=N_REQUESTS,
                        n_history=FKE_HISTORY, seed=29, n_users=REPEAT_USERS)
    freqs = generate_traffic(ftc, n_items=N_ITEMS)

    def fke_engine(impl):
        eng = create_engine(
            "flame", bundle, params, n_history=FKE_HISTORY, buckets=BUCKETS,
            n_streams=2, feature_mode="sync",
            store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
            coalesce=True, max_batch=REPEAT_MAX_BATCH, window_s=0.008,
            n_workers=FKE_WORKERS, history_cache=True,
            pool_slots=POOL_SLOTS, pool_dtype="int8", impl=impl)
        eng.features.query(list(range(N_ITEMS)))
        return eng

    eng_ch = fke_engine("chunked")
    eng_fu = fke_engine("fused")
    # interleaved per-round ratios, gated on the MEDIAN: a single round
    # poisoned by a CI-box load spike must not decide a hard gate either
    # way (the aggregate-time ratio is still reported)
    chunked, out_ch, fused, out_fu, ratios = _ab_interleaved_ratios(
        eng_ch, eng_fu, freqs, rounds=FKE_ROUNDS)
    eng_ch.shutdown()
    eng_fu.shutdown()
    fke_speedup = float(np.median(ratios))
    fke_speedup_agg = (fused["throughput_items_per_s"]
                       / max(chunked["throughput_items_per_s"], 1e-9))
    fke_max_diff = max(
        float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
        for a, b in zip(out_ch, out_fu))
    print(f"{'config':<28}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'dedup':>7}")
    for name, r in (("chunked (framework ops)", chunked),
                    ("fused (FKE kernels)", fused)):
        print(f"{name:<28}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['dedup_rows_saved']:>7}")
    print(f"-> FKE: throughput x{fke_speedup:.2f} median per-round "
          f"(x{fke_speedup_agg:.2f} aggregate) vs chunked (fused reads "
          f"int8 rows + dedup index in-kernel: no host dequant, no kv[idx] "
          f"copy); max |diff| {fke_max_diff:.2e}; dedup auto-on saved "
          f"{fused['dedup_rows_saved']} row restacks on this backend")
    if csv:
        print(f"serving/fke_chunked,{chunked['p50_latency_ms'] * 1e3:.1f},"
              f"tput={chunked['throughput_items_per_s']:.0f}")
        print(f"serving/fke_fused,{fused['p50_latency_ms'] * 1e3:.1f},"
              f"tput={fused['throughput_items_per_s']:.0f}")

    if fke_max_diff > FKE_TOL:
        raise AssertionError(
            f"fused scores diverged from chunked by {fke_max_diff:.2e} "
            f"(> {FKE_TOL}) on the shared int8 pool — correctness gate "
            f"failed")
    if fke_speedup < FKE_SPEEDUP_MIN:
        raise AssertionError(
            f"FKE median per-round speedup x{fke_speedup:.2f} < "
            f"{FKE_SPEEDUP_MIN} vs impl=chunked on the repeat-user profile "
            f"(per-round ratios {[round(r, 2) for r in ratios]}) — perf "
            f"gate failed")
    if fused["dedup_rows_saved"] < 1:
        raise AssertionError(
            "fused engine saved no KV-row restacks — in-kernel dedup is "
            "not engaging (it must auto-enable on every backend)")
    return {
        "workload": {"distribution": "jittered",
                     "counts": list(REPEAT_COUNTS),
                     "n_requests": N_REQUESTS, "history": FKE_HISTORY,
                     "n_users": REPEAT_USERS, "pool_dtype": "int8",
                     "max_batch": REPEAT_MAX_BATCH},
        "chunked": chunked,
        "fused": fused,
        "speedup_items_per_s": fke_speedup_agg,
        "speedup_median_per_round": fke_speedup,
        "per_round_ratios": [float(r) for r in ratios],
        "max_abs_diff_vs_chunked": fke_max_diff,
        "gates": {"fke_speedup_min": FKE_SPEEDUP_MIN,
                  "fke_tolerance": FKE_TOL,
                  "fke_dedup_nonzero": True},
    }


def _cached_padded_fraction(m0: dict, m1: dict) -> float:
    """Padded fraction of the cached-scoring dispatches between two metric
    snapshots: 1 - real candidates / dispatched candidate slots."""
    slots = m1.get("dso_cand_slots_cached", 0) - m0.get(
        "dso_cand_slots_cached", 0)
    valid = m1.get("dso_cand_valid_cached", 0) - m0.get(
        "dso_cand_valid_cached", 0)
    return 1.0 - valid / slots if slots else 0.0


def run_dso_nonuniform_profile(bundle, params, csv=True):
    """Profile 7: DSO v2 segment packing + deadline-aware flushing vs PR-4
    coalescing on non-uniform (zipf + lognormal) candidate traffic over a
    hot history pool.  Gates (zipf side): packed >= 1.2x items/s median
    per-round, padded_fraction reduced >= 2x, scores within the cross-
    executable tolerance."""
    print("\n=== DSO v2: segment-packed ragged dispatch vs PR-4 coalescing "
          f"(history {DSO_HISTORY}, counts {DSO_COUNTS}, bucket "
          f"{DSO_BUCKETS}, hot pool) ===")

    def dso_engine(pack):
        eng = create_engine(
            "flame", bundle, params, n_history=DSO_HISTORY,
            buckets=DSO_BUCKETS, n_streams=DSO_STREAMS, feature_mode="sync",
            store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
            coalesce=True, max_batch=REPEAT_MAX_BATCH, window_s=0.008,
            n_workers=N_WORKERS, history_cache=True,
            pool_slots=POOL_SLOTS, impl="fused", pack_tails=pack)
        eng.features.query(list(range(N_ITEMS)))
        return eng

    report = {"workload": {"counts": list(DSO_COUNTS),
                           "n_requests": N_REQUESTS, "history": DSO_HISTORY,
                           "n_users": REPEAT_USERS, "impl": "fused",
                           "max_batch": REPEAT_MAX_BATCH,
                           "buckets": list(DSO_BUCKETS)},
              "gates": {"dso_pack_speedup_min": DSO_SPEEDUP_MIN,
                        "dso_pad_ratio_min": DSO_PAD_RATIO_MIN,
                        "dso_tolerance": DSO_TOL}}
    for dist in ("zipf", "lognormal"):
        tc = TrafficConfig(candidate_counts=DSO_COUNTS, distribution=dist,
                           n_requests=N_REQUESTS, n_history=DSO_HISTORY,
                           seed=31, n_users=REPEAT_USERS)
        reqs = generate_traffic(tc, n_items=N_ITEMS)
        eng_un, eng_pk = dso_engine(False), dso_engine(True)
        m0 = [eng_un.metrics(), eng_pk.metrics()]
        unpacked, out_un, packed, out_pk, ratios = _ab_interleaved_ratios(
            eng_un, eng_pk, reqs, rounds=DSO_ROUNDS)
        pf_un = _cached_padded_fraction(m0[0], eng_un.metrics())
        pf_pk = _cached_padded_fraction(m0[1], eng_pk.metrics())
        eng_un.shutdown()
        eng_pk.shutdown()
        speedup = float(np.median(ratios))
        speedup_agg = (packed["throughput_items_per_s"]
                       / max(unpacked["throughput_items_per_s"], 1e-9))
        max_diff = max(
            float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
            for a, b in zip(out_un, out_pk))
        bitwise_frac = float(np.mean([np.array_equal(a, b)
                                      for a, b in zip(out_un, out_pk)]))
        print(f"-- {dist} traffic --")
        print(f"{'config':<28}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
              f"{'padded':>8}")
        for name, r, pf in (("unpacked (PR-4 coalescing)", unpacked, pf_un),
                            ("packed (DSO v2)", packed, pf_pk)):
            print(f"{name:<28}{r['throughput_items_per_s']:>10.0f}"
                  f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
                  f"{pf:>8.2f}")
        print(f"-> packing ({dist}): throughput x{speedup:.2f} median "
              f"per-round (x{speedup_agg:.2f} aggregate); padded_fraction "
              f"{pf_un:.2f} -> {pf_pk:.2f} "
              f"({pf_un / max(pf_pk, 1e-9):.1f}x less padding); max |diff| "
              f"{max_diff:.2e}, bitwise on {bitwise_frac:.0%} of requests")
        if csv:
            print(f"serving/dso_{dist}_unpacked,"
                  f"{unpacked['p50_latency_ms'] * 1e3:.1f},"
                  f"tput={unpacked['throughput_items_per_s']:.0f}")
            print(f"serving/dso_{dist}_packed,"
                  f"{packed['p50_latency_ms'] * 1e3:.1f},"
                  f"tput={packed['throughput_items_per_s']:.0f}")
        report[dist] = {
            "unpacked": dict(unpacked, padded_fraction=pf_un),
            "packed": dict(packed, padded_fraction=pf_pk),
            "speedup_items_per_s": speedup_agg,
            "speedup_median_per_round": speedup,
            "per_round_ratios": [float(r) for r in ratios],
            "padded_fraction_ratio": pf_un / max(pf_pk, 1e-9),
            "max_abs_diff_vs_unpacked": max_diff,
            "bitwise_fraction": bitwise_frac,
        }
        if max_diff > DSO_TOL:
            raise AssertionError(
                f"packed scores diverged from unpacked by {max_diff:.2e} "
                f"(> {DSO_TOL}) on {dist} traffic — correctness gate failed")
        if dist == "zipf":
            if speedup < DSO_SPEEDUP_MIN:
                raise AssertionError(
                    f"DSO v2 packing x{speedup:.2f} < {DSO_SPEEDUP_MIN} "
                    f"median per-round vs PR-4 coalescing on zipf traffic "
                    f"(per-round {[round(r, 2) for r in ratios]}) — perf "
                    f"gate failed")
            if pf_un < DSO_PAD_RATIO_MIN * pf_pk:
                raise AssertionError(
                    f"padded_fraction only {pf_un:.2f} -> {pf_pk:.2f} on "
                    f"zipf traffic (< {DSO_PAD_RATIO_MIN}x reduction) — "
                    f"packing is not reclaiming the tail padding")
    return report


#: Runs inside a forced-4-device subprocess (see run_sharded_profile):
#: XLA_FLAGS must be set before jax imports anywhere in the process, so the
#: whole A/B — engine builds, traffic, interleaved rounds — happens here and
#: ships one JSON line back on stdout.
_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = \
    "--xla_force_host_platform_device_count={devices}"
import json
import sys

sys.path.insert(0, "src")
import numpy as np

from benchmarks.bench_serving import (BUCKETS, N_ITEMS, N_REQUESTS,
                                      N_WORKERS, POOL_SLOTS, REPEAT_COUNTS,
                                      REPEAT_HISTORY, REPEAT_MAX_BATCH,
                                      REPEAT_USERS, _ab_interleaved_ratios)
from benchmarks.common import make_climber
from repro.core.pda import RemoteFeatureStore
from repro.launch.mesh import make_host_mesh
from repro.serving import create_engine
from repro.serving.scheduler import TrafficConfig, generate_traffic

cfg, bundle, params = make_climber(d_model=64, layers=2, blocks=2)
tc = TrafficConfig(candidate_counts=REPEAT_COUNTS, distribution="jittered",
                   n_requests=N_REQUESTS, n_history=REPEAT_HISTORY,
                   seed=13, n_users=REPEAT_USERS)
reqs = generate_traffic(tc, n_items=N_ITEMS)


def engine(mesh):
    eng = create_engine(
        "flame", bundle, params, n_history=REPEAT_HISTORY, buckets=BUCKETS,
        n_streams=2, feature_mode="sync",
        store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
        coalesce=True, max_batch=REPEAT_MAX_BATCH, window_s=0.008,
        n_workers=N_WORKERS, history_cache=True, pool_slots=POOL_SLOTS,
        mesh=mesh)
    eng.features.query(list(range(N_ITEMS)))
    return eng


eng_single = engine(None)
eng_sharded = engine(make_host_mesh(model_parallel={model_parallel}))
single, out_s, sharded, out_m, ratios = _ab_interleaved_ratios(
    eng_single, eng_sharded, reqs, rounds={rounds})
metrics = eng_sharded.metrics()
shard_bytes = sorted(int(metrics[k]) for k in metrics
                     if k.startswith("pool_bytes_used_shard"))
max_diff = max(
    float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
    for a, b in zip(out_s, out_m))
bitwise_frac = float(np.mean([np.array_equal(a, b)
                              for a, b in zip(out_s, out_m)]))
eng_single.shutdown()
eng_sharded.shutdown()
print("RESULT " + json.dumps({{
    "single": single, "sharded": sharded,
    "per_round_ratios": [float(r) for r in ratios],
    "max_abs_diff_vs_single": max_diff,
    "bitwise_fraction": bitwise_frac,
    "pool_bytes_used_per_shard": shard_bytes,
    "pool_bytes_used_total": int(metrics.get("pool_bytes_used", 0)),
    "pool_shard_ways": int(metrics.get("pool_shard_ways", 0)),
    "dso_batch_axis": int(metrics.get("dso_batch_axis", 0)),
}}))
"""


def run_sharded_profile(bundle, params, csv=True):
    """Profile 8 (sharded): mesh-sharded serving vs single-device on the
    repeat-user workload, A/B-interleaved inside a forced-4-device
    subprocess.  ``bundle``/``params`` are unused — the subprocess rebuilds
    the same seeded model because the device count is fixed at jax import.
    Gates: median per-round throughput ratio >= the CPU parity floor, score
    agreement within the TP reassociation tolerance, and the pool byte
    budget actually split across model shards."""
    import subprocess
    import sys

    del bundle, params
    print("\n=== Sharded serving: (data=2, model=2) host mesh vs "
          f"single-device (forced {SHARDED_DEVICES} devices, repeat-user "
          f"workload, history {REPEAT_HISTORY}) ===")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)      # the script pins its own device count
    proc = subprocess.run(
        [sys.executable, "-c",
         _SHARDED_SCRIPT.format(devices=SHARDED_DEVICES,
                                model_parallel=SHARDED_MODEL_PARALLEL,
                                rounds=SHARDED_ROUNDS)],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if proc.returncode != 0:
        raise AssertionError(
            f"sharded A/B subprocess failed "
            f"(rc={proc.returncode}):\n{proc.stdout}\n{proc.stderr}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    single, sharded = res["single"], res["sharded"]
    speedup = float(np.median(res["per_round_ratios"]))
    speedup_agg = (sharded["throughput_items_per_s"]
                   / max(single["throughput_items_per_s"], 1e-9))
    print(f"{'config':<28}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}")
    for name, r in (("single-device", single),
                    (f"sharded (2,2) x{SHARDED_DEVICES}dev", sharded)):
        print(f"{name:<28}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}")
    print(f"-> sharded: throughput x{speedup:.2f} median per-round "
          f"(x{speedup_agg:.2f} aggregate) vs single-device on one CPU "
          f"({SHARDED_PARITY_MIN} parity floor — devices are emulated); "
          f"max |diff| {res['max_abs_diff_vs_single']:.2e}, bitwise on "
          f"{res['bitwise_fraction']:.0%}; pool bytes/shard "
          f"{res['pool_bytes_used_per_shard']} "
          f"({res['pool_shard_ways']} shard ways)")
    if csv:
        print(f"serving/sharded_single,{single['p50_latency_ms'] * 1e3:.1f},"
              f"tput={single['throughput_items_per_s']:.0f}")
        print(f"serving/sharded_mesh,{sharded['p50_latency_ms'] * 1e3:.1f},"
              f"tput={sharded['throughput_items_per_s']:.0f}")

    if res["max_abs_diff_vs_single"] > SHARDED_TOL:
        raise AssertionError(
            f"sharded scores diverged from single-device by "
            f"{res['max_abs_diff_vs_single']:.2e} (> {SHARDED_TOL}) — "
            f"correctness gate failed")
    if speedup < SHARDED_PARITY_MIN:
        raise AssertionError(
            f"sharded serving x{speedup:.2f} < {SHARDED_PARITY_MIN} median "
            f"per-round vs single-device (per-round ratios "
            f"{[round(r, 2) for r in res['per_round_ratios']]}) — the mesh "
            f"machinery is taxing the hot path beyond CPU-emulation "
            f"overhead")
    shard_bytes = res["pool_bytes_used_per_shard"]
    if res["pool_shard_ways"] != SHARDED_MODEL_PARALLEL or \
            len(set(shard_bytes)) != 1 or shard_bytes[0] <= 0 or \
            shard_bytes[0] * SHARDED_MODEL_PARALLEL != \
            res["pool_bytes_used_total"]:
        raise AssertionError(
            f"per-shard pool budget not split {SHARDED_MODEL_PARALLEL} "
            f"ways: shards {shard_bytes}, ways {res['pool_shard_ways']}, "
            f"total {res['pool_bytes_used_total']}")
    return {
        "workload": {"distribution": "jittered",
                     "counts": list(REPEAT_COUNTS),
                     "n_requests": N_REQUESTS, "history": REPEAT_HISTORY,
                     "n_users": REPEAT_USERS,
                     "max_batch": REPEAT_MAX_BATCH,
                     "devices": SHARDED_DEVICES,
                     "mesh": [SHARDED_DEVICES // SHARDED_MODEL_PARALLEL,
                              SHARDED_MODEL_PARALLEL]},
        "single_device": single,
        "sharded": sharded,
        "speedup_items_per_s": speedup_agg,
        "speedup_median_per_round": speedup,
        "per_round_ratios": res["per_round_ratios"],
        "max_abs_diff_vs_single": res["max_abs_diff_vs_single"],
        "bitwise_fraction": res["bitwise_fraction"],
        "pool_bytes_used_per_shard": shard_bytes,
        "pool_bytes_used_total": res["pool_bytes_used_total"],
        "pool_shard_ways": res["pool_shard_ways"],
        "global_batch_axis": res["dso_batch_axis"],
        "gates": {"sharded_parity_min": SHARDED_PARITY_MIN,
                  "sharded_tolerance": SHARDED_TOL,
                  "sharded_pool_split": True},
    }


def _decode_traffic(seed):
    """Zipf repeat-user decode traffic, alternating top-k and beam
    requests so one executor set serves both ranking policies."""
    from repro.serving.api import BeamConfig, TopKConfig

    tc = TrafficConfig(candidate_counts=DECODE_COUNTS, distribution="zipf",
                       n_requests=DECODE_REQUESTS, n_history=DECODE_HISTORY,
                       seed=seed, n_users=REPEAT_USERS)
    reqs = generate_traffic(tc, n_items=N_ITEMS)
    for i, r in enumerate(reqs):
        r["generate"] = (TopKConfig(k=DECODE_BEAM, steps=DECODE_STEPS)
                         if i % 2 == 0 else
                         BeamConfig(width=DECODE_BEAM, steps=DECODE_STEPS))
    return reqs


def _decode_engine(bundle, params, *, pack, impl="chunked", pool_dtype=None,
                   faults=None):
    kw = {"pool_dtype": pool_dtype} if pool_dtype else {}
    eng = create_engine(
        "flame", bundle, params, n_history=DECODE_HISTORY,
        buckets=BUCKETS, n_streams=2, feature_mode="sync",
        store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
        coalesce=True, max_batch=REPEAT_MAX_BATCH, window_s=0.008,
        n_workers=DECODE_WORKERS, history_cache=True,
        pool_slots=POOL_SLOTS, generate=DECODE_STEPS, pack_tails=pack,
        impl=impl, faults=faults, **kw)
    eng.features.query(list(range(N_ITEMS)))
    return eng


def _decode_chaos_pass(bundle, params, reqs, *, impl):
    """Chaos arm shared by the decode profiles: DECODE_FAULT_SPEC injects
    transient dispatch faults into decode-step dispatches and pool
    eviction storms that evict parked beam caches mid-generation.  Gates:
    zero hung futures (liveness) and ``gen_replays`` > 0 (an evicted beam
    re-decodes from the root instead of failing the request)."""
    from repro.serving.faults import FaultInjector

    faults = FaultInjector.parse(DECODE_FAULT_SPEC, seed=43)
    eng = _decode_engine(bundle, params, pack=True, impl=impl,
                         pool_dtype="int8", faults=faults)
    hung = 0
    last = {}
    for _ in range(2):
        r = run_workload_async(eng, reqs, tolerate_errors=True)
        hung += r["hung"]
        last = {k: r[k] for k in ("resolved", "rejected", "failed")}
    m = eng.metrics()
    eng.shutdown()
    chaos = dict(
        last, hung_total=hung, impl=impl, fault_spec=DECODE_FAULT_SPEC,
        fault_dispatch_fired=int(m.get("fault_dispatch_fired", 0)),
        fault_evict_fired=int(m.get("fault_evict_fired", 0)),
        dispatch_retries=int(m.get("dso_dispatch_retries", 0)),
        gen_replays=int(m.get("gen_replays", 0)))
    print(f"-> decode chaos ({impl}, {DECODE_FAULT_SPEC}): "
          f"{chaos['fault_dispatch_fired']} dispatch faults "
          f"({chaos['dispatch_retries']} retried), "
          f"{chaos['fault_evict_fired']} eviction storms, "
          f"{chaos['gen_replays']} beam replays; hung futures: {hung}")
    if hung:
        raise AssertionError(
            f"{hung} decode future(s) never resolved under fault "
            f"injection — the zero-hung liveness gate failed")
    if chaos["fault_dispatch_fired"] < 1 or chaos["fault_evict_fired"] < 1:
        raise AssertionError(
            "decode chaos pass fired no dispatch/evict faults — the "
            "injector is not engaging (seed/spec drift?)")
    if chaos["gen_replays"] < 1:
        raise AssertionError(
            "eviction storms never forced a mid-generation beam replay — "
            "the parked-beam recovery path is not being exercised")
    return chaos


def run_decode_profile(bundle, params, csv=True):
    """Profile 9: generative decode — DSO-packed beam decode vs per-request
    dispatch on zipf repeat-user traffic with alternating top-k and beam
    requests.  Each decode step on the unpacked side is one (width, bucket)
    dispatch per request; the packed side fills shared rows with beam
    segments from many in-flight requests.  Gates: exact token-sequence
    equality (both sides run the same row-wise batch-invariant AOT
    executables, so sequences must match bitwise), median per-round
    gen-tokens/s ratio >= DECODE_SPEEDUP_MIN (cpu-count-aware, see the
    constant), the packer actually engaging (packed segments > 0), and
    the shared chaos arm (zero hung futures, beam replays firing)."""
    print("\n=== Generative decode: DSO-packed beam rows vs per-request "
          f"dispatch (history {DECODE_HISTORY}, universes {DECODE_COUNTS} "
          f"zipf, {DECODE_STEPS} steps, width {DECODE_BEAM}) ===")
    reqs = _decode_traffic(seed=23)
    eng_packed = _decode_engine(bundle, params, pack=True)
    eng_plain = _decode_engine(bundle, params, pack=False)
    # warm both sides (compiles the decode/append executors and encodes
    # every user's history into the pool), then interleave measured rounds
    # — same drift-cancelling protocol as _ab_interleaved_ratios, but the
    # item unit here is GENERATED TOKENS, which that helper (built for
    # scoring traffic) would miscount from len(candidates)
    run_workload_async(eng_packed, reqs)
    run_workload_async(eng_plain, reqs)
    m0 = [eng_packed.metrics(), eng_plain.metrics()]
    agg = [dict(t=0.0, p50=[], p99=[]), dict(t=0.0, p50=[], p99=[])]
    outs = [None, None]
    ratios = []
    for _ in range(DECODE_ROUNDS):
        pair_t = [0.0, 0.0]
        for i, eng in enumerate((eng_packed, eng_plain)):
            r = run_workload_async(eng, reqs)
            outs[i] = r.pop("outputs")
            agg[i]["t"] += r["total_s"]
            pair_t[i] = r["total_s"]
            agg[i]["p50"].append(r["p50_latency_ms"])
            agg[i]["p99"].append(r["p99_latency_ms"])
        ratios.append(pair_t[1] / max(pair_t[0], 1e-9))  # plain_t/packed_t
    res = []
    for i, eng in enumerate((eng_packed, eng_plain)):
        tokens_per_pass = sum(int((o >= 0).sum()) for o in outs[i])
        m1 = eng.metrics()
        res.append({
            "requests": len(reqs) * DECODE_ROUNDS,
            "gen_tokens_per_s": (DECODE_ROUNDS * tokens_per_pass
                                 / max(agg[i]["t"], 1e-9)),
            "p50_latency_ms": float(np.median(agg[i]["p50"])),
            "p99_latency_ms": float(np.median(agg[i]["p99"])),
            "decode_dispatches": (m1.get("dso_dispatches_decode", 0)
                                  - m0[i].get("dso_dispatches_decode", 0)),
            "append_dispatches": (m1.get("dso_dispatches_append", 0)
                                  - m0[i].get("dso_dispatches_append", 0)),
            "packed_segments": (m1.get("dso_packed_segments", 0)
                                - m0[i].get("dso_packed_segments", 0)),
            **_pool_delta(m0[i], m1),
        })
        eng.shutdown()
    packed, plain = res
    seq_bitwise = all(np.array_equal(a, b)
                      for a, b in zip(outs[0], outs[1]))
    speedup = float(np.median(ratios))
    speedup_agg = (packed["gen_tokens_per_s"]
                   / max(plain["gen_tokens_per_s"], 1e-9))
    print(f"{'config':<26}{'gen tok/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'decode':>8}{'packed':>8}")
    for name, r in (("per-request decode", plain),
                    ("packed beam rows", packed)):
        print(f"{name:<26}{r['gen_tokens_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['decode_dispatches']:>8}{r['packed_segments']:>8}")
    print(f"-> decode packing: x{speedup:.2f} median per-round "
          f"(x{speedup_agg:.2f} aggregate); sequences bitwise-identical "
          f"across engines: {seq_bitwise}")
    if csv:
        print(f"serving/decode_unpacked,{plain['p50_latency_ms'] * 1e3:.1f},"
              f"tput={plain['gen_tokens_per_s']:.0f}")
        print(f"serving/decode_packed,{packed['p50_latency_ms'] * 1e3:.1f},"
              f"tput={packed['gen_tokens_per_s']:.0f}")

    if not seq_bitwise:
        raise AssertionError(
            "packed decode generated different token sequences than the "
            "per-request engine — correctness gate failed (same AOT "
            "executables must be row-wise batch-invariant)")
    if speedup < DECODE_SPEEDUP_MIN:
        raise AssertionError(
            f"packed decode median per-round speedup x{speedup:.2f} < "
            f"{DECODE_SPEEDUP_MIN} (per-round ratios "
            f"{[round(r, 2) for r in ratios]}) — perf gate failed")
    if packed["packed_segments"] < 1:
        raise AssertionError(
            "packed engine reported no packed segments during decode — "
            "the beam packer is not engaging on this traffic")
    chaos = _decode_chaos_pass(bundle, params, reqs, impl="chunked")
    return {
        "workload": {"distribution": "zipf", "counts": list(DECODE_COUNTS),
                     "n_requests": DECODE_REQUESTS,
                     "history": DECODE_HISTORY, "n_users": REPEAT_USERS,
                     "steps": DECODE_STEPS, "width": DECODE_BEAM,
                     "max_batch": REPEAT_MAX_BATCH},
        "unpacked": plain,
        "packed": packed,
        "speedup_gen_tokens_per_s": speedup_agg,
        "speedup_median_per_round": speedup,
        "per_round_ratios": [float(r) for r in ratios],
        "sequences_bitwise": bool(seq_bitwise),
        "chaos": chaos,
        "gates": {"decode_speedup_min": DECODE_SPEEDUP_MIN,
                  "decode_sequences_bitwise": True,
                  "decode_packed_segments_nonzero": True,
                  "decode_chaos_zero_hung": True,
                  "decode_chaos_gen_replays_nonzero": True},
    }


def run_decode_fused_profile(bundle, params, csv=True):
    """Profile 11 (FKE v2): fused generative decode — the lengths-masked
    fused kernel scores each decode step in ONE executor call against
    stored pool KV — vs the chunked per-pass decode, both segment-packed.
    Two passes: a NATIVE-pool parity pass (exact f32 math on both sides;
    every generated sequence must match token for token) and an int8-pool
    timed A/B (interleaved rounds, median per-round gen-tokens/s ratio
    >= DECODE_FUSED_SPEEDUP_MIN, cpu-count-aware).  The fused side must
    report zero ``packed_kernel_reroutes`` (the bq-alignment contract
    holds end to end) and the shared chaos arm runs against the fused
    engine (zero hung futures, beam replays firing)."""
    print("\n=== Fused generative decode (FKE v2): fused vs chunked "
          f"decode formulation (history {DECODE_HISTORY}, universes "
          f"{DECODE_COUNTS} zipf, {DECODE_STEPS} steps, width "
          f"{DECODE_BEAM}) ===")
    reqs = _decode_traffic(seed=31)

    # ---- native-pool parity pass: token-for-token sequence gate ----
    eng_ch = _decode_engine(bundle, params, pack=False, impl="chunked")
    eng_fu = _decode_engine(bundle, params, pack=False, impl="fused")
    want = run_workload_async(eng_ch, reqs)["outputs"]
    got = run_workload_async(eng_fu, reqs)["outputs"]
    seq_ok = all(np.array_equal(a, b) for a, b in zip(want, got))
    eng_ch.shutdown()
    eng_fu.shutdown()
    print(f"-> native-pool parity: fused sequences token-for-token equal "
          f"to chunked: {seq_ok} ({len(want)} requests)")

    # ---- int8-pool timed pass: interleaved A/B rounds ----
    eng_fused = _decode_engine(bundle, params, pack=True, impl="fused",
                               pool_dtype="int8")
    eng_chunk = _decode_engine(bundle, params, pack=True, impl="chunked",
                               pool_dtype="int8")
    run_workload_async(eng_fused, reqs)        # warm: compile + encode pool
    run_workload_async(eng_chunk, reqs)
    m0 = [eng_fused.metrics(), eng_chunk.metrics()]
    agg = [dict(t=0.0, p50=[], p99=[]), dict(t=0.0, p50=[], p99=[])]
    outs = [None, None]
    ratios = []
    for _ in range(DECODE_ROUNDS):
        pair_t = [0.0, 0.0]
        for i, eng in enumerate((eng_fused, eng_chunk)):
            r = run_workload_async(eng, reqs)
            outs[i] = r.pop("outputs")
            agg[i]["t"] += r["total_s"]
            pair_t[i] = r["total_s"]
            agg[i]["p50"].append(r["p50_latency_ms"])
            agg[i]["p99"].append(r["p99_latency_ms"])
        ratios.append(pair_t[1] / max(pair_t[0], 1e-9))  # chunked_t/fused_t
    res = []
    for i, eng in enumerate((eng_fused, eng_chunk)):
        tokens_per_pass = sum(int((o >= 0).sum()) for o in outs[i])
        m1 = eng.metrics()
        res.append({
            "requests": len(reqs) * DECODE_ROUNDS,
            "gen_tokens_per_s": (DECODE_ROUNDS * tokens_per_pass
                                 / max(agg[i]["t"], 1e-9)),
            "p50_latency_ms": float(np.median(agg[i]["p50"])),
            "p99_latency_ms": float(np.median(agg[i]["p99"])),
            "decode_dispatches": (m1.get("dso_dispatches_decode", 0)
                                  - m0[i].get("dso_dispatches_decode", 0)),
            "packed_segments": (m1.get("dso_packed_segments", 0)
                                - m0[i].get("dso_packed_segments", 0)),
            "packed_kernel_reroutes": int(
                m1.get("packed_kernel_reroutes", 0)),
            **_pool_delta(m0[i], m1),
        })
        eng.shutdown()
    fused, chunked = res
    # int8 pools: the two formulations round differently, so sequences may
    # legitimately diverge where quantized logits tie — report, don't gate
    int8_match = float(np.mean([np.array_equal(a, b)
                                for a, b in zip(outs[0], outs[1])]))
    speedup = float(np.median(ratios))
    speedup_agg = (fused["gen_tokens_per_s"]
                   / max(chunked["gen_tokens_per_s"], 1e-9))
    print(f"{'config':<26}{'gen tok/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'decode':>8}{'packed':>8}")
    for name, r in (("chunked decode (int8)", chunked),
                    ("fused decode (int8)", fused)):
        print(f"{name:<26}{r['gen_tokens_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['decode_dispatches']:>8}{r['packed_segments']:>8}")
    print(f"-> fused decode: x{speedup:.2f} median per-round "
          f"(x{speedup_agg:.2f} aggregate) vs chunked; int8 sequence "
          f"agreement {int8_match:.2f}; packed kernel reroutes "
          f"{fused['packed_kernel_reroutes']}")
    if csv:
        print(f"serving/decode_chunked_int8,"
              f"{chunked['p50_latency_ms'] * 1e3:.1f},"
              f"tput={chunked['gen_tokens_per_s']:.0f}")
        print(f"serving/decode_fused_int8,"
              f"{fused['p50_latency_ms'] * 1e3:.1f},"
              f"tput={fused['gen_tokens_per_s']:.0f}")

    if not seq_ok:
        raise AssertionError(
            "fused decode generated different token sequences than the "
            "chunked engine on the NATIVE pool — correctness gate failed "
            "(both sides run exact f32 math over the same stored values)")
    if fused["packed_kernel_reroutes"]:
        raise AssertionError(
            f"{fused['packed_kernel_reroutes']} packed kernel dispatch(es) "
            f"rerouted to the jnp formulation — the bq-alignment contract "
            f"is not holding on the fused engine")
    if speedup < DECODE_FUSED_SPEEDUP_MIN:
        raise AssertionError(
            f"fused decode median per-round speedup x{speedup:.2f} < "
            f"{DECODE_FUSED_SPEEDUP_MIN} vs chunked (per-round ratios "
            f"{[round(r, 2) for r in ratios]}) — perf gate failed")
    chaos = _decode_chaos_pass(bundle, params, reqs, impl="fused")
    return {
        "workload": {"distribution": "zipf", "counts": list(DECODE_COUNTS),
                     "n_requests": DECODE_REQUESTS,
                     "history": DECODE_HISTORY, "n_users": REPEAT_USERS,
                     "steps": DECODE_STEPS, "width": DECODE_BEAM,
                     "max_batch": REPEAT_MAX_BATCH,
                     "pool_dtype_timed": "int8",
                     "cpu_count": int(os.cpu_count() or 1)},
        "chunked": chunked,
        "fused": fused,
        "speedup_gen_tokens_per_s": speedup_agg,
        "speedup_median_per_round": speedup,
        "per_round_ratios": [float(r) for r in ratios],
        "native_sequences_token_for_token": bool(seq_ok),
        "int8_sequence_agreement": int8_match,
        "chaos": chaos,
        "gates": {"decode_fused_speedup_min": DECODE_FUSED_SPEEDUP_MIN,
                  "decode_fused_native_sequences": True,
                  "decode_fused_zero_reroutes": True,
                  "decode_fused_chaos_zero_hung": True,
                  "decode_fused_chaos_gen_replays_nonzero": True},
    }


def run_overload_profile(bundle, params, csv=True):
    """Profile 10 (overload): SLO-tiered EDF admission + shedding vs FIFO
    under sustained overload, plus a chaos pass under fault injection.
    Gates: EDF interactive-tier goodput-under-SLO >= OVERLOAD_GOODPUT_MIN x
    FIFO (median per-round, +1-smoothed), zero hung futures everywhere,
    and the chaos arms actually firing."""
    from repro.serving.api import DegradationPolicy
    from repro.serving.faults import FaultInjector

    print("\n=== Overload: EDF admission + tiered shedding vs FIFO "
          f"(lognormal traffic, {OVERLOAD_REQUESTS} reqs -> "
          f"{OVERLOAD_WORKERS} workers, queue {OVERLOAD_PENDING}, "
          f"SLOs {OVERLOAD_TIER_SLO}) ===")
    tc = TrafficConfig(candidate_counts=OVERLOAD_COUNTS,
                       distribution="lognormal",
                       n_requests=OVERLOAD_REQUESTS,
                       n_history=OVERLOAD_HISTORY, seed=37,
                       n_users=REPEAT_USERS, tier_mix=OVERLOAD_TIER_MIX)
    reqs = generate_traffic(tc, n_items=N_ITEMS)

    def overload_engine(admission, shed, faults=None, degradation=None,
                        watchdog=0.0):
        eng = create_engine(
            "flame", bundle, params, n_history=OVERLOAD_HISTORY,
            buckets=BUCKETS, n_streams=2, feature_mode="sync",
            store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
            coalesce=True, max_batch=MAX_BATCH, window_s=0.002,
            n_workers=OVERLOAD_WORKERS, max_pending=OVERLOAD_PENDING,
            history_cache=True, pool_slots=POOL_SLOTS,
            admission=admission, shed_policy=shed,
            slo_tier_defaults=dict(OVERLOAD_TIER_SLO),
            faults=faults, degradation=degradation,
            watchdog_grace_s=watchdog)
        eng.features.query(list(range(N_ITEMS)))
        return eng

    eng_fifo = overload_engine("fifo", "none")
    eng_edf = overload_engine("edf", "tiered")
    # warm both sides (executors compiled, pool encoded), then interleave
    # measured rounds; goodput is a COUNTER, so each round reads the delta
    run_workload_async(eng_fifo, reqs, tolerate_errors=True)
    run_workload_async(eng_edf, reqs, tolerate_errors=True)
    sides = [dict(eng=eng_fifo, name="fifo", good=[], missed=[], shed=0,
                  hung=0),
             dict(eng=eng_edf, name="edf", good=[], missed=[], shed=0,
                  hung=0)]
    ratios = []
    for _ in range(OVERLOAD_ROUNDS):
        round_good = [0, 0]
        for i, s in enumerate(sides):
            m0 = s["eng"].metrics()
            r = run_workload_async(s["eng"], reqs, tolerate_errors=True)
            m1 = s["eng"].metrics()
            s["hung"] += r["hung"]
            g = int(m1.get("goodput_interactive", 0)
                    - m0.get("goodput_interactive", 0))
            s["good"].append(g)
            s["missed"].append(int(
                m1.get("deadline_misses_interactive", 0)
                - m0.get("deadline_misses_interactive", 0)))
            round_good[i] = g
        ratios.append((round_good[1] + 1) / (round_good[0] + 1))
    summary = {}
    for s in sides:
        m = s["eng"].metrics()
        summary[s["name"]] = {
            "goodput_interactive_per_round": s["good"],
            "misses_interactive_per_round": s["missed"],
            "goodput_interactive": int(sum(s["good"])),
            "shed_total": int(m.get("shed_total", 0)),
            "shed_bulk": int(m.get("shed_bulk", 0)),
            "shed_standard": int(m.get("shed_standard", 0)),
            "shed_interactive": int(m.get("shed_interactive", 0)),
            "hung": s["hung"],
        }
        s["eng"].shutdown()
    goodput_ratio = float(np.median(ratios))
    print(f"{'policy':<22}{'good(int)':>10}{'miss(int)':>10}{'shed':>7}")
    for name in ("fifo", "edf"):
        r = summary[name]
        print(f"{name:<22}{r['goodput_interactive']:>10}"
              f"{sum(r['misses_interactive_per_round']):>10}"
              f"{r['shed_total']:>7}")
    print(f"-> EDF+shed: interactive goodput-under-SLO x{goodput_ratio:.2f} "
          f"median per-round vs FIFO (per-round "
          f"{[round(r, 2) for r in ratios]}); EDF shed "
          f"{summary['edf']['shed_total']} low-priority requests to get "
          f"there; hung futures fifo={summary['fifo']['hung']} "
          f"edf={summary['edf']['hung']}")

    # ---- chaos pass: injected faults must never hang a future ----
    faults = FaultInjector.parse(OVERLOAD_FAULT_SPEC, seed=41)
    eng_chaos = overload_engine(
        "edf", "tiered", faults=faults,
        degradation=DegradationPolicy(threshold_s=0.05),
        watchdog=OVERLOAD_WATCHDOG_GRACE_S)
    chaos_hung = 0
    chaos = {}
    for _ in range(2):
        r = run_workload_async(eng_chaos, reqs, tolerate_errors=True)
        chaos_hung += r["hung"]
        chaos = {k: r[k] for k in
                 ("resolved", "rejected", "failed", "hung")}
    mc = eng_chaos.metrics()
    chaos.update(
        hung_total=chaos_hung,
        fault_dispatch_fired=int(mc.get("fault_dispatch_fired", 0)),
        fault_stall_fired=int(mc.get("fault_stall_fired", 0)),
        fault_evict_fired=int(mc.get("fault_evict_fired", 0)),
        dispatch_retries=int(mc.get("dso_dispatch_retries", 0)),
        dispatch_failures=int(mc.get("dso_dispatch_failures", 0)),
        watchdog_timeouts=int(mc.get("watchdog_timeouts", 0)),
        encode_recoveries=int(mc.get("encode_recoveries", 0)),
        degrade_steps=int(mc.get("degrade_steps", 0)))
    eng_chaos.shutdown()
    print(f"-> chaos ({OVERLOAD_FAULT_SPEC}): "
          f"{chaos['fault_dispatch_fired']} dispatch faults "
          f"({chaos['dispatch_retries']} retried, "
          f"{chaos['dispatch_failures']} fatal), "
          f"{chaos['fault_stall_fired']} stalls, "
          f"{chaos['fault_evict_fired']} eviction storms, "
          f"{chaos['watchdog_timeouts']} watchdog fails; "
          f"hung futures: {chaos_hung}")
    if csv:
        print(f"serving/overload_fifo,0,"
              f"goodput_int={summary['fifo']['goodput_interactive']}")
        print(f"serving/overload_edf,0,"
              f"goodput_int={summary['edf']['goodput_interactive']}")

    total_hung = (summary['fifo']['hung'] + summary['edf']['hung']
                  + chaos_hung)
    if total_hung:
        raise AssertionError(
            f"{total_hung} future(s) never resolved — the zero-hung "
            f"liveness gate failed")
    if goodput_ratio < OVERLOAD_GOODPUT_MIN:
        raise AssertionError(
            f"EDF+shed interactive goodput x{goodput_ratio:.2f} < "
            f"{OVERLOAD_GOODPUT_MIN} vs FIFO (per-round ratios "
            f"{[round(r, 2) for r in ratios]}) — overload gate failed")
    if chaos["fault_dispatch_fired"] < 1 or chaos["fault_evict_fired"] < 1:
        raise AssertionError(
            "chaos pass fired no dispatch/evict faults — the injector is "
            "not engaging (seed/spec drift?)")
    return {
        "workload": {"distribution": "lognormal",
                     "counts": list(OVERLOAD_COUNTS),
                     "n_requests": OVERLOAD_REQUESTS,
                     "history": OVERLOAD_HISTORY, "n_users": REPEAT_USERS,
                     "tier_mix": dict(OVERLOAD_TIER_MIX),
                     "tier_slo_s": dict(OVERLOAD_TIER_SLO),
                     "n_workers": OVERLOAD_WORKERS,
                     "max_pending": OVERLOAD_PENDING,
                     "cpu_count": int(os.cpu_count() or 1)},
        "fifo": summary["fifo"],
        "edf": summary["edf"],
        "goodput_ratio_median_per_round": goodput_ratio,
        "per_round_ratios": [float(r) for r in ratios],
        "chaos": dict(chaos, fault_spec=OVERLOAD_FAULT_SPEC),
        "gates": {"overload_goodput_min": OVERLOAD_GOODPUT_MIN,
                  "zero_hung_futures": True,
                  "chaos_faults_fired": True},
    }


def _merge_report(section: str, payload: dict):
    """Update one section of BENCH_serving.json in place (standalone
    profile runs must not clobber the other profiles' trajectory)."""
    path = os.path.abspath(OUT_PATH)
    report = {}
    if os.path.exists(path):
        with open(path) as f:
            report = json.load(f)
    report[section] = payload
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path} ({section})")


#: standalone profile name -> runner.  scripts/check_docs.py parses this
#: dict (by AST, without importing jax) to verify every `--profile <name>`
#: mentioned in benchmarks/README.md actually exists; add new profiles here.
PROFILE_RUNNERS = {
    "fke": run_fke_profile,
    "dso_nonuniform": run_dso_nonuniform_profile,
    "sharded": run_sharded_profile,
    "decode": run_decode_profile,
    "decode_fused": run_decode_fused_profile,
    "overload": run_overload_profile,
}


def main(csv=True, profile: str = "all"):
    cfg, bundle, params = make_climber(d_model=64, layers=2, blocks=2)
    if profile in PROFILE_RUNNERS:
        _merge_report(profile, PROFILE_RUNNERS[profile](bundle, params, csv))
        return
    tc = TrafficConfig(candidate_counts=COUNTS, distribution="jittered",
                       n_requests=N_REQUESTS, n_history=HISTORY, seed=11)
    reqs = generate_traffic(tc, n_items=N_ITEMS)

    print("\n=== Serving API v2: coalesced vs per-request dispatch "
          "(jittered traffic, hot cache) ===")
    base, out_base, _ = _run(bundle, params, reqs, coalesce=False,
                             sequential_ref=False)
    coal, out_coal, seq_ref = _run(bundle, params, reqs, coalesce=True,
                                   sequential_ref=True)

    bitwise_seq = all(np.array_equal(a, b)
                      for a, b in zip(seq_ref, out_coal))
    bitwise_base = all(np.array_equal(a, b)
                       for a, b in zip(out_base, out_coal))
    print(f"{'config':<26}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'dispatches':>12}{'fill':>6}")
    for name, r in (("per-request (B=1)", base),
                    (f"coalesced (B={MAX_BATCH})", coal)):
        print(f"{name:<26}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['dispatches']:>12}{r['avg_fill']:>6.1f}")
    speedup = (coal["throughput_items_per_s"]
               / max(base["throughput_items_per_s"], 1e-9))
    print(f"-> coalescing: throughput x{speedup:.2f}; bitwise vs sequential "
          f"self: {bitwise_seq}; bitwise vs B=1 baseline: {bitwise_base}")
    if csv:
        print(f"serving/uncoalesced,{base['p50_latency_ms'] * 1e3:.1f},"
              f"tput={base['throughput_items_per_s']:.0f}")
        print(f"serving/coalesced,{coal['p50_latency_ms'] * 1e3:.1f},"
              f"tput={coal['throughput_items_per_s']:.0f}")

    print("\n=== History-KV pool: repeat-user / session re-rank "
          f"({REPEAT_USERS} users, history {REPEAT_HISTORY}, hot pool) ===")
    rtc = TrafficConfig(candidate_counts=REPEAT_COUNTS,
                        distribution="jittered",
                        n_requests=N_REQUESTS, n_history=REPEAT_HISTORY,
                        seed=13, n_users=REPEAT_USERS)
    rreqs = generate_traffic(rtc, n_items=N_ITEMS)
    eng_full = _repeat_engine(bundle, params, history_cache=False)
    eng_pool = _repeat_engine(bundle, params, history_cache=True,
                              pool_budget_bytes=V2_BUDGET_BYTES)
    full, out_full, pooled, out_pool = _ab_interleaved(eng_full, eng_pool,
                                                       rreqs)
    eng_full.shutdown()
    bitwise_frac = np.mean([np.array_equal(a, b)
                            for a, b in zip(out_full, out_pool)])
    pool_max_diff = max(
        float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
        for a, b in zip(out_full, out_pool))
    print(f"{'config':<26}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'hits':>6}{'miss':>6}")
    for name, r in (("full pass (pool off)", full),
                    ("history pool (hot)", pooled)):
        print(f"{name:<26}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['pool_hits']:>6}{r['pool_misses']:>6}")
    pool_speedup = (pooled["throughput_items_per_s"]
                    / max(full["throughput_items_per_s"], 1e-9))
    print(f"-> history pool: throughput x{pool_speedup:.2f}; vs full pass: "
          f"max |diff| {pool_max_diff:.2e}, bitwise on "
          f"{bitwise_frac:.0%} of requests; "
          f"pool bytes {pooled['pool_bytes']}")
    if csv:
        print(f"serving/repeat_full,{full['p50_latency_ms'] * 1e3:.1f},"
              f"tput={full['throughput_items_per_s']:.0f}")
        print(f"serving/repeat_pooled,{pooled['p50_latency_ms'] * 1e3:.1f},"
              f"tput={pooled['throughput_items_per_s']:.0f}")

    print("\n=== PDA v2: device-resident, byte-budgeted pool vs PR 2-style "
          "host pool (hot repeat-user path) ===")
    eng_v1 = _repeat_engine(bundle, params, history_cache=True,
                            pool_placement="host", kv_dedup=False)
    v1_style, out_v1, v2, out_v2 = _ab_interleaved(eng_v1, eng_pool, rreqs)
    eng_v1.shutdown()
    v2_speedup = (v2["throughput_items_per_s"]
                  / max(v1_style["throughput_items_per_s"], 1e-9))
    v2_max_diff = max(
        float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
        for a, b in zip(out_v1, out_v2))
    # KV-row dedup exercised explicitly: auto-dedup resolves OFF on the CPU
    # backend (stacking is a local memcpy; the executor gather would be
    # pure overhead) and ON for accelerators, where each deduped row is a
    # skipped host->HBM transfer.  Recorded, not wall-clock-gated on CPU.
    eng_dd = _repeat_engine(bundle, params, history_cache=True,
                            kv_dedup=True)
    run_workload_async(eng_dd, rreqs)
    m0 = eng_dd.metrics()
    rdd = run_workload_async(eng_dd, rreqs)
    rdd.pop("outputs")
    forced = dict(rdd, **_pool_delta(m0, eng_dd.metrics()))
    row_bytes = forced["pool_bytes"] // max(len(eng_dd.history_pool), 1)
    forced["transfer_bytes_saved_per_pass"] = \
        forced["dedup_rows_saved"] * row_bytes
    eng_dd.shutdown()
    print(f"{'config':<28}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'dedup':>7}")
    for name, r in (("v1-style (host, no dedup)", v1_style),
                    ("PDA v2 (device + budget)", v2),
                    ("PDA v2 + forced dedup", forced)):
        print(f"{name:<28}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['dedup_rows_saved']:>7}")
    print(f"-> PDA v2: throughput x{v2_speedup:.2f} vs v1-style pool "
          f"(CPU backend: placements coincide, so this is a parity guard; "
          f"the dedup row saves {forced['dedup_rows_saved']} restacks "
          f"= {forced['transfer_bytes_saved_per_pass'] / 1e6:.1f} MB of "
          f"per-pass H2D on an accelerator); max |diff| {v2_max_diff:.2e}")
    if csv:
        print(f"serving/pool_v1_style,{v1_style['p50_latency_ms'] * 1e3:.1f},"
              f"tput={v1_style['throughput_items_per_s']:.0f}")
        print(f"serving/pool_v2,{v2['p50_latency_ms'] * 1e3:.1f},"
              f"tput={v2['throughput_items_per_s']:.0f}")

    print("\n=== Suffix extension: stale-sweep (tail-append) traffic, "
          "full re-encode vs incremental ===")
    reenc, out_re, ext, out_ext = _run_stale_sweeps_interleaved(bundle,
                                                                params)
    ext_speedup = (ext["throughput_items_per_s"]
                   / max(reenc["throughput_items_per_s"], 1e-9))
    ext_max_diff = max(
        float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
        for a, b in zip(out_re, out_ext))
    print(f"{'config':<26}{'items/s':>10}{'p50 ms':>9}{'p99 ms':>9}"
          f"{'stale':>7}{'ext':>5}")
    for name, r in (("full re-encode", reenc),
                    ("suffix extension", ext)):
        print(f"{name:<26}{r['throughput_items_per_s']:>10.0f}"
              f"{r['p50_latency_ms']:>9.1f}{r['p99_latency_ms']:>9.1f}"
              f"{r['pool_stale']:>7}{r['pool_extensions']:>5}")
    print(f"-> suffix extension: throughput x{ext_speedup:.2f} on stale "
          f"hits; max |diff| vs re-encode {ext_max_diff:.2e}")
    if csv:
        print(f"serving/stale_reencode,{reenc['p50_latency_ms'] * 1e3:.1f},"
              f"tput={reenc['throughput_items_per_s']:.0f}")
        print(f"serving/stale_extend,{ext['p50_latency_ms'] * 1e3:.1f},"
              f"tput={ext['throughput_items_per_s']:.0f}")

    print("\n=== Quantized pool: int8 entries vs native "
          "(hot repeat-user path) ===")
    eng_q8 = _repeat_engine(bundle, params, history_cache=True,
                            pool_dtype="int8")
    v2_again, _, q8, out_q8 = _ab_interleaved(eng_pool, eng_q8, rreqs)
    eng_pool.shutdown()
    eng_q8.shutdown()
    q8_speedup = (q8["throughput_items_per_s"]
                  / max(v2_again["throughput_items_per_s"], 1e-9))
    q8_drift = max(
        float(np.abs(a.astype(np.float32) - b.astype(np.float32)).max())
        for a, b in zip(out_v2, out_q8))
    bytes_ratio = q8["pool_bytes"] / max(v2["pool_bytes"], 1)
    print(f"int8 pool: {q8['throughput_items_per_s']:.0f} items/s "
          f"(x{q8_speedup:.2f} vs native), bytes/entry ratio "
          f"{bytes_ratio:.2f}, score drift {q8_drift:.2e} "
          f"(~{1 / max(bytes_ratio, 1e-9):.1f}x users per byte budget)")
    if csv:
        print(f"serving/pool_int8,{q8['p50_latency_ms'] * 1e3:.1f},"
              f"tput={q8['throughput_items_per_s']:.0f}")

    fke = run_fke_profile(bundle, params, csv)
    dso_nonuniform = run_dso_nonuniform_profile(bundle, params, csv)
    sharded = run_sharded_profile(bundle, params, csv)
    decode = run_decode_profile(bundle, params, csv)
    decode_fused = run_decode_fused_profile(bundle, params, csv)
    overload = run_overload_profile(bundle, params, csv)

    report = {
        "workload": {"distribution": "jittered", "counts": list(COUNTS),
                     "n_requests": N_REQUESTS, "history": HISTORY,
                     "buckets": list(BUCKETS), "max_batch": MAX_BATCH,
                     "n_workers": N_WORKERS},
        "uncoalesced": base,
        "coalesced": coal,
        "speedup_items_per_s": speedup,
        "bitwise_identical": bool(bitwise_base),
        "bitwise_vs_sequential_self": bool(bitwise_seq),
        "repeat_user": {
            "workload": {"distribution": "jittered",
                         "counts": list(REPEAT_COUNTS),
                         "n_requests": N_REQUESTS, "history": REPEAT_HISTORY,
                         "n_users": REPEAT_USERS, "pool_slots": POOL_SLOTS,
                         "max_batch": REPEAT_MAX_BATCH},
            "full_pass": full,
            "history_pool": pooled,
            "speedup_items_per_s": pool_speedup,
            "max_abs_diff_vs_full": pool_max_diff,
            "bitwise_fraction": float(bitwise_frac),
        },
        "pda_v2": {
            "v1_style_pool": v1_style,
            "v2_pool": v2,
            "forced_dedup": forced,
            "speedup_items_per_s": v2_speedup,
            "max_abs_diff_vs_v1": v2_max_diff,
        },
        "suffix_extension": {
            "workload": {"n_sweeps": 16, "n_users": REPEAT_USERS,
                         "history": STALE_HISTORY, "tail_append": 4},
            "full_reencode": reenc,
            "incremental": ext,
            "speedup_items_per_s": ext_speedup,
            "max_abs_diff_vs_reencode": ext_max_diff,
        },
        "quantized_pool": {
            "int8": q8,
            "items_per_s_vs_native": q8_speedup,
            "bytes_ratio_vs_native": bytes_ratio,
            "max_score_drift_vs_native": q8_drift,
        },
        "fke": fke,
        "dso_nonuniform": dso_nonuniform,
        "sharded": sharded,
        "decode": decode,
        "decode_fused": decode_fused,
        "overload": overload,
        "gates": {
            "coalesced_bitwise": True,
            "pool_tolerance": 2e-3,
            "pool_speedup_min": 1.5,
            "pda_v2_speedup_min": 0.9,
            "extension_speedup_min": 1.1,
            "int8_drift_max": 5e-2,
            "fke_speedup_min": FKE_SPEEDUP_MIN,
            "dso_pack_speedup_min": DSO_SPEEDUP_MIN,
            "dso_pad_ratio_min": DSO_PAD_RATIO_MIN,
            "sharded_parity_min": SHARDED_PARITY_MIN,
            "sharded_tolerance": SHARDED_TOL,
            "decode_speedup_min": DECODE_SPEEDUP_MIN,
            "decode_fused_speedup_min": DECODE_FUSED_SPEEDUP_MIN,
            "overload_goodput_min": OVERLOAD_GOODPUT_MIN,
        },
    }
    path = os.path.abspath(OUT_PATH)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    if not (bitwise_seq and bitwise_base):
        raise AssertionError("coalesced scores diverged from per-request "
                             "reference — correctness gate failed")
    if pool_max_diff > 2e-3:
        raise AssertionError(
            f"pooled-history scores diverged from the full pass by "
            f"{pool_max_diff:.2e} (> 2e-3) — correctness gate failed")
    if pool_speedup < 1.5:
        raise AssertionError(
            f"history pool speedup x{pool_speedup:.2f} < 1.5 on the "
            f"repeat-user profile — perf gate failed")
    if v2_max_diff > 2e-3 or ext_max_diff > 2e-3:
        raise AssertionError(
            f"PDA v2 / suffix-extension scores diverged (v2 "
            f"{v2_max_diff:.2e}, ext {ext_max_diff:.2e} vs 2e-3 gate)")
    if v2_speedup < 0.9:
        raise AssertionError(
            f"PDA v2 x{v2_speedup:.2f} vs the v1-style pool — parity "
            f"guard failed (v2 machinery must be free on CPU)")
    if forced["dedup_rows_saved"] < 1:
        raise AssertionError(
            "forced-dedup run saved no KV-row restacks — dedup machinery "
            "is not engaging on multi-chunk traffic")
    if ext_speedup < 1.1:
        raise AssertionError(
            f"suffix extension x{ext_speedup:.2f} < 1.1 vs full re-encode "
            f"on stale sweeps — perf gate failed")
    if q8_drift > 5e-2:
        raise AssertionError(
            f"int8 pool score drift {q8_drift:.2e} exceeds the 5e-2 bound")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="all",
                    choices=["all"] + sorted(PROFILE_RUNNERS),
                    help="'fke' runs only the fused-engine A/B + gates; "
                         "'dso_nonuniform' runs only the segment-packing "
                         "vs PR-4-coalescing A/B + gates; 'decode' runs "
                         "only the packed-vs-unpacked generative decode "
                         "A/B + gates (all CI gates); each merges its "
                         "section into BENCH_serving.json")
    main(profile=ap.parse_args().profile)
