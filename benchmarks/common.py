"""Shared benchmark utilities."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.types import ClimberConfig


def bench_climber_cfg(d_model=128, layers=2, blocks=2):
    """CPU-feasible Climber with the paper's structure (blocks/SUMI/head)."""
    return dataclasses.replace(
        get_config("climber"), vocab_size=50_000, d_model=d_model,
        d_ff=4 * d_model, n_heads=4, n_kv_heads=4, head_dim=d_model // 4,
        climber=ClimberConfig(num_blocks=blocks, layers_per_block=layers))


def make_climber(d_model=128, layers=2, blocks=2, seed=0):
    cfg = bench_climber_cfg(d_model, layers, blocks)
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(seed))
    return cfg, bundle, params


def timeit(fn, *args, warmup=2, iters=8):
    """Median wall time (s) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}")
