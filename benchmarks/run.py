"""Benchmark harness — one module per paper table.

  bench_pda       Table 3: PDA feature-pipeline ablation (measured)
  bench_fke       Table 4: FKE engine-build ablation (measured + modeled)
  bench_dso       Table 5: DSO vs implicit-shape mixed traffic (measured)
  bench_serving   API v2 coalesced-vs-per-request A/B; emits BENCH_serving.json
  bench_roofline  assignment roofline table from dry-run artifacts

Each prints human tables plus ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only pda|fke|dso|serving|roofline]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "pda", "fke", "dso", "serving",
                             "roofline"])
    args = ap.parse_args()

    from benchmarks import (bench_dso, bench_fke, bench_pda, bench_roofline,
                            bench_serving)
    jobs = {"pda": bench_pda.main, "fke": bench_fke.main,
            "dso": bench_dso.main, "serving": bench_serving.main,
            "roofline": bench_roofline.main}
    failed = []
    for name, fn in jobs.items():
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
