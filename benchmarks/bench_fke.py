"""Paper Table 4 — FKE ablation under the *base* (512+128) and *long*
(1024+512) scenarios.

Row mapping (DESIGN.md §2):
  "ONNX Model Conversion"   -> node-by-node eager dispatch (each op hits the
                               runtime separately — the ONNX-runtime-style
                               unspecialized execution), materialized-mask
                               attention
  "TensorRT API Impl."      -> one AOT-compiled XLA graph (whole-graph fusion,
                               the hand-built-network analogue)
  "+ Kernel Fusion"         -> the Pallas mask-aware flash-attention +
                               fused-FFN kernels.  On this CPU container the
                               kernels run in interpret mode (Python), so the
                               wall-clock row is NOT meaningful; we report the
                               roofline-modeled gain from mask-aware block
                               skipping instead (validated for correctness in
                               tests/test_kernels.py).

Throughput is user-item pairs per second, as in the paper.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_climber, timeit
from repro.core import sumi
from repro.core.climber import climber_forward

SCENARIOS = {"base": (512, 128), "long": (1024, 512)}
BATCH = 1      # SUMI: one user per request


def _batch(cfg, n, m, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return {
        "history": jax.random.randint(ks[0], (BATCH, n), 0, cfg.vocab_size),
        "candidates": jax.random.randint(ks[1], (BATCH, m), 0, cfg.vocab_size),
        "side": jax.random.normal(ks[2], (BATCH, 12)),
    }


def _aot(fn, batch):
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          batch)
    return jax.jit(fn).lower(shapes).compile()


def mask_aware_speedup(n, m, n_blocks=2):
    """Attention-FLOP ratio dense/SUMI-skipped (the paper's mask-aware gain).

    Dense scores: S^2 per block (S = n/N_b + M).  Mask-aware kernel: history
    causal (~h^2/2) + candidates x (history + self)."""
    h = n // n_blocks
    s = h + m
    dense = s * s
    skipped = h * h / 2 + m * (h + 1)
    return dense / skipped


def run_scenario(name, n, m):
    cfg, bundle, params = make_climber(d_model=128, layers=2, blocks=2)
    batch = _batch(cfg, n, m)
    pairs = m  # user-item pairs per request

    # --- row 1: "ONNX conversion": node-by-node eager dispatch with
    # materialized-mask attention (runtime interprets the graph op by op)
    def onnx_like(b):
        return climber_forward(params, b, cfg, impl="reference")

    t_onnx = timeit(onnx_like, batch, warmup=1, iters=3)

    # --- row 2: "TensorRT API": ONE AOT-compiled fused graph
    compiled = _aot(onnx_like, batch)
    t_trt = timeit(compiled, batch, warmup=2, iters=6)

    # --- row 3: "+ kernel fusion": roofline-modeled from the mask-aware
    # skipping factor applied to the attention share of row 2
    # attention share of total flops:
    total_fl = sumi.flops_per_request(n, m, 2, 2, cfg.d_model, cfg.d_ff)
    hsub = n // 2
    s_blk = hsub + m
    attn_fl = 2 * 2 * 2 * 2 * s_blk * s_blk * cfg.d_model  # blocks*layers*qk,pv
    attn_share = min(0.9, attn_fl / total_fl)
    speed = mask_aware_speedup(n, m)
    t_fused_model = t_trt * ((1 - attn_share) + attn_share / speed)

    return {
        "scenario": f"{name} ({n}+{m})",
        "rows": [
            ("ONNX Model Conversion", t_onnx, pairs / t_onnx),
            ("TensorRT API Impl.", t_trt, pairs / t_trt),
            ("+ Kernel Fusion (modeled)", t_fused_model, pairs / t_fused_model),
        ],
        "mask_aware_speedup": speed,
        "attn_share": attn_share,
    }


def main(csv=True):
    print("\n=== Table 4 analogue: FKE ablation ===")
    for name, (n, m) in SCENARIOS.items():
        res = run_scenario(name, n, m)
        print(f"\n--- scenario {res['scenario']} "
              f"(mask-aware attention skip x{res['mask_aware_speedup']:.2f}, "
              f"attn share {res['attn_share']:.2f}) ---")
        print(f"{'engine build':<30}{'latency ms':>12}{'pairs/s':>12}")
        base = res["rows"][0][1]
        for rname, t, tput in res["rows"]:
            print(f"{rname:<30}{t*1e3:>12.2f}{tput:>12.0f}  "
                  f"(x{base/t:.2f} vs ONNX)")
        if csv:
            for rname, t, tput in res["rows"]:
                print(f"fke/{name}/{rname},{t*1e6:.1f},tput={tput:.0f}")
    print("\nNOTE: '+ Kernel Fusion' wall-clock is roofline-modeled — Pallas "
          "kernels execute in interpret mode on CPU; correctness is asserted "
          "against ref.py oracles in tests/test_kernels.py, and the TPU-side "
          "gain comes from mask-aware KV-block skipping (see DESIGN.md).")


if __name__ == "__main__":
    main()
