#!/usr/bin/env python
"""Docs-reference check: fail CI when docs point at files that don't exist.

Scans the backtick code spans of the narrative docs for repo-relative
path-like references (contain a ``/`` or a known suffix) and verifies each
resolves to a real file or directory.  Keeps docs/ARCHITECTURE.md,
benchmarks/README.md and DESIGN.md honest as the tree refactors.

Also cross-checks the ``--profile <name>`` tokens in benchmarks/README.md
against the ``PROFILE_RUNNERS`` registry in benchmarks/bench_serving.py
(parsed by AST so the check never imports jax).

    python scripts/check_docs.py
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = ["docs/ARCHITECTURE.md", "benchmarks/README.md", "DESIGN.md"]
SUFFIXES = (".py", ".md", ".sh", ".json", ".yml")

# `code span` that looks like a repo path: has a slash or a known suffix
_CODE = re.compile(r"`([^`\n]+)`")
# markdown links: [text](target)
_LINK = re.compile(r"\]\(([^)#\s]+)\)")


def _candidates(text: str):
    for m in _CODE.finditer(text):
        ref = m.group(1).strip()
        if " " in ref or ref.startswith(("--", "-", "<", "{")):
            continue                      # flags / placeholders, not paths
        if "/" in ref or ref.endswith(SUFFIXES):
            yield ref
    for m in _LINK.finditer(text):
        ref = m.group(1).strip()
        if "://" in ref:
            continue                      # external URL
        yield ref


def check(doc: str) -> list:
    path = os.path.join(ROOT, doc)
    base = os.path.dirname(path)
    missing = []
    with open(path) as f:
        text = f.read()
    for ref in _candidates(text):
        ref = ref.rstrip("/").split("::")[0]
        # e.g. `BENCH_serving.json → quantized_pool` style spans
        ref = ref.split(" ")[0].split("→")[0].strip()
        if not ("/" in ref or ref.endswith(SUFFIXES)):
            continue
        if "*" in ref:
            continue                      # glob pattern, not a single file
        # try: relative to the doc, repo root, src/ and src/repro/ (the
        # narrative docs use `serving/kv_cache.py`-style module shorthand),
        # kernels/ for the kernel packages (`fused_score/kernel.py`), and
        # launch/ for bare entrypoint names
        roots = (base, ROOT, os.path.join(ROOT, "src"),
                 os.path.join(ROOT, "src", "repro"),
                 os.path.join(ROOT, "src", "repro", "kernels"),
                 os.path.join(ROOT, "src", "repro", "launch"))
        if not any(os.path.exists(os.path.normpath(os.path.join(r, ref)))
                   for r in roots):
            missing.append((doc, ref))
    return missing


#: `--profile fke` / `--profile all|fke` style mentions in the bench README
_PROFILE_REF = re.compile(r"--profile[=\s]+([A-Za-z0-9_|]+)")


def _registry_profiles() -> set:
    """AST-parse PROFILE_RUNNERS keys out of benchmarks/bench_serving.py
    (importing it would drag in jax; CI gates must stay cheap)."""
    path = os.path.join(ROOT, "benchmarks", "bench_serving.py")
    tree = ast.parse(open(path).read(), filename=path)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        names = [t.id for t in node.targets if isinstance(t, ast.Name)]
        if "PROFILE_RUNNERS" in names and isinstance(node.value, ast.Dict):
            return {k.value for k in node.value.keys
                    if isinstance(k, ast.Constant)}
    raise SystemExit("check_docs: PROFILE_RUNNERS dict not found in "
                     "benchmarks/bench_serving.py")


def check_profiles() -> list:
    """Every profile name benchmarks/README.md advertises must exist."""
    doc = "benchmarks/README.md"
    known = _registry_profiles() | {"all"}
    bad = []
    with open(os.path.join(ROOT, doc)) as f:
        text = f.read()
    for m in _PROFILE_REF.finditer(text):
        for name in m.group(1).split("|"):
            if name and name not in known:
                bad.append((doc, f"--profile {name} (registry has: "
                                 f"{', '.join(sorted(known))})"))
    return bad


def main() -> int:
    missing = []
    for doc in DOCS:
        if not os.path.exists(os.path.join(ROOT, doc)):
            missing.append(("<tree>", doc))
            continue
        missing.extend(check(doc))
    missing.extend(check_profiles())
    if missing:
        print("docs reference files that do not exist:")
        for doc, ref in missing:
            print(f"  {doc}: {ref}")
        return 1
    print(f"docs check OK ({', '.join(DOCS)}; bench profiles verified)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
