#!/usr/bin/env bash
# Tier-1 CI gate: flamecheck static analysis, the repo's own test suite,
# a docs-reference check, an end-to-end serving smoke run, and a PDA v2
# (quantized + incremental history pool) serve smoke.  Run from the repo
# root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== flamecheck: static analysis (strict) =="
python -m repro.analysis --strict

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== docs: reference check =="
python scripts/check_docs.py

echo "== smoke: examples/serve_e2e.py =="
python examples/serve_e2e.py

echo "== smoke: quantized + incremental history-KV pool =="
python -m repro.launch.serve --engine flame --history-cache \
    --incremental-history --pool-dtype int8 --pool-budget-mb 64 \
    --pool-slots 64 --users 4 --requests 12 --history 64 \
    --buckets 16,8 --counts 8,16 --d-model 64

echo "== smoke: FKE fused serving (impl=fused, int8 pool, drift cap) =="
python -m repro.launch.serve --engine flame --impl fused --history-cache \
    --incremental-history --extend-refresh-limit 4 --pool-dtype int8 \
    --pool-slots 64 --users 4 --requests 12 --history 64 \
    --buckets 16,8 --counts 8,16 --d-model 64

echo "== smoke: DSO v2 segment packing + deadline-aware flushing =="
python -m repro.launch.serve --engine flame --impl fused --history-cache \
    --pack-tails --deadline-ms 250 --distribution lognormal \
    --pool-slots 64 --users 4 --requests 12 --history 64 \
    --buckets 16 --counts 3,5,9,15 --d-model 64

echo "== smoke: generative top-k decode from pooled KV =="
python -m repro.launch.serve --engine flame --generate topk \
    --gen-steps 4 --beam-width 2 --pool-slots 64 --users 4 \
    --requests 12 --history 64 --buckets 16,8 --counts 8,16 --d-model 64

echo "== smoke: fused generative decode (impl=fused, int8 pool) =="
python -m repro.launch.serve --engine flame --generate topk --impl fused \
    --pack-tails --pool-dtype int8 --gen-steps 4 --beam-width 2 \
    --pool-slots 64 --users 4 --requests 12 --history 64 \
    --buckets 16,8 --counts 8,16 --d-model 64

echo "== smoke: chaos serving (fault injection, shed, degrade, watchdog) =="
python -m repro.launch.serve --engine flame --history-cache \
    --fault-spec "dispatch:0.2,stall:0.1:0.005,evict:0.15" --fault-seed 7 \
    --shed-policy tiered --slo-tier-defaults \
    "interactive=250,standard=1500,bulk=10000" \
    --slo-mix "interactive=0.3,standard=0.4,bulk=0.3" --degrade 50 \
    --watchdog-grace-ms 2000 --distribution lognormal \
    --pool-slots 64 --users 4 --requests 16 --history 64 \
    --buckets 16,8 --counts 8,16 --d-model 64

echo "== smoke: mesh-sharded serving (forced 4-device host mesh, 2x2) =="
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
python -m repro.launch.serve --engine flame --history-cache --mesh 2,2 \
    --pool-slots 64 --users 4 --requests 12 --history 64 \
    --buckets 16,8 --counts 8,16 --d-model 64

echo "== bench gate: FKE vs chunked (1.3x multi-core, parity 1-core) =="
python -m benchmarks.bench_serving --profile fke

echo "== bench gate: DSO v2 packing >= 1.2x coalescing on zipf traffic =="
python -m benchmarks.bench_serving --profile dso_nonuniform

echo "== bench gate: sharded parity + per-shard pool split (4-dev mesh) =="
python -m benchmarks.bench_serving --profile sharded

echo "== bench gate: packed decode bitwise + gen-tokens/s vs unpacked =="
python -m benchmarks.bench_serving --profile decode

echo "== bench gate: fused decode parity + speedup + zero reroutes =="
python -m benchmarks.bench_serving --profile decode_fused

echo "== bench gate: EDF goodput-under-SLO vs FIFO + chaos liveness =="
python -m benchmarks.bench_serving --profile overload

echo "CI OK"
