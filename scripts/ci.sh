#!/usr/bin/env bash
# Tier-1 CI gate: the repo's own test suite plus an end-to-end serving
# smoke run.  Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: examples/serve_e2e.py =="
python examples/serve_e2e.py

echo "CI OK"
