"""End-to-end behaviour of the FLAME system (paper pipeline composed)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import GRInteractionDataset, make_batch_iterator
from repro.models import build_model
from repro.serving import FlameEngine
from repro.serving.scheduler import TrafficConfig, generate_traffic, run_workload
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig
from repro.types import ClimberConfig


@pytest.fixture(scope="module")
def trained_climber():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=5_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    ds = GRInteractionDataset(n_items=5_000, n_users=500, seed=0)
    it = make_batch_iterator(ds, 16, n_history=32, n_candidates=8)
    params, _, hist = train(bundle, it, 30, AdamWConfig(lr=3e-3,
                                                        warmup_steps=5),
                            log_every=30, impl="reference")
    return cfg, bundle, params, ds, hist


def test_train_then_serve_pipeline(trained_climber):
    """Train Climber on synthetic interactions, then serve it through the
    full PDA->DSO->FKE pipeline under mixed traffic."""
    cfg, bundle, params, ds, hist = trained_climber
    assert hist[-1]["loss"] < hist[0]["loss"]

    eng = FlameEngine(bundle, params, n_history=32, buckets=(32, 16, 8),
                      n_streams=2)
    tc = TrafficConfig(n_requests=12, n_history=32,
                       candidate_counts=(8, 16, 24), distribution="jittered",
                       seed=1)
    reqs = generate_traffic(tc, n_items=5_000)
    res = run_workload(lambda h, c: eng.serve(h, c), reqs, concurrency=3)
    assert res["requests"] == 12
    assert res["throughput_items_per_s"] > 0
    summary = eng.metrics()
    assert summary["requests"] == 12
    assert summary["p99_latency_ms"] >= summary["mean_latency_ms"] * 0.5
    eng.shutdown()


def test_served_scores_track_planted_preferences(trained_climber):
    """After training, candidates the generator marks positive should score
    higher on average than negatives — the system serves *useful* results."""
    cfg, bundle, params, ds, _ = trained_climber
    rng = np.random.default_rng(7)
    pos, neg = [], []
    for _ in range(40):
        r = ds.sample_request(rng, 32, 8)
        batch = {k: jnp.asarray(v)[None] for k, v in r.items()
                 if k in ("history", "candidates", "side")}
        scores = np.asarray(bundle.prefill(params, batch))[0]   # [M,T]
        lab = r["labels"]
        pos.extend(scores[lab[:, 0] > 0.5, 0].tolist())
        neg.extend(scores[lab[:, 0] < 0.5, 0].tolist())
    assert np.mean(pos) > np.mean(neg)


def test_dryrun_machinery_importable():
    """dryrun helpers are unit-testable without 512 devices (the module-level
    XLA flag only matters when dryrun is __main__ before jax init)."""
    from repro.launch.dryrun import _with_layers, should_skip
    from repro.configs import get_shape
    cfg = get_config("qwen2-72b")
    assert should_skip(cfg, get_shape("long_500k")) is not None
    assert should_skip(cfg, get_shape("train_4k")) is None
    assert should_skip(get_config("rwkv6-7b"), get_shape("long_500k")) is None
    c1 = _with_layers(cfg, 1)
    assert c1.n_layers == 1
    cg = _with_layers(get_config("gemma3-12b"), 2)
    assert cg.n_layers == 12      # 2 x period-6 pattern
