"""Flash-decode Pallas kernel: shape/dtype sweep vs oracle + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import ops, ref

CASES = [
    (2, 4, 2, 512, 64, 0, 128),
    (1, 8, 8, 300, 64, 0, 64),
    (2, 4, 1, 512, 128, 100, 128),   # GQA 4:1 + sliding window
    (3, 2, 2, 256, 96, 0, 64),       # lane-padded head dim
]


@pytest.mark.parametrize("case", CASES, ids=[f"s{c[3]}d{c[4]}w{c[5]}" for c in CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vs_oracle(case, dtype):
    b, h, hkv, s, d, w, bk = case
    ks = jax.random.split(jax.random.key(s + d), 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    lens = jax.random.randint(ks[3], (b,), max(1, w + 1), s + 1)
    out = ops.flash_decode(q, kc, vc, lens, window=w, bk=bk)
    exp = ref.reference(q, kc, vc, lens, window=w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_flash_decode_ignores_past_length():
    """Cache contents beyond `length` must not affect the output (the
    block-skipping property that makes HBM traffic scale with the valid
    prefix)."""
    b, h, s, d = 2, 2, 256, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, s, h, d))
    vc = jax.random.normal(ks[2], (b, s, h, d))
    lens = jnp.array([100, 180])
    out1 = ops.flash_decode(q, kc, vc, lens, bk=64)
    kc2 = kc.at[:, 200:].set(1e4)
    vc2 = vc.at[:, 200:].set(-1e4)
    out2 = ops.flash_decode(q, kc2, vc2, lens, bk=64)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_flash_decode_matches_model_decode_attention():
    """Same semantics as the model's jnp decode path (uniform lengths)."""
    from repro.models.attention import decode_attention
    b, h, hkv, s, d = 2, 4, 2, 128, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    cur = 77
    jnp_out = decode_attention(q, kc, vc, cur_len=cur)
    pl_out = ops.flash_decode(q[:, 0], kc, vc, jnp.full((b,), cur), bk=64)
    np.testing.assert_allclose(np.asarray(jnp_out[:, 0]), np.asarray(pl_out),
                               atol=2e-5, rtol=2e-5)


def test_model_decode_step_pallas_impl():
    """decode_step(impl='pallas') routes through the flash-decode kernel and
    matches the reference decode path end to end."""
    from repro.configs import reduced_config
    from repro.models import build_model
    cfg = reduced_config("qwen2-72b")   # global-attention arch (non-ring)
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    B, S = 2, 48
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    caches, _ = bundle.cache_init(B, S + 4)
    _, c2 = bundle.prefill(params, {"tokens": toks}, caches=caches,
                           impl="reference")
    nt = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
    step = {"tokens": nt, "cur_index": jnp.int32(S)}
    ref_out, _ = bundle.decode_step(params, c2, step, impl="reference")
    pal_out, _ = bundle.decode_step(params, c2, step, impl="pallas")
    np.testing.assert_allclose(np.asarray(ref_out, np.float32),
                               np.asarray(pal_out, np.float32),
                               atol=3e-2, rtol=3e-2)
