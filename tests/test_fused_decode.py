"""FKE v2 (ISSUE 10): fused generative decode test suite.

Layers of coverage:

  1. op level — ``fused_decode_attention`` (jnp fast path + Pallas kernel
     in interpret mode) against the fp32 ``fused_score/ref.py::
     decode_reference`` oracle: int8/native stored operands, dedup
     row-index, ragged per-row lengths including zero-length rows, and
     universes smaller than one q block;
  2. root identity — decode at zero generated tokens (``lengths == S``)
     is BITWISE the fused cached scoring it generalizes, at the op level
     and through ``decode_logits`` on raw int8 pool views (padded beam
     caches included: masked slots get exact-zero weight);
  3. in-epilogue quantize — a jitted ``quantize_kv_graph`` emits codes
     and scales bitwise identical to the post-hoc ``quantize_kv`` of the
     same values, for int8 and bf16 pools;
  4. packed dispatch alignment — ``SegmentPacker(align=8)`` starts every
     segment on an 8-multiple (fuzzed: no align-sized block ever mixes
     two segments), ``align=1`` reproduces the legacy first-fit layouts
     exactly, ``set_packed_alignment`` validates its contract, and a 2-D
     seg index dispatched under a declared alignment takes the auto path
     with ZERO ``packed_kernel_reroutes``;
  5. engine level — fused generative decode (mixed top-k/beam) reproduces
     the chunked engine token for token on a native pool; the packed
     fused engine reproduces the unpacked fused engine on an int8 pool
     with zero kernel reroutes; EOS finishes sequences early against a
     truncation oracle (``gen_early_exits``); beam width wider than the
     universe; all-zero histories exercise the int8 scale-underflow floor.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import climber as C
from repro.core.dso import SegmentPacker
from repro.core.pda import RemoteFeatureStore
from repro.kernels.fused_score import ops as fs_ops
from repro.kernels.fused_score import ref as fs_ref
from repro.models import build_model
from repro.serving import FlameEngine
from repro.serving.api import BeamConfig, TopKConfig
from repro.serving.kv_cache import (quantize_kv, quantize_kv_graph,
                                    quantize_leaf, raw_kv_view)
from repro.serving.scheduler import run_workload_async
from repro.types import ClimberConfig

TOL = 2e-5
QTOL = 2e-2
N_HIST = 16
VOCAB = 64


def _mk(seed, b, m, h, hkv, d, s, u=None):
    ks = jax.random.split(jax.random.key(seed), 6)
    u = b if u is None else u
    return dict(
        q=jax.random.normal(ks[0], (b, m, h, d)),
        k_hist=jax.random.normal(ks[1], (u, s, hkv, d)),
        v_hist=jax.random.normal(ks[2], (u, s, hkv, d)),
        k_cand=jax.random.normal(ks[3], (b, m, hkv, d)),
        v_cand=jax.random.normal(ks[4], (b, m, hkv, d)),
    )


def _quant(t, dtype):
    if dtype == "native":
        return dict(t, k_scale=None, v_scale=None), TOL
    qk = quantize_leaf(t["k_hist"], dtype)
    qv = quantize_leaf(t["v_hist"], dtype)
    return dict(t, k_hist=qk.q, v_hist=qv.q, k_scale=qk.scale,
                v_scale=qv.scale), (QTOL if dtype == "int8" else TOL)


# ---------------------------------------------------------------------------
# 1. op-level parity vs the fp32 decode oracle
# ---------------------------------------------------------------------------

DEC_CASES = [
    # b, m, h, hkv, d, s, u, idx?, dtype
    (2, 8, 2, 2, 16, 24, None, False, "native"),
    (3, 12, 4, 2, 16, 37, 2, True, "int8"),      # ragged + dedup idx
    (2, 5, 4, 2, 16, 9, None, False, "int8"),    # gqa, tiny history
    (1, 1, 2, 2, 32, 8, None, False, "native"),  # universe < one q block
]
_IDS = [f"{c[8]}-s{c[5]}-m{c[1]}" + ("-idx" if c[7] else "")
        for c in DEC_CASES]


@pytest.mark.parametrize("case", DEC_CASES, ids=_IDS)
@pytest.mark.parametrize("path", ["jnp", "kernel"])
def test_decode_op_parity(case, path):
    """Ragged per-row lengths (a zero-length row included) over stored
    operands, both formulations, vs the dequantize-everything oracle."""
    b, m, h, hkv, d, s, u, use_idx, dtype = case
    t = _mk(b * 77 + s, b, m, h, hkv, d, s, u)
    t, tol = _quant(t, dtype)
    rng = np.random.default_rng(b + s)
    lengths = rng.integers(0, s + 1, u or b).astype(np.int32)
    lengths[0] = 0                                  # an empty-history row
    idx = jnp.asarray(rng.integers(0, u or b, b), jnp.int32) \
        if use_idx else None
    ref = fs_ref.decode_reference(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"], lengths,
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx,
        kv_dtype=jnp.float32)
    got = fs_ops.fused_decode_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"], lengths,
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx,
        path=path)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("path", ["jnp", "kernel"])
def test_decode_zero_lengths_is_self_only(path):
    """All-zero lengths mask the whole history: softmax collapses onto the
    candidate's self logit, so the output IS v_cand (cast to q dtype)."""
    t = _mk(11, b=2, m=6, h=2, hkv=2, d=16, s=16)
    lengths = np.zeros(2, np.int32)
    got = fs_ops.fused_decode_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"], lengths,
        path=path)
    b, m, hkv, d = t["v_cand"].shape
    g = t["q"].shape[2] // hkv
    want = jnp.broadcast_to(t["v_cand"][:, :, :, None, :],
                            (b, m, hkv, g, d)).reshape(b, m, hkv * g, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("path", ["jnp", "kernel"])
def test_decode_root_identity_bitwise(path):
    """Decode at zero generated tokens (lengths == S) is the fused cached
    scoring it generalizes on the same stored int8 operands — BITWISE on
    the kernel path (an everywhere-true mask is arithmetic identity inside
    one kernel body); the jnp twin traces a different graph for the masked
    form and XLA's CPU fusion reassociates the dot at 1 ulp, so it gates
    at float-ulp tolerance instead."""
    t = _mk(21, b=2, m=10, h=2, hkv=2, d=16, s=24, u=3)
    t, _ = _quant(t, "int8")
    idx = jnp.asarray([2, 0], jnp.int32)
    lengths = np.full(3, 24, np.int32)
    score = fs_ops.fused_cached_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx, path=path)
    dec = fs_ops.fused_decode_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"], lengths,
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx, path=path)
    if path == "kernel":
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(score))
    else:
        np.testing.assert_allclose(np.asarray(dec), np.asarray(score),
                                   atol=2e-6, rtol=0)


@pytest.mark.parametrize("path", ["jnp", "kernel"])
@pytest.mark.parametrize("fill", [0.0, 2.5])
def test_decode_int8_scale_underflow_all_equal_rows(path, fill):
    """All-equal (and all-zero) history rows: the absmax scale hits its
    1e-8 floor (or a constant), quantization must not divide by zero and
    the masked softmax must stay finite and match the oracle."""
    b, m, h, hkv, d, s = 2, 4, 2, 2, 16, 16
    t = _mk(31, b, m, h, hkv, d, s)
    t["k_hist"] = jnp.full((b, s, hkv, d), fill)
    t["v_hist"] = jnp.full((b, s, hkv, d), fill)
    t, _ = _quant(t, "int8")
    lengths = np.asarray([s, 3], np.int32)
    ref = fs_ref.decode_reference(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"], lengths,
        k_scale=t["k_scale"], v_scale=t["v_scale"], kv_dtype=jnp.float32)
    got = fs_ops.fused_decode_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"], lengths,
        k_scale=t["k_scale"], v_scale=t["v_scale"], path=path)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=QTOL, rtol=QTOL)


# ---------------------------------------------------------------------------
# 2. model-level root identity on raw pool views
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def climber_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=VOCAB, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    ks = jax.random.split(jax.random.key(1), 3)
    batch = {"history": jax.random.randint(ks[0], (1, N_HIST), 0, VOCAB),
             "side": jax.random.normal(ks[2], (1, 12))}
    return cfg, bundle, params, batch


def _s0(cfg):
    return N_HIST // cfg.climber.num_blocks + 1


def _pad_raw(kv, extra: int):
    """Pad raw-view value leaves (NOT trailing-singleton scale leaves) by
    ``extra`` sequence slots with junk, as the engine's beam caches do."""
    return jax.tree.map(
        lambda a: a if a.shape[-1] == 1 else jnp.pad(
            a, [(0, 0), (0, 0), (0, extra), (0, 0), (0, 0)],
            constant_values=3), kv)


def test_decode_logits_root_bitwise_vs_score(climber_setup):
    """Through the model surface on raw int8 views: decode at the root
    length equals fused score_candidates bitwise, and the PADDED beam
    cache (junk in the masked slots) decodes bitwise like the tight one."""
    cfg, bundle, params, batch = climber_setup
    kv = C.encode_history(params, batch, cfg, impl="reference")
    raw = raw_kv_view(quantize_kv(kv, "int8")[0])
    cand = jax.random.randint(jax.random.key(7), (1, 8), 0, VOCAB)
    lengths = np.asarray([_s0(cfg)], np.int32)
    want = np.asarray(bundle.score_candidates(params, raw, cand,
                                              impl="fused"))
    got = np.asarray(bundle.decode_logits(params, raw, cand, lengths,
                                          impl="fused"))
    np.testing.assert_array_equal(got, want)
    padded = np.asarray(bundle.decode_logits(params, _pad_raw(raw, 5), cand,
                                             lengths, impl="fused"))
    np.testing.assert_array_equal(padded, want)


def test_append_token_raw_keeps_root_scales(climber_setup):
    """append_token on a raw int8 beam cache scatters the new token's
    QUANTIZED K/V into the padded value leaves while the root scale leaves
    pass through untouched (object-level: same shape, same values)."""
    cfg, bundle, params, batch = climber_setup
    kv = C.encode_history(params, batch, cfg, impl="reference")
    raw = _pad_raw(raw_kv_view(quantize_kv(kv, "int8")[0]), 3)
    lengths = np.asarray([_s0(cfg)], np.int32)
    grown = bundle.append_token(params, raw, np.asarray([[5]], np.int32),
                                lengths, impl="fused")
    assert jax.tree.structure(grown) == jax.tree.structure(raw)
    for a, b in zip(jax.tree.leaves(raw), jax.tree.leaves(grown)):
        assert a.shape == b.shape and a.dtype == b.dtype
        if a.shape[-1] == 1:                       # scale leaf: frozen
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:                                      # values: int8 stays int8
            assert b.dtype == jnp.int8


# ---------------------------------------------------------------------------
# 3. in-epilogue quantize == post-hoc quantize_kv, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["int8", "bf16", "native"])
def test_quantize_kv_graph_bitwise(dtype):
    """The jitted in-graph quantizer (the fused encode/extend epilogue)
    emits exactly the raw view of quantize_kv: same tree structure, every
    code and every scale bitwise identical."""
    ks = jax.random.split(jax.random.key(3), 4)
    kv = {"b0": {"k": jax.random.normal(ks[0], (2, 2, 9, 2, 16)) * 3.0,
                 "v": jax.random.normal(ks[1], (2, 2, 9, 2, 16))},
          "b1": {"k": jax.random.normal(ks[2], (2, 2, 9, 2, 16)) * 1e-6,
                 "v": jnp.zeros((2, 2, 9, 2, 16))}}   # underflow floor arm
    want = raw_kv_view(quantize_kv(kv, dtype)[0])
    got = jax.jit(lambda t: quantize_kv_graph(t, dtype))(kv)
    assert jax.tree.structure(got) == jax.tree.structure(want)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# 4. packer alignment + dispatch-path contract
# ---------------------------------------------------------------------------

def test_segment_packer_alignment_fuzz():
    """align=8: every accepted offset is an 8-multiple and no 8-slot block
    ever holds candidates of two different segments (the fused kernel's
    per-q-block index-sampling contract, with bq == align)."""
    rng = np.random.default_rng(0)
    for trial in range(50):
        bucket = int(rng.choice([16, 24, 32]))
        p = SegmentPacker(bucket, max_rows=4, max_kv=6, align=8)
        rows = {}                                  # row -> slot -> seg id
        for seg in range(20):
            valid = int(rng.integers(1, bucket + 1))
            place = p.try_add(valid, ident=("u", seg % 5))
            if place is None:
                continue
            row, off, _ = place
            assert off % 8 == 0, (trial, seg, place)
            assert off + valid <= bucket
            for c in range(off, off + valid):
                assert c not in rows.setdefault(row, {}), "overlap"
                rows[row][c] = seg
        for row, cols in rows.items():
            for blk in range(0, bucket, 8):
                segs = {cols[c] for c in range(blk, min(blk + 8, bucket))
                        if c in cols}
                assert len(segs) <= 1, (trial, row, blk, segs)


def test_segment_packer_align1_is_legacy_first_fit():
    """align=1 must reproduce the pre-FKE-v2 layouts exactly: first-fit
    with no rounding (the non-fused packed families stay bitwise)."""
    rng = np.random.default_rng(1)
    p = SegmentPacker(16, max_rows=3, max_kv=32, align=1)
    fills = []
    for seg in range(40):
        valid = int(rng.integers(1, 17))
        got = p.try_add(valid, ident=seg)
        row = next((i for i, f in enumerate(fills) if f + valid <= 16), None)
        if row is None and len(fills) < 3:
            row = len(fills)
            fills.append(0)
        if row is None:
            assert got is None
            continue
        assert got is not None and got[0] == row and got[1] == fills[row]
        fills[row] += valid
    assert p.is_full() == all(f >= 16 for f in fills) and len(fills) == 3


def test_set_packed_alignment_contract():
    prev = fs_ops.set_packed_alignment(0)
    try:
        assert fs_ops.packed_alignment() == 0
        assert fs_ops.set_packed_alignment(8) == 0
        assert fs_ops.packed_alignment() == 8
        assert fs_ops.set_packed_alignment(16) == 8
        for bad in (4, -8, 7, 1):
            with pytest.raises(ValueError):
                fs_ops.set_packed_alignment(bad)
        assert fs_ops.packed_alignment() == 16
    finally:
        fs_ops.set_packed_alignment(prev)


def test_packed_2d_auto_path_no_reroute():
    """With the alignment contract declared, a 2-D seg index on path="auto"
    dispatches without counting a kernel->jnp reroute; without it, the
    legacy reroute (and its counter) is preserved."""
    t = _mk(41, b=2, m=16, h=2, hkv=2, d=16, s=24, u=3)
    idx2 = jnp.asarray([[2] * 8 + [0] * 8, [1] * 8 + [2] * 8], jnp.int32)
    # cached_reference has no 2-D gather; the jnp formulation (validated
    # against it on 1-D indices above and in test_fke) is the oracle here
    ref = fs_ops.fused_cached_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
        row_index=idx2, path="jnp")
    prev = fs_ops.set_packed_alignment(8)
    try:
        before = fs_ops.packed_reroute_count()
        got = fs_ops.fused_cached_attention(
            t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
            row_index=idx2, path="auto")
        assert fs_ops.packed_reroute_count() == before, \
            "aligned 2-D dispatch must not count a reroute"
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=TOL, rtol=TOL)
        # the declared alignment also sizes bq for the explicit kernel path
        gk = fs_ops.fused_cached_attention(
            t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
            row_index=idx2, path="kernel")
        np.testing.assert_allclose(np.asarray(gk), np.asarray(ref),
                                   atol=TOL, rtol=TOL)
        fs_ops.set_packed_alignment(0)
        before = fs_ops.packed_reroute_count()
        fs_ops.fused_cached_attention(
            t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
            row_index=idx2, path="auto")
        assert fs_ops.packed_reroute_count() == before + 1
    finally:
        fs_ops.set_packed_alignment(prev)


# ---------------------------------------------------------------------------
# 5. engine level
# ---------------------------------------------------------------------------

def _engine(bundle, params, **kw):
    base = dict(n_history=N_HIST, buckets=(8, 4), n_streams=2,
                feature_mode="off",
                store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                window_s=0.01, max_batch=4, n_workers=4,
                history_cache=True, pool_slots=32,
                generate=6, gen_vocab=16)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


@pytest.fixture(scope="module")
def engines(climber_setup):
    cfg, bundle, params, _ = climber_setup
    chunked = _engine(bundle, params, impl="chunked")
    fused = _engine(bundle, params, impl="fused")
    fused8 = _engine(bundle, params, impl="fused", pool_dtype="int8")
    fused8p = _engine(bundle, params, impl="fused", pool_dtype="int8",
                      pack_tails=True)
    yield chunked, fused, fused8, fused8p
    for e in (chunked, fused, fused8, fused8p):
        e.shutdown()


def _requests(n, seed=0, steps=4):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        m = int(rng.integers(3, 12))
        reqs.append({
            "history": rng.integers(0, VOCAB, N_HIST).astype(np.int32),
            "candidates": rng.integers(0, VOCAB, m).astype(np.int32),
            "user_id": int(i),
            "generate": (TopKConfig(k=2, steps=steps) if i % 2 else
                         BeamConfig(width=3, steps=steps)),
        })
    return reqs


def test_fused_generate_matches_chunked_token_for_token(engines):
    """Native pool: both engines run exact f32 math over the same stored
    values, so fused top-k and beam sequences must reproduce the chunked
    engine's token for token (the ISSUE's end-to-end sequence oracle)."""
    chunked, fused, _, _ = engines
    for r in _requests(6, seed=2):
        want = chunked.serve(r["history"], candidates=r["candidates"],
                             user_id=r["user_id"], generate=r["generate"])
        got = fused.serve(r["history"], candidates=r["candidates"],
                          user_id=r["user_id"], generate=r["generate"])
        np.testing.assert_array_equal(got, want)
    m = fused.metrics()
    assert m["decode_steps"] > 0 and m["gen_tokens"] > 0


def test_packed_fused_decode_equals_unpacked_zero_reroutes(engines):
    """int8 pool: concurrent segment-packed fused decode emits bitwise the
    unpacked fused engine's sequences, packs real segments, and never
    reroutes a packed kernel dispatch to the jnp formulation (the bq
    alignment contract holds end to end)."""
    _, _, fused8, fused8p = engines
    assert fused8p._pack_align == 8
    reqs = _requests(6, seed=3)
    want = [fused8.serve(r["history"], candidates=r["candidates"],
                         user_id=r["user_id"], generate=r["generate"])
            for r in reqs]
    res = run_workload_async(fused8p, reqs)
    for got, exp in zip(res["outputs"], want):
        np.testing.assert_array_equal(got, exp)
    m = fused8p.metrics()
    assert m["dso_packed_segments"] > 0
    assert m.get("packed_kernel_reroutes", 0) == 0
    # plain candidate scoring through the same packed fused engine too:
    # the packed layout traces a different graph shape for tail chunks, so
    # XLA refuses bitwise here (2.4e-4, pre-existing, an order under the
    # int8 envelope) — the token sequences above ARE bitwise
    r0 = reqs[0]
    a = fused8.serve(r0["history"], candidates=r0["candidates"], user_id=0)
    b = fused8p.serve(r0["history"], candidates=r0["candidates"], user_id=0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-3, rtol=0)
    assert fused8p.metrics().get("packed_kernel_reroutes", 0) == 0


def test_eos_early_exit_truncation_oracle(engines):
    """eos on TopKConfig: the greedy path is unchanged up to the first EOS
    (the no-eos run is the oracle), the row is -1-padded after it, and the
    skipped decode rounds are counted by gen_early_exits."""
    _, fused, _, _ = engines
    rng = np.random.default_rng(11)   # greedy seq [32,32,9,9,55]: EOS=9
    hist = rng.integers(0, VOCAB, N_HIST).astype(np.int32)
    uni = rng.integers(0, VOCAB, 9).astype(np.int32)
    free = fused.serve(hist, candidates=uni, user_id=500,
                       generate=TopKConfig(k=1, steps=5))
    assert (free[0] >= 0).all()
    # EOS must be a token whose FIRST occurrence is mid-sequence, else the
    # run legitimately finishes at that earlier step
    p = next(i for i in range(1, 4)
             if int(free[0][i]) not in [int(x) for x in free[0][:i]])
    eos = int(free[0][p])
    before = fused.metrics().get("gen_early_exits", 0)
    out = fused.serve(hist, candidates=uni, user_id=500,
                      generate=TopKConfig(k=1, steps=5, eos=eos))
    np.testing.assert_array_equal(out[0][:p + 1], free[0][:p + 1])
    assert (out[0][p + 1:] == -1).all(), out
    assert fused.metrics()["gen_early_exits"] == before + 1
    # beam mode through the same eos plumbing still resolves
    bout = fused.serve(hist, candidates=uni, user_id=501,
                       generate=BeamConfig(width=2, steps=4, eos=eos))
    assert bout.shape == (2, 4)


def test_beam_wider_than_universe(engines):
    """Beam search may run wider than the universe (hypotheses multiply
    V-fold per step); fused and chunked agree on a native pool."""
    chunked, fused, _, _ = engines
    rng = np.random.default_rng(13)
    hist = rng.integers(0, VOCAB, N_HIST).astype(np.int32)
    uni = np.asarray([4, 9, 31], np.int32)           # |universe| = 3
    gen = BeamConfig(width=6, steps=3)
    want = chunked.serve(hist, candidates=uni, user_id=600, generate=gen)
    got = fused.serve(hist, candidates=uni, user_id=600, generate=gen)
    assert got.shape == (6, 3)
    np.testing.assert_array_equal(got, want)


def test_fused_all_zero_history_generates_finite(engines):
    """An all-equal history drives every int8 scale toward one constant
    (and side features toward degenerate rows): generation must still
    resolve with valid tokens on the int8 fused engine."""
    _, _, fused8, _ = engines
    hist = np.zeros(N_HIST, np.int32)
    uni = np.asarray([1, 2, 3, 5, 8], np.int32)
    out = fused8.serve(hist, candidates=uni, user_id=700,
                       generate=TopKConfig(k=2, steps=3))
    assert out.shape == (2, 3)
    assert np.isin(out, uni).all()
