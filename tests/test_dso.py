"""DSO: bucket routing properties (hypothesis) + executor pool behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._propcheck import given, settings, st

from repro.core.dso import (Chunk, DynamicStreamOrchestrator, ExecutorPool,
                            ImplicitShapeEngine, padded_fraction,
                            split_request)

BUCKETS = st.lists(st.sampled_from([16, 32, 64, 128, 256, 512, 1024]),
                   min_size=1, max_size=5, unique=True)


@given(st.integers(1, 5000), BUCKETS)
@settings(max_examples=200, deadline=None)
def test_split_request_properties(m, buckets):
    plan = split_request(m, buckets)
    # 1. covers every candidate exactly once, in order
    assert plan[0].start == 0
    for a, b in zip(plan, plan[1:]):
        assert b.start == a.start + a.valid
    assert plan[-1].start + plan[-1].valid == m
    # 2. every chunk runs on a real bucket, valid <= bucket
    for c in plan:
        assert c.bucket in buckets and 1 <= c.valid <= c.bucket
    # 3. only the LAST chunk may be padded
    for c in plan[:-1]:
        assert c.valid == c.bucket
    # 4. greedy-descending: bucket sizes never increase along the plan
    sizes = [c.bucket for c in plan]
    assert sizes == sorted(sizes, reverse=True)
    # 5. padding bounded by smallest bucket
    pad = sum(c.bucket for c in plan) - m
    assert pad < min(buckets)


def test_padded_fraction():
    assert padded_fraction(128, [128]) == 0.0
    assert padded_fraction(1, [128]) > 0.99


def _build_pool(buckets, n_streams=2):
    def build_fn(bucket):
        def fn(x):
            return x * 2.0
        return jax.jit(fn).lower(
            jax.ShapeDtypeStruct((1, bucket), jnp.float32)).compile()
    return ExecutorPool(build_fn, buckets, n_streams=n_streams)


def test_executor_pool_checkout():
    pool = _build_pool([32, 16])
    e1 = pool.acquire(32)
    e2 = pool.acquire(32)
    assert e1.eid != e2.eid
    pool.release(e1)
    e3 = pool.acquire(32)
    assert e3.eid == e1.eid       # round-trips through the index queue


def test_orchestrator_end_to_end_matches_direct():
    pool = _build_pool([32, 16], n_streams=2)

    def pad_slice(request, chunk: Chunk):
        x, = request
        sl = x[:, chunk.start:chunk.start + chunk.valid]
        if chunk.valid < chunk.bucket:
            sl = jnp.pad(sl, ((0, 0), (0, chunk.bucket - chunk.valid)))
        return (sl,)

    def gather(results, chunks, m):
        return np.concatenate([np.asarray(r[:, :c.valid])
                               for r, c in zip(results, chunks)], axis=1)

    dso = DynamicStreamOrchestrator(pool, pad_slice, gather)
    for m in (7, 16, 33, 70, 100):
        x = jnp.arange(m, dtype=jnp.float32)[None]
        out = dso.score((x,), m)
        np.testing.assert_allclose(out, np.asarray(x) * 2.0)
        assert out.shape == (1, m)
    dso.shutdown()


def test_implicit_shape_engine_recompiles():
    eng = ImplicitShapeEngine(lambda x: x + 1.0)
    for m in (3, 5, 3, 7):
        out = eng.score((jnp.zeros((1, m)),), m)
        assert out.shape == (1, m)
    assert eng.compiles == 3     # 3 novel shapes


def test_concurrent_submissions():
    pool = _build_pool([16], n_streams=2)

    def pad_slice(request, chunk):
        x, = request
        sl = x[:, chunk.start:chunk.start + chunk.valid]
        if chunk.valid < chunk.bucket:
            sl = jnp.pad(sl, ((0, 0), (0, chunk.bucket - chunk.valid)))
        return (sl,)

    def gather(results, chunks, m):
        return np.concatenate([np.asarray(r[:, :c.valid])
                               for r, c in zip(results, chunks)], axis=1)

    dso = DynamicStreamOrchestrator(pool, pad_slice, gather, max_workers=8)
    xs = [jnp.full((1, 40), float(i)) for i in range(8)]
    futs = [dso.submit((x,), 40) for x in xs]
    for i, f in enumerate(futs):
        np.testing.assert_allclose(f.result(), np.full((1, 40), 2.0 * i))
    dso.shutdown()
