"""Mesh-sharded serving: rule resolution, per-shard pool budgets, and
end-to-end executor parity on a forced multi-device host mesh.

Layers of coverage:

  1. rule/spec unit tests — ``resolve_rules`` axis dropping,
     ``serving_rules``'s replicated ``cache_batch`` + CP fallback,
     ``logical_to_spec``'s per-dim divisibility fallback / used-axis dedup
     / trailing-``None`` trim, ``rules_for_shape``'s batch-ways flip, and
     the ``SERVING_KV_LEAF`` layout all executors and the pool share.
     These run against ``AbstractMesh`` so the main pytest process keeps
     its single device;
  2. ``CoalescePolicy`` mesh scaling — ``max_batch`` / ``pack_rows`` are
     per-device capacities, the compiled global axes scale by
     ``data_ways`` (which is also what keeps the per-device local shape —
     and hence XLA's kernel choice and FP reduction order — identical to
     a single-device engine);
  3. ``make_serving_mesh`` CLI resolution;
  4. subprocess (4 forced host devices) — data-parallel (4,1) serving is
     BITWISE identical to the single-device engine for reference and
     chunked impls over an int8 pool; a (2,2) tensor-parallel mesh agrees
     to f32-reassociation tolerance, halves the per-shard pool bytes, and
     no executor's compiled HLO contains a cross-shard reshard collective
     (all-to-all / collective-permute) on the steady-state hot path.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.core.dso import CoalescePolicy
from repro.launch.mesh import make_serving_mesh

try:
    from jax.sharding import AbstractMesh
except ImportError:                                    # pragma: no cover
    AbstractMesh = None

needs_abstract_mesh = pytest.mark.skipif(
    AbstractMesh is None, reason="jax.sharding.AbstractMesh unavailable")


def _amesh(shape, axes):
    # this jax version's AbstractMesh takes ((name, size), ...)
    return AbstractMesh(tuple(zip(axes, shape)))


# ---------------------------------------------------------------------------
# 1. rule / spec resolution
# ---------------------------------------------------------------------------

@needs_abstract_mesh
def test_resolve_rules_drops_missing_axes():
    mesh = _amesh((2, 2), ("data", "model"))
    rules = shd.resolve_rules(mesh)
    # 'pod' exists in DEFAULT_RULES targets but not on this mesh
    assert rules["batch"] == ("data",)
    assert rules["cache_batch"] == ("data",)
    assert rules["experts"] == ("data",)
    for axes in rules.values():
        assert all(a in ("data", "model") for a in axes)
    # a mesh WITH a pod axis keeps it, in rule order
    mesh3 = _amesh((2, 2, 2), ("pod", "data", "model"))
    assert shd.resolve_rules(mesh3)["batch"] == ("pod", "data")


@needs_abstract_mesh
def test_serving_rules_replicated_cache_batch_and_cp_fallback():
    mesh = _amesh((2, 2), ("data", "model"))
    # TP case: heads divide the model ways -> history length unsharded
    rules = shd.serving_rules(mesh, kv_heads=4)
    assert rules["batch"] == ("data",)
    assert rules["cache_batch"] == ()          # reshard-free dedup gather
    assert rules["cache_heads"] == ("model",)
    assert rules["cache_seq_shard"] == ()
    # CP fallback: 3 heads on a 2-way model axis cannot head-shard
    rules = shd.serving_rules(mesh, kv_heads=3)
    assert rules["cache_seq_shard"] == ("model",)
    # no model axis at all -> no fallback either
    rules = shd.serving_rules(_amesh((4,), ("data",)), kv_heads=3)
    assert rules["cache_seq_shard"] == ()
    assert rules["cache_heads"] == ()
    # unknown head count: stay on the TP layout
    assert shd.serving_rules(mesh)["cache_seq_shard"] == ()


@needs_abstract_mesh
def test_logical_to_spec_divisibility_fallback():
    mesh = _amesh((2, 2), ("data", "model"))
    rules = shd.serving_rules(mesh, kv_heads=4)
    # [U, L, S, Hkv, D] with Hkv divisible -> heads take the model axis
    spec = shd.logical_to_spec(shd.SERVING_KV_LEAF, (3, 2, 33, 4, 16),
                               mesh, rules)
    assert spec == P(None, None, None, "model")
    # Hkv NOT divisible by the model ways -> dropped (replicated), and the
    # trailing-None trim leaves an empty spec
    spec = shd.logical_to_spec(shd.SERVING_KV_LEAF, (3, 2, 33, 3, 16),
                               mesh, rules)
    assert spec == P()
    # int8 scale leaf [U, L, 1, Hkv, 1] under the CP-fallback rules: the
    # size-1 sequence dim cannot take the model axis
    cp = shd.serving_rules(mesh, kv_heads=3)
    assert shd.logical_to_spec(shd.SERVING_KV_LEAF, (3, 2, 1, 3, 1),
                               mesh, cp) == P()
    # ... while the value leaf's even history length can
    assert shd.logical_to_spec(shd.SERVING_KV_LEAF, (3, 2, 64, 3, 16),
                               mesh, cp) == P(None, None, "model")


@needs_abstract_mesh
def test_logical_to_spec_used_axis_dedup_and_compose():
    mesh = _amesh((2, 2), ("data", "model"))
    # one mesh axis is spent on the first logical dim that claims it
    spec = shd.logical_to_spec(("batch", "seq_shard"), (4, 8), mesh)
    assert spec == P("data")
    # multi-axis compose: a rule listing two axes takes both when both
    # divide, as a tuple entry
    rules = dict(shd.resolve_rules(mesh))
    rules["tokens"] = ("data", "model")
    assert shd.logical_to_spec(("tokens",), (8,), mesh, rules) \
        == P(("data", "model"))
    # ... and only the dividing prefix when the dim is odd after one split
    assert shd.logical_to_spec(("tokens",), (6,), mesh, rules) == P("data")


@needs_abstract_mesh
def test_rules_for_shape_batch_ways_flip():
    mesh = _amesh((2, 2), ("data", "model"))
    # plenty of batch: default rules, fsdp shards embed over data
    rules = shd.rules_for_shape(mesh, global_batch=8)
    assert rules["cache_seq"] == () and rules["seq"] == ()
    assert rules["embed"] == ("data",)
    # batch-1 workload: the unshardable batch axis hands data (and model)
    # to the sequence axes instead
    rules = shd.rules_for_shape(mesh, global_batch=1)
    assert rules["cache_seq"] == ("data", "model")
    assert rules["seq"] == ("data",)
    assert shd.rules_for_shape(mesh, global_batch=8, fsdp=False)["embed"] \
        == ()


# ---------------------------------------------------------------------------
# 2. mesh-aware coalescing capacity
# ---------------------------------------------------------------------------

def test_coalesce_policy_scales_per_device_capacity():
    pol = CoalescePolicy(max_batch=4, data_ways=4)
    assert pol.batch == 16 and pol.rows == 16
    pol = CoalescePolicy(max_batch=4, pack_rows=2, data_ways=4)
    assert pol.batch == 16 and pol.rows == 8
    # no mesh: unchanged single-device semantics
    pol = CoalescePolicy(max_batch=4)
    assert pol.batch == 4 and pol.rows == 4
    assert CoalescePolicy(enabled=False, max_batch=4, data_ways=4).batch == 1
    with pytest.raises(ValueError):
        CoalescePolicy(data_ways=0)


# ---------------------------------------------------------------------------
# 3. CLI mesh resolution
# ---------------------------------------------------------------------------

def test_make_serving_mesh():
    assert make_serving_mesh("", 0) is None
    mesh = make_serving_mesh("1,1")
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    assert make_serving_mesh(model_parallel=1).shape["model"] == 1
    with pytest.raises(ValueError):
        make_serving_mesh("4")
    with pytest.raises(ValueError):
        make_serving_mesh("2,0")


# ---------------------------------------------------------------------------
# 4. forced multi-device end-to-end parity
# ---------------------------------------------------------------------------

SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import dataclasses, numpy as np, jax
from repro.configs import get_config
from repro.models import build_model
from repro.types import ClimberConfig
from repro.launch.mesh import make_serving_mesh
from repro.serving import create_engine

cfg = dataclasses.replace(get_config("climber"), vocab_size=5000, d_model=64,
                          d_ff=256, n_heads=4, n_kv_heads=4, head_dim=16,
                          climber=ClimberConfig(num_blocks=2,
                                                layers_per_block=2))
bundle = build_model(cfg)
params, _ = bundle.init(jax.random.key(0))


def run(mesh, impl, dtype):
    eng = create_engine("flame", bundle, params, n_history=64, buckets=(16,),
                        history_cache=True, pool_slots=16, pool_dtype=dtype,
                        impl=impl, mesh=mesh)
    rr = np.random.default_rng(0)
    res = []
    for i in range(6):
        h = rr.integers(0, 5000, 64).astype(np.int32)
        c = rr.integers(0, 5000, 11).astype(np.int32)
        res.append(np.asarray(eng.serve(h, c, user_id=i % 2)))
    gauges = {k: v for k, v in eng.metrics().items() if "shard" in k}
    hlo = {kb: ex.as_text() for kb, ex in eng.dso.compiled.items()}
    eng.shutdown()
    return np.concatenate([r.ravel() for r in res]), gauges, hlo


RESHARD = ("all-to-all", "collective-permute")
# encode/extend may all-gather their OUTPUT: that is the one-time publish
# of fresh KV into the pool's replicated cache_batch layout.  The
# steady-state scoring kinds (cached/full) must stay reshard-free.
PUBLISH_KINDS = ("encode", "extend")

# data-parallel (4,1): bitwise vs single-device, scoring collective-free
for impl in ("reference", "chunked"):
    base, _, _ = run(None, impl, "int8")
    out, g, hlo = run(make_serving_mesh("4,1"), impl, "int8")
    assert np.array_equal(base, out), (impl, float(np.abs(base - out).max()))
    assert g.get("pool_shard_ways") == 1, g
    assert g.get("pool_bytes_shard0", 0) > 0, g
    assert g.get("pool_bytes_used_shard0", 0) == g["pool_bytes_shard0"], g
    for (kind, b), txt in hlo.items():
        ops = RESHARD if kind in PUBLISH_KINDS \
            else RESHARD + ("all-reduce", "all-gather")
        for op in ops:
            assert op not in txt, (impl, kind, b, op)

# tensor+data (2,2): f32-reassociation tolerance (the head-sharded
# out-projection all-reduces partial sums — reassociation, not a reshard
# — and the per-layer ~1e-7 drift compounds through the block stack),
# per-shard pool bytes halve, still no reshard collectives
base, _, _ = run(None, "chunked", "native")
out, g, hlo = run(make_serving_mesh("2,2"), "chunked", "native")
assert np.allclose(base, out, atol=5e-3), float(np.abs(base - out).max())
assert g.get("pool_shard_ways") == 2, g
assert g["pool_bytes_shard0"] == g["pool_bytes_shard1"] > 0, g
for kb, txt in hlo.items():
    for op in RESHARD:
        assert op not in txt, (kb, op)
print("OK")
"""


def test_sharded_serving_multi_device_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout


def test_single_device_mesh_engine_matches_no_mesh(climber_engine_pair):
    """A (1,1) mesh engine must be bitwise identical to a mesh-less one in
    the SAME process — the sharding plumbing (SDS in-shardings, eval_shape
    out-shardings, mesh_rules trace context) is a no-op at 1 way."""
    eng_plain, eng_mesh = climber_engine_pair
    rr = np.random.default_rng(7)
    for i in range(4):
        h = rr.integers(0, 5000, 64).astype(np.int32)
        c = rr.integers(0, 5000, 9).astype(np.int32)
        a = np.asarray(eng_plain.serve(h, c, user_id=i % 2))
        b = np.asarray(eng_mesh.serve(h, c, user_id=i % 2))
        np.testing.assert_array_equal(a, b)
    # mesh engine surfaces per-shard pool accounting even at 1 way
    m = eng_mesh.metrics()
    assert m.get("pool_shard_ways") == 1
    assert m.get("pool_bytes_shard0", 0) > 0


@pytest.fixture(scope="module")
def climber_engine_pair():
    import dataclasses

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import create_engine
    from repro.types import ClimberConfig

    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=5000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=16,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    kw = dict(n_history=64, buckets=(16,), history_cache=True,
              pool_slots=16, pool_dtype="int8", impl="chunked")
    eng_plain = create_engine("flame", bundle, params, **kw)
    eng_mesh = create_engine("flame", bundle, params,
                             mesh=make_serving_mesh("1,1"), **kw)
    yield eng_plain, eng_mesh
    eng_plain.shutdown()
    eng_mesh.shutdown()
