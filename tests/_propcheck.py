"""Property-test front-end: real hypothesis when installed, otherwise a tiny
deterministic fallback so the modules still collect and their core
assertions still run offline (the importorskip-style guard lives here, in
one place, instead of in every module).

The fallback implements only the strategy surface this repo's tests use —
``integers``, ``sampled_from``, ``lists``, ``tuples`` — and a ``given``
that replays the test body over a fixed number of seed-deterministic
examples (same draws every run, no shrinking)."""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as _np

    _FALLBACK_EXAMPLES = 25
    _SEED = 0xF1A3E

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    def _lists(elem, min_size=0, max_size=10, unique=False):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            if not unique:
                return [elem.example(rng) for _ in range(n)]
            out = []
            tries = 0
            while len(out) < n and tries < 50 * (n + 1):
                v = elem.example(rng)
                tries += 1
                if v not in out:
                    out.append(v)
            return out
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

    class _Strategies:
        integers = staticmethod(_integers)
        sampled_from = staticmethod(_sampled_from)
        lists = staticmethod(_lists)
        tuples = staticmethod(_tuples)

    st = _Strategies()

    def settings(**kw):
        def deco(fn):
            fn._prop_max_examples = kw.get("max_examples")
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: runner takes no params and carries no __wrapped__, so
            # pytest does not mistake the drawn arguments for fixtures.
            def runner():
                n = min(getattr(fn, "_prop_max_examples", None)
                        or _FALLBACK_EXAMPLES, _FALLBACK_EXAMPLES)
                for i in range(n):
                    rng = _np.random.default_rng(_SEED + i)
                    drawn = [s.example(rng) for s in strategies]
                    try:
                        fn(*drawn)
                    except BaseException:
                        print(f"[propcheck] falsifying example #{i}: {drawn}")
                        raise
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco
