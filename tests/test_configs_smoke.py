"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers / one compressed pattern period, d_model<=256, <=4 experts) runs a
real forward and a real train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.models import build_model
from repro.training.loop import make_train_step
from repro.training.optimizer import AdamWConfig, adamw_init

B, S = 2, 64


def _batch(cfg, key=1):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.modality == "vision":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(key + 1), (B, 16, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.key(key + 2), (B, 16, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_shapes(arch):
    cfg = reduced_config(arch)
    assert cfg.n_layers <= max(2, len(cfg.layer_pattern))
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    bundle = build_model(cfg)
    params, specs = bundle.init(jax.random.key(0))
    logits = bundle.prefill(params, _batch(cfg))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    opt_state = adamw_init(params)
    step = jax.jit(make_train_step(bundle, AdamWConfig(lr=1e-3)))
    params, opt_state, metrics = step(params, opt_state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # ~ln(vocab) at init (untrained); generous envelope
    assert 1.0 < loss < 2.5 * np.log(cfg.vocab_size)
    assert np.isfinite(float(metrics["grad_norm"]))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    }
    for arch, (L, d, h, kv, f, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers + c.n_enc_layers, c.d_model, c.n_heads,
                c.n_kv_heads, c.d_ff, c.vocab_size) == (L, d, h, kv, f, v), arch
    c = get_config("seamless-m4t-large-v2")
    assert c.n_layers + c.n_enc_layers == 24 and c.d_model == 1024
    assert c.vocab_size == 256206 and c.d_ff == 8192
    # MoE specifics
    kimi = get_config("kimi-k2-1t-a32b").moe
    assert kimi.num_experts == 384 and kimi.top_k == 8
    mav = get_config("llama4-maverick-400b-a17b").moe
    assert mav.num_experts == 128 and mav.top_k == 1
    jam = get_config("jamba-v0.1-52b").moe
    assert jam.num_experts == 16 and jam.top_k == 2


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "gemma3-12b",
                                  "jamba-v0.1-52b", "rwkv6-7b",
                                  "seamless-m4t-large-v2"])
def test_reduced_decode_matches_prefill(arch):
    """One decode step with a cache == last-position logits of a one-token-
    longer prefill (exercises KV/ring/ssm/rwkv caches per family)."""
    cfg = reduced_config(arch)
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    batch = _batch(cfg)
    toks = batch["tokens"]
    kw = {"n_frames": 16} if cfg.enc_dec else {}
    caches, _ = bundle.cache_init(B, S + 4, **kw)
    _, caches2 = bundle.prefill(params, batch, caches=caches, impl="reference")
    nt = jax.random.randint(jax.random.key(9), (B, 1), 0, cfg.vocab_size)
    logits_dec, _ = bundle.decode_step(
        params, caches2, {"tokens": nt, "cur_index": jnp.int32(S)})
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([toks, nt], axis=1)
    logits_full = bundle.prefill(params, b2, impl="reference")
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_dec[:, 0], np.float32), atol=0.06, rtol=0.05)
