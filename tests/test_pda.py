"""PDA: bucketed LRU-TTL cache, async/sync query engines, packed transfer."""
import time

import numpy as np
import pytest
from tests._propcheck import given, settings, st

from repro.core.pda import (BucketedLRUCache, FeatureQueryEngine,
                            RemoteFeatureStore, pack_features,
                            packed_transfer, unpacked_transfer)


def test_lru_eviction_order():
    c = BucketedLRUCache(capacity=4, ttl_s=100, n_buckets=1)
    for i in range(4):
        c.put(i, i)
    c.get(0)          # touch 0 -> 1 becomes LRU
    c.put(99, 99)     # evicts 1
    assert c.get(1)[0] is None
    assert c.get(0)[0] == 0
    assert c.get(99)[0] == 99


def test_ttl_expiry():
    c = BucketedLRUCache(capacity=10, ttl_s=0.5, n_buckets=2)
    c.put(1, "x", now=100.0)
    val, fresh = c.get(1, now=100.2)
    assert val == "x" and fresh
    val, fresh = c.get(1, now=101.0)
    assert val == "x" and not fresh     # expired but still returned (stale)


def test_sync_engine_accuracy_and_hits():
    store = RemoteFeatureStore(latency_s=0.0)
    eng = FeatureQueryEngine(store, BucketedLRUCache(100, 100), mode="sync")
    out1 = eng.query([1, 2, 3])
    assert all(v is not None for v in out1.values())   # sync never misses
    out2 = eng.query([1, 2, 3])
    assert eng.stats.hits == 3
    for k in (1, 2, 3):
        np.testing.assert_array_equal(out1[k], out2[k])


def test_async_engine_never_blocks_then_converges():
    store = RemoteFeatureStore(latency_s=0.002)
    eng = FeatureQueryEngine(store, BucketedLRUCache(100, 100), mode="async")
    t0 = time.perf_counter()
    out1 = eng.query(list(range(50)))
    elapsed = time.perf_counter() - t0
    assert elapsed < 0.5                      # returned without blocking
    assert any(v is None for v in out1.values())
    deadline = time.time() + 5.0
    while time.time() < deadline:
        out2 = eng.query(list(range(50)))
        if all(v is not None for v in out2.values()):
            break
        time.sleep(0.01)
    assert all(v is not None for v in out2.values())
    eng.shutdown()


def test_off_mode_always_network():
    store = RemoteFeatureStore(latency_s=0.0)
    eng = FeatureQueryEngine(store, None, mode="off")
    eng.query([1, 2])
    eng.query([1, 2])
    assert store.requests == 2                # no caching at all


def test_network_bytes_accounting():
    store = RemoteFeatureStore(latency_s=0.0, feature_dim=8)
    store.query([1, 2, 3])
    assert store.bytes_sent == 3 * 8 * 4


@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), min_size=1,
                max_size=8))
@settings(max_examples=30, deadline=None)
def test_pack_features_roundtrip(shapes):
    rng = np.random.default_rng(0)
    arrays = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    buf, layout = pack_features(arrays)
    assert buf.size == sum(a.size for a in arrays)
    off = 0
    for (o, shp), a in zip(layout, arrays):
        assert o == off and tuple(shp) == a.shape
        np.testing.assert_array_equal(buf[o:o + a.size].reshape(shp), a)
        off += a.size


def test_packed_equals_unpacked_transfer():
    rng = np.random.default_rng(1)
    arrays = [rng.standard_normal((4, 8)).astype(np.float32) for _ in range(5)]
    p = packed_transfer(arrays)
    u = unpacked_transfer(arrays)
    for a, b in zip(p, u):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=200),
       st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_cache_invariant_capacity(keys, n_buckets):
    """Hypothesis: cache never exceeds capacity; a get after put within TTL
    returns the stored value."""
    cap = 32
    c = BucketedLRUCache(capacity=cap, ttl_s=1000, n_buckets=n_buckets)
    for k in keys:
        c.put(k, k * 2)
        got, fresh = c.get(k)
        assert got == k * 2 and fresh
    assert len(c) <= max(1, cap // n_buckets) * n_buckets
