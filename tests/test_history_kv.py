"""History-KV reuse: split SUMI forward + HistoryKVPool + cache-aware engine.

Covers the three layers of the refactor:
  1. the candidate-vs-cached-KV attention path (``q_offset``) against the
     monolithic SUMI pass, for all three impls;
  2. climber's ``encode_history`` / ``score_candidates`` decomposition
     against ``climber_forward``;
  3. the serving stack — HistoryKVPool LRU semantics (propcheck), concurrent
     hit/miss accounting, and FlameEngine's cache-aware execution path.
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import climber as C
from repro.core import sumi
from repro.models import attention as A
from repro.models import build_model
from repro.serving import FlameEngine, HistoryKVPool
from repro.serving.kv_cache import HistoryKVPool as _PoolAlias
from repro.types import ClimberConfig
from tests._propcheck import given, settings, st

assert HistoryKVPool is _PoolAlias


# ---------------------------------------------------------------------------
# 1. attention substrate: q_offset candidate path vs monolithic SUMI
# ---------------------------------------------------------------------------

def _qkv(key, b, s, h, hkv, d):
    ks = jax.random.split(jax.random.key(key), 3)
    return (jax.random.normal(ks[0], (b, s, h, d), jnp.float32),
            jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32),
            jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32))


@pytest.mark.parametrize("nh,m,h,hkv,d", [
    (150, 30, 4, 2, 32),     # GQA, non-aligned history
    (33, 9, 2, 2, 16),       # history tail shares a block with candidates
    (64, 64, 2, 1, 64),      # block-aligned history, many candidates
])
def test_q_offset_paths_match_monolithic(nh, m, h, hkv, d):
    q, k, v = _qkv(nh + m, 2, nh + m, h, hkv, d)
    full = A.reference_attention(q, k, v, "sumi", n_history=nh)[:, nh:]
    qc = q[:, nh:]
    ref = A.reference_attention(qc, k, v, "sumi", n_history=nh, q_offset=nh)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(full))
    ch = A.chunked_attention(qc, k, v, "sumi", n_history=nh,
                             q_chunk=16, k_chunk=16, q_offset=nh)
    np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                               atol=2e-5, rtol=2e-5)
    from repro.kernels.flash_attention import ops as fa_ops
    pl = fa_ops.flash_attention(qc, k, v, "sumi", n_history=nh,
                                q_offset=nh, interpret=True)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_cached_candidate_attention_helper():
    nh, m = 40, 12
    q, k, v = _qkv(7, 2, nh + m, 4, 4, 32)
    tau = 1.3
    full = sumi.sumi_attention(q, k, v, nh, impl="reference",
                               temperature=tau)[:, nh:]
    out = sumi.cached_candidate_attention(
        q[:, nh:], k[:, :nh], v[:, :nh], k[:, nh:], v[:, nh:],
        impl="reference", temperature=tau)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(full))


# ---------------------------------------------------------------------------
# 2. climber decomposition: encode_history + score_candidates == forward
# ---------------------------------------------------------------------------

def _climber_cfg():
    return dataclasses.replace(
        get_config("climber"), vocab_size=3000, d_model=128, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))


@pytest.fixture(scope="module")
def climber():
    cfg = _climber_cfg()
    params, _ = C.climber_init(jax.random.key(0), cfg)
    ks = jax.random.split(jax.random.key(1), 3)
    batch = {"history": jax.random.randint(ks[0], (2, 64), 0, 3000),
             "candidates": jax.random.randint(ks[1], (2, 16), 0, 3000),
             "side": jax.random.normal(ks[2], (2, 12))}
    return cfg, params, batch


@pytest.mark.parametrize("impl", ["reference", "chunked", "pallas"])
def test_encode_score_matches_monolithic(climber, impl):
    """The acceptance gate: cached-history candidate scores are numerically
    identical to the monolithic SUMI forward — bitwise where the impl keeps
    the same reduction order (reference; chunked routes there at this
    scale), allclose at bf16-tight tolerance for the block-reordered pallas
    interpret path."""
    cfg, params, batch = climber
    full = C.climber_forward(params, batch, cfg, impl=impl)
    kv = C.encode_history(params, batch, cfg, impl=impl)
    got = C.score_candidates(params, kv, batch["candidates"], cfg, impl=impl)
    if impl == "pallas":
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(full, np.float32),
                                   atol=5e-3, rtol=5e-3)
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(full))


def test_bundle_split_surface_matches_prefill(climber):
    cfg, params, batch = climber
    bundle = build_model(cfg)
    probs = bundle.prefill(params, batch, impl="reference")
    kv = bundle.encode_history(params, batch, impl="reference")
    got = bundle.score_candidates(params, kv, batch["candidates"],
                                  impl="reference")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(probs))


def test_history_kv_specs_match_encode(climber):
    cfg, params, batch = climber
    bundle = build_model(cfg)
    specs = bundle.history_kv_specs(params, 64, batch=2)
    kv = bundle.encode_history(params, batch)
    got = jax.tree.map(lambda a: (a.shape, a.dtype), kv)
    want = jax.tree.map(lambda s: (s.shape, s.dtype), specs)
    assert got == want
    # leading axis is batch (so serving can stack pool rows along axis 0)
    assert specs["b0"]["k"].shape[0] == 2


def test_kv_independent_of_candidates(climber):
    """The refactor's premise: history K/V must not depend on the candidate
    set (SUMI keeps the prefix self-contained)."""
    cfg, params, batch = climber
    kv1 = C.encode_history(params, batch, cfg)
    full1 = C.climber_forward(params, batch, cfg)
    b2 = dict(batch, candidates=batch["candidates"][:, :5])
    got = C.score_candidates(params, kv1, b2["candidates"], cfg)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(full1[:, :5]))


# ---------------------------------------------------------------------------
# 3a. HistoryKVPool semantics
# ---------------------------------------------------------------------------

def _kv(i, n=64):
    return {"k": np.full((1, 2, 4), i, np.float32),
            "v": np.full((1, 2, 4), i, np.float32)}


def test_pool_hit_miss_and_bytes():
    p = HistoryKVPool(slots=4)
    assert p.get("u1", "f1") is None                   # cold miss
    p.put("u1", "f1", _kv(1))
    got = p.get("u1", "f1")
    np.testing.assert_array_equal(got["k"], _kv(1)["k"])
    s = p.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["entries"] == 1
    assert s["bytes"] == 2 * 8 * 4                      # two [1,2,4] f32


def test_pool_stale_fingerprint_is_miss():
    p = HistoryKVPool(slots=4)
    p.put("u1", "f1", _kv(1))
    assert p.get("u1", "f2") is None                    # history advanced
    s = p.stats()
    assert s["stale"] == 1 and s["misses"] == 1 and s["entries"] == 0
    p.put("u1", "f2", _kv(2))
    assert p.get("u1", "f2")["k"][0, 0, 0] == 2


def test_pool_lru_eviction_order():
    p = HistoryKVPool(slots=3)
    for i in range(3):
        p.put(f"u{i}", "f", _kv(i))
    p.get("u0", "f")                                    # refresh u0
    p.put("u3", "f", _kv(3))                            # evicts u1 (LRU)
    assert p.get("u1", "f") is None
    assert p.get("u0", "f") is not None
    assert p.stats()["evictions"] == 1
    assert len(p) == 3


def test_pool_release_on_shutdown():
    p = HistoryKVPool(slots=2)
    p.put("a", "f", _kv(0))
    p.put("b", "f", _kv(1))
    p.release()
    assert len(p) == 0 and p.stats()["bytes"] == 0
    assert p.get("a", "f") is None                      # counters survive
    assert p.stats()["misses"] == 1


@given(st.lists(st.tuples(st.integers(0, 7), st.integers(0, 1)),
                min_size=1, max_size=40),
       st.integers(1, 5))
@settings(max_examples=40, deadline=None)
def test_pool_lru_eviction_property(ops, slots):
    """Model check: after any put/get sequence the pool holds exactly the
    ``slots`` most-recently-used non-stale keys, in LRU->MRU order."""
    p = HistoryKVPool(slots=slots)
    model = {}                       # key -> fingerprint, insertion=recency
    for key, is_put in ops:
        k = f"u{key}"
        if is_put:
            p.put(k, "f", _kv(key))
            model.pop(k, None)
            model[k] = "f"
            while len(model) > slots:
                del model[next(iter(model))]
        else:
            got = p.get(k, "f")
            assert (got is not None) == (k in model)
            if k in model:           # refresh recency
                model[k] = model.pop(k)
    assert p.keys() == list(model)


def test_pool_concurrent_counters_consistent():
    """Hit/miss accounting under concurrent submits: every get is counted
    exactly once and entries never exceed the slot budget."""
    p = HistoryKVPool(slots=4)
    n_threads, n_ops = 8, 200
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        rng = np.random.default_rng(tid)
        barrier.wait()
        for _ in range(n_ops):
            key = f"u{rng.integers(8)}"
            if p.get(key, "f") is None:
                p.put(key, "f", _kv(0))

    ths = [threading.Thread(target=worker, args=(t,))
           for t in range(n_threads)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    s = p.stats()
    assert s["hits"] + s["misses"] == n_threads * n_ops
    assert s["entries"] <= 4
    assert s["bytes"] == s["entries"] * 2 * 8 * 4


# ---------------------------------------------------------------------------
# 3b. cache-aware FlameEngine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=5_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def _engines(bundle, params, **kw):
    from repro.core.pda import RemoteFeatureStore
    base = dict(n_history=64, buckets=(16, 8), n_streams=2,
                feature_mode="sync",
                store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                window_s=0.004, max_batch=2, n_workers=2)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


def test_engine_cached_scores_match_full(serving_setup):
    cfg, bundle, params = serving_setup
    eng_full = _engines(bundle, params)
    eng_pool = _engines(bundle, params, history_cache=True, pool_slots=4)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, 5000, 64).astype(np.int32)
    try:
        for m in (8, 12, 24):        # aligned, padded, multi-chunk
            cand = rng.integers(0, 5000, m).astype(np.int32)
            a = eng_full.serve(hist, cand)
            b = eng_pool.serve(hist, cand, user_id=1)
            assert a.shape == b.shape == (m, cfg.climber.num_tasks)
            np.testing.assert_allclose(a.astype(np.float32),
                                       b.astype(np.float32),
                                       atol=2e-3, rtol=2e-3)
        m = eng_pool.metrics()
        assert m["pool_hits"] == 2 and m["pool_misses"] == 1
        assert m["dso_dispatches_encode"] == 1
        assert m["pool_bytes"] > 0
    finally:
        eng_full.shutdown()
        eng_pool.shutdown()


def test_engine_hit_path_bitwise_vs_miss_path(serving_setup):
    """Hit and miss both score through the SAME cached executors, so scores
    for identical requests must be bitwise equal across the pool states."""
    cfg, bundle, params = serving_setup
    eng = _engines(bundle, params, history_cache=True, pool_slots=4)
    rng = np.random.default_rng(1)
    hist = rng.integers(0, 5000, 64).astype(np.int32)
    cand = rng.integers(0, 5000, 12).astype(np.int32)
    try:
        miss = eng.serve(hist, cand, user_id=9)         # encodes
        hit = eng.serve(hist, cand, user_id=9)          # pool hit
        np.testing.assert_array_equal(miss, hit)
    finally:
        eng.shutdown()


def test_engine_stale_history_reencodes(serving_setup):
    """Same user, changed history -> the pooled KV is stale; the engine must
    re-encode rather than score against outdated state."""
    cfg, bundle, params = serving_setup
    eng = _engines(bundle, params, history_cache=True, pool_slots=4)
    rng = np.random.default_rng(2)
    h1 = rng.integers(0, 5000, 64).astype(np.int32)
    h2 = rng.integers(0, 5000, 64).astype(np.int32)
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        eng.serve(h1, cand, user_id=3)
        out2 = eng.serve(h2, cand, user_id=3)           # stale -> re-encode
        m = eng.metrics()
        assert m["pool_stale"] == 1 and m["pool_misses"] == 2
        # scores reflect the NEW history, not the stale KV
        eng2 = _engines(bundle, params, history_cache=True, pool_slots=4)
        try:
            fresh = eng2.serve(h2, cand, user_id=99)
            np.testing.assert_array_equal(out2, fresh)
        finally:
            eng2.shutdown()
    finally:
        eng.shutdown()


def test_engine_tail_only_history_change_is_stale(serving_setup):
    """The model truncates history to n_history but side features average
    the FULL array — a tail-only change must invalidate the pooled KV, and
    the pooled scores must track what the full-pass engine would serve."""
    cfg, bundle, params = serving_setup
    eng = _engines(bundle, params, history_cache=True, pool_slots=4)
    eng_full = _engines(bundle, params)
    rng = np.random.default_rng(5)
    h1 = rng.integers(0, 5000, 80).astype(np.int32)     # > n_history=64
    h2 = h1.copy()
    h2[70:] = rng.integers(0, 5000, 10)                 # tail-only change
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        eng.serve(h1, cand, user_id=5)
        out2 = eng.serve(h2, cand, user_id=5)           # must re-encode
        assert eng.metrics()["pool_stale"] == 1
        np.testing.assert_allclose(
            out2.astype(np.float32),
            eng_full.serve(h2, cand).astype(np.float32),
            atol=2e-3, rtol=2e-3)
    finally:
        eng.shutdown()
        eng_full.shutdown()


def test_engine_pad_sentinel_does_not_leak(serving_setup):
    """m=5 into bucket 8 pads with the -1 sentinel; scores must equal an
    unpadded request for the same leading candidates, and negative real
    candidate ids are rejected up front."""
    cfg, bundle, params = serving_setup
    eng = _engines(bundle, params)
    rng = np.random.default_rng(3)
    hist = rng.integers(0, 5000, 64).astype(np.int32)
    cand8 = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        full = eng.serve(hist, cand8)
        part = eng.serve(hist, cand8[:5])               # padded to bucket 8
        np.testing.assert_array_equal(part, full[:5])
        bad = cand8.copy()
        bad[2] = -1
        with pytest.raises(Exception, match="candidate ids must be >= 0"):
            eng.serve(hist, bad)
    finally:
        eng.shutdown()


def test_engine_concurrent_repeat_users(serving_setup):
    """Concurrent submits from a small user population: counters stay
    consistent and every response matches the full-pass engine."""
    from repro.serving import ServeRequest
    cfg, bundle, params = serving_setup
    eng = _engines(bundle, params, history_cache=True, pool_slots=8,
                   n_workers=4)
    rng = np.random.default_rng(4)
    users = {u: rng.integers(0, 5000, 64).astype(np.int32) for u in range(3)}
    reqs = [(u, rng.integers(0, 5000, 8).astype(np.int32))
            for u in list(users) * 6]
    try:
        futs = [eng.submit(ServeRequest(history=users[u], candidates=c,
                                        user_id=u)) for u, c in reqs]
        outs = [f.result().output for f in futs]
        m = eng.metrics()
        assert m["pool_hits"] + m["pool_misses"] == len(reqs)
        assert m["pool_misses"] >= len(users)
        assert len(eng.history_pool) == len(users)
        # single-flight: concurrent same-user misses share ONE encode
        assert m["dso_chunks_encode"] == len(users)
        # sequential re-serve of the same requests must be bitwise stable
        for (u, c), out in zip(reqs, outs):
            np.testing.assert_array_equal(
                eng.serve(users[u], c, user_id=u), out)
    finally:
        eng.shutdown()
