"""Attention substrate: masks, chunked-vs-reference, decode paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def _qkv(key, b=2, sq=256, sk=256, h=4, hkv=2, d=32, dtype=jnp.float32):
    ks = jax.random.split(jax.random.key(key), 3)
    return (jax.random.normal(ks[0], (b, sq, h, d), dtype),
            jax.random.normal(ks[1], (b, sk, hkv, d), dtype),
            jax.random.normal(ks[2], (b, sk, hkv, d), dtype))


@pytest.mark.parametrize("mode,kw", [
    ("causal", {}), ("full", {}), ("sliding", {"window": 70}),
    ("sumi", {"n_history": 150}),
])
def test_chunked_matches_reference(mode, kw):
    q, k, v = _qkv(0)
    ref = A.reference_attention(q, k, v, mode, **kw)
    out = A.chunked_attention(q, k, v, mode, q_chunk=64, k_chunk=64, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_chunked_nondivisible_lengths():
    q, k, v = _qkv(1, sq=200, sk=200)
    ref = A.reference_attention(q, k, v, "causal")
    out = A.chunked_attention(q, k, v, "causal", q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_sliding_window_chunked_path():
    """window < sk triggers the sliced (S*W flops) path."""
    q, k, v = _qkv(2, sq=512, sk=512)
    ref = A.reference_attention(q, k, v, "sliding", window=100)
    out = A.chunked_attention(q, k, v, "sliding", window=100,
                              q_chunk=128, k_chunk=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_mask_semantics():
    m = A.make_mask(6, 6, "sumi", n_history=4)
    m = np.asarray(m)
    # history rows: causal
    for qp in range(4):
        for kp in range(6):
            assert m[qp, kp] == (kp <= qp)
    # candidate rows: history + self only
    for qp in range(4, 6):
        for kp in range(6):
            assert m[qp, kp] == (kp < 4 or kp == qp)
    ms = np.asarray(A.make_mask(8, 8, "sliding", window=3))
    for qp in range(8):
        for kp in range(8):
            assert ms[qp, kp] == (kp <= qp and qp - kp < 3)


def test_decode_attention_matches_reference_last_row():
    b, s, h, hkv, d = 2, 64, 4, 2, 32
    q, k, v = _qkv(3, b=b, sq=s, sk=s, h=h, hkv=hkv, d=d)
    ref = A.reference_attention(q, k, v, "causal")
    out = A.decode_attention(q[:, -1:], k, v, cur_len=s)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, -1]),
                               atol=1e-5, rtol=1e-5)


def test_decode_attention_per_sample_lengths():
    b, s, h, hkv, d = 2, 32, 2, 2, 16
    q, k, v = _qkv(4, b=b, sq=1, sk=s, h=h, hkv=hkv, d=d)
    lens = jnp.array([10, 32])
    out = A.decode_attention(q, k, v, cur_len=lens)
    # sample 0 must ignore positions >= 10: perturbing them changes nothing
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(99.0)
    out2 = A.decode_attention(q, k2, v2, cur_len=lens)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]))
    # sample 1 (len 32) does see the perturbed positions
    assert not np.allclose(np.asarray(out[1]), np.asarray(out2[1]))


def test_gqa_grouping_matches_repeated_heads():
    """GQA == MHA with kv heads explicitly repeated."""
    q, k, v = _qkv(5, h=8, hkv=2)
    ref_gqa = A.reference_attention(q, k, v, "causal")
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    ref_mha = A.reference_attention(q, k_rep, v_rep, "causal")
    np.testing.assert_allclose(np.asarray(ref_gqa), np.asarray(ref_mha),
                               atol=1e-5, rtol=1e-5)
