"""All-to-all MoE dispatch (the §Perf optimized path) == GSPMD path.

The multi-shard case runs in a subprocess with 8 forced host devices so the
main pytest process keeps seeing 1 device."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.models.layers import split_params
from repro.models.moe import moe_apply, moe_apply_a2a, moe_init
from tests.test_moe import make_cfg

SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.models.layers import split_params
from repro.models.moe import moe_apply, moe_apply_a2a, moe_init
from tests.test_moe import make_cfg

cfg = make_cfg(e=8, k=2, cf=8.0)
params, _ = split_params(moe_init(jax.random.key(0), cfg))
x = jax.random.normal(jax.random.key(1), (8, 16, 64), jnp.float32)
mesh = make_mesh((8,), ("data",))
ref, aux_ref = moe_apply(params, x, cfg)
out, aux = jax.jit(lambda p, xx: moe_apply_a2a(p, xx, cfg, mesh=mesh,
                                               axis="data"))(params, x)
err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
scale = float(np.abs(np.asarray(ref)).max())
assert err / scale < 2e-2, (err, scale)
d_ref = float(aux_ref["dropped_fraction"])
d_a2a = float(aux["dropped_fraction"])
assert d_a2a <= 0.05, d_a2a
print("OK", err, scale)
"""


def test_a2a_single_shard_matches_gspmd():
    """On a 1-device mesh the a2a path must equal the scatter path exactly
    (all_to_all over a size-1 axis is the identity)."""
    cfg = make_cfg(e=4, k=2, cf=8.0)
    params, _ = split_params(moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (2, 32, 64), jnp.float32)
    mesh = make_mesh((1,), ("data",))
    ref, _ = moe_apply(params, x, cfg)
    out, aux = moe_apply_a2a(params, x, cfg, mesh=mesh, axis="data")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)
    assert float(aux["dropped_fraction"]) < 0.05


def test_a2a_multi_shard_matches_gspmd_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env, capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
