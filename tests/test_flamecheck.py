"""flamecheck (repro.analysis) — fixture coverage for every pass.

Each test writes a minimal fixture module, runs the relevant pass through
the library API, and asserts (a) the violation is found, (b) the matching
pragma suppresses it, and (c) ``--strict`` semantics (unused pragmas,
empty reasons) hold.  A subprocess test pins the CLI exit-code contract
that scripts/ci.sh gates on.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.cli import PASSES, default_paths, load_sources, \
    run_passes
from repro.analysis.common import ModuleSource

SRC_ROOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _findings(tmp_path, name, code, passes=tuple(PASSES), strict=False):
    src = ModuleSource(str(tmp_path / name), code)
    return [f for f in run_passes([src], passes, strict=strict)]


def _active(findings):
    return [f for f in findings if not f.suppressed]


# ---------------------------------------------------------------------------
# pass 1: lock discipline
# ---------------------------------------------------------------------------

LOCK_FIXTURE = """
import threading

class Pool:
    def __init__(self):
        self._lock = threading.Lock()
        self._entries = {}
        self.bytes_used = 0

    def put(self, k, v):
        with self._lock:
            self._entries[k] = v
            self.bytes_used += 1

    def peek(self, k):
        return self._entries.get(k){pragma}
"""


def test_lock_unguarded_read_found(tmp_path):
    code = LOCK_FIXTURE.replace("{pragma}", "")
    fs = _active(_findings(tmp_path, "m.py", code,
                           passes=("lock-discipline",)))
    assert len(fs) == 1
    assert fs[0].code == "FC-LOCK"
    assert "_entries" in fs[0].message and "peek" in fs[0].message


def test_lock_pragma_suppresses(tmp_path):
    code = LOCK_FIXTURE.replace(
        "{pragma}",
        "  # flamecheck: unguarded-ok(read-only probe; stale OK)")
    fs = _findings(tmp_path, "m.py", code, passes=("lock-discipline",))
    assert len(fs) == 1 and fs[0].suppressed
    assert not _active(fs)


def test_lock_guarded_access_clean(tmp_path):
    code = textwrap.dedent("""
        import threading

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._entries = {}

            def put(self, k, v):
                with self._lock:
                    self._entries[k] = v

            def get(self, k):
                with self._lock:
                    return self._entries.get(k)
        """)
    assert not _findings(tmp_path, "m.py", code,
                         passes=("lock-discipline",))


def test_lock_locked_by_caller_pragma(tmp_path):
    code = textwrap.dedent("""
        import threading

        class Pool:
            def __init__(self):
                self._lock = threading.Lock()
                self._entries = {}

            def put(self, k, v):
                with self._lock:
                    self._admit(k, v)

            def _admit(self, k, v):  # flamecheck: locked-by-caller(self._lock)
                self._entries[k] = v
        """)
    assert not _active(_findings(tmp_path, "m.py", code,
                                 passes=("lock-discipline",)))


def test_lock_condition_shares_wrapped_lock(tmp_path):
    """Condition(self._lock) and self._lock are one lock to the pass."""
    code = textwrap.dedent("""
        import threading

        class Q:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)
                self._items = []

            def put(self, x):
                with self._lock:
                    self._items.append(x)

            def drain(self):
                with self._cv:
                    return self._items.pop()
        """)
    assert not _findings(tmp_path, "m.py", code,
                         passes=("lock-discipline",))


def test_lock_admission_queue_cv_discipline(tmp_path):
    """The engine's _AdmissionQueue shape: two CVs wrapping one mutex.
    Holding either CV counts as holding the mutex; an access outside all
    three is flagged."""
    code = textwrap.dedent("""
        import heapq
        import threading

        class AdmissionQueue:
            def __init__(self):
                self._lock = threading.Lock()
                self._not_empty = threading.Condition(self._lock)
                self._not_full = threading.Condition(self._lock)
                self._heap = []
                self._live = 0

            def put(self, rec):
                with self._not_full:
                    heapq.heappush(self._heap, rec)
                    self._live += 1

            def get(self):
                with self._not_empty:
                    self._live -= 1
                    return heapq.heappop(self._heap)

            def shed_victim(self):
                with self._lock:
                    self._heap.sort()

            def qsize(self):
                return self._live
        """)
    fs = _active(_findings(tmp_path, "m.py", code,
                           passes=("lock-discipline",)))
    assert len(fs) == 1 and fs[0].code == "FC-LOCK"
    assert "qsize" in fs[0].message and "_live" in fs[0].message


def test_lock_alias_and_heappush_tracked(tmp_path):
    """cond aliasing + heapq first-arg mutation, the dso.py idioms."""
    code = textwrap.dedent("""
        import heapq
        import threading

        class Orch:
            def __init__(self):
                self._cond = {k: threading.Condition() for k in (1, 2)}
                self._pending = {k: [] for k in (1, 2)}

            def submit(self, k, item):
                cond = self._cond[k]
                with cond:
                    heapq.heappush(self._pending[k], item)

            def steal(self, k):
                return self._pending[k]
        """)
    fs = _active(_findings(tmp_path, "m.py", code,
                           passes=("lock-discipline",)))
    assert len(fs) == 1 and "steal" in fs[0].message


# ---------------------------------------------------------------------------
# pass 2: host sync in hot paths
# ---------------------------------------------------------------------------

SYNC_FIXTURE = """
import numpy as np

class FlameEngine:
    def submit(self, req):
        return self._score(req)

    def _score(self, req):
        arr = np.asarray(req.history){pragma}
        return arr.sum()

def offline_tool(x):
    return np.asarray(x)    # NOT reachable from the hot path
"""


def test_host_sync_reachable_found(tmp_path):
    fs = _active(_findings(tmp_path, "m.py",
                           SYNC_FIXTURE.replace("{pragma}", ""),
                           passes=("host-sync",)))
    assert len(fs) == 1
    assert fs[0].code == "FC-SYNC-NP" and "_score" in fs[0].message


def test_host_sync_pragma_suppresses(tmp_path):
    code = SYNC_FIXTURE.replace(
        "{pragma}",
        "  # flamecheck: host-sync-ok(request arrays are host-side)")
    assert not _active(_findings(tmp_path, "m.py", code,
                                 passes=("host-sync",)))


def test_host_sync_detects_item_and_device_get(tmp_path):
    code = textwrap.dedent("""
        import jax
        import numpy as np

        class CoalescingOrchestrator:
            def _worker(self):
                out = self._run()
                jax.block_until_ready(out)
                host = jax.tree.map(np.asarray, out)
                return float(np.max(host)), out.item()
        """)
    codes = {f.code for f in _active(_findings(
        tmp_path, "m.py", code, passes=("host-sync",)))}
    assert codes == {"FC-SYNC-JAX", "FC-SYNC-CALLBACK", "FC-SYNC-SCALAR",
                     "FC-SYNC-METHOD"}


# ---------------------------------------------------------------------------
# pass 3: recompile / tracer hazards
# ---------------------------------------------------------------------------

def test_recompile_traced_branch_found(tmp_path):
    code = textwrap.dedent("""
        import jax

        @jax.jit
        def f(x):
            if x.sum() > 0:
                return x
            return -x
        """)
    fs = _active(_findings(tmp_path, "m.py", code, passes=("recompile",)))
    assert len(fs) == 1 and fs[0].code == "FC-TRACED-BRANCH"


def test_recompile_static_branches_clean(tmp_path):
    code = textwrap.dedent("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, y, mode):
            b, m = x.shape
            if mode == "causal":
                x = x + 1
            if y is None:
                return x
            if x.ndim == 3 and b > m:
                return x * 2
            while len(x.shape) < 4:
                x = x[None]
            return x
        """)
    assert not _active(_findings(tmp_path, "m.py", code,
                                 passes=("recompile",)))


def test_recompile_bad_cache_key_found(tmp_path):
    code = textwrap.dedent("""
        import numpy as np

        class Eng:
            def remember(self, hist, out):
                self._cache[np.array(hist)] = out
                self._executors[[1, 2]] = out
                self._memo.get((1, 2.5))
        """)
    fs = _active(_findings(tmp_path, "m.py", code, passes=("recompile",)))
    assert len(fs) == 3
    assert {f.code for f in fs} == {"FC-CACHE-KEY"}


def test_recompile_jit_in_hot_path_found(tmp_path):
    code = textwrap.dedent("""
        import jax

        class FlameEngine:
            def submit(self, req):
                fn = jax.jit(lambda x: x * 2)   # per-request trace
                return fn(req)
        """)
    fs = _active(_findings(tmp_path, "m.py", code, passes=("recompile",)))
    assert len(fs) == 1 and fs[0].code == "FC-JIT-HOT"


def test_recompile_shape_branch_in_serving_module(tmp_path):
    code = textwrap.dedent("""
        class Engine:
            def route(self, x):
                if x.shape[0] > 128:
                    return self.big(x)
                return self.small(x)
        """)
    fs = _active(_findings(tmp_path, "engine.py", code,
                           passes=("recompile",)))
    assert len(fs) == 1 and fs[0].code == "FC-SHAPE-BRANCH"
    # same code outside the serving modules is not R4's business
    assert not _active(_findings(tmp_path, "util.py", code,
                                 passes=("recompile",)))


# ---------------------------------------------------------------------------
# pass 4: Pallas kernel contracts
# ---------------------------------------------------------------------------

IMPURE_MAP_FIXTURE = """
import jax.numpy as jnp
from jax.experimental import pallas as pl

def build(nk):
    def kv_map(i, j):
        return (i, jnp.minimum(j, nk - 1)){pragma}
    return pl.BlockSpec((1, 128), kv_map)
"""


def test_kernel_impure_index_map_found(tmp_path):
    fs = _active(_findings(tmp_path, "kernel.py",
                           IMPURE_MAP_FIXTURE.replace("{pragma}", ""),
                           passes=("kernel-contract",)))
    assert len(fs) == 1 and fs[0].code == "FC-INDEX-MAP-JNP"


def test_kernel_pragma_suppresses(tmp_path):
    code = IMPURE_MAP_FIXTURE.replace(
        "{pragma}",
        "  # flamecheck: kernel-ok(scalar clamp of a traced index)")
    assert not _active(_findings(tmp_path, "kernel.py", code,
                                 passes=("kernel-contract",)))


def test_kernel_mutable_global_closure_found(tmp_path):
    code = textwrap.dedent("""
        from jax.experimental import pallas as pl

        OFFSETS = [0, 1, 2]

        def build():
            return pl.BlockSpec((1, 8), lambda i: (OFFSETS[i], 0))
        """)
    fs = _active(_findings(tmp_path, "kernel.py", code,
                           passes=("kernel-contract",)))
    assert len(fs) == 1 and fs[0].code == "FC-INDEX-MAP-STATE"


def test_kernel_missing_pad_guard_found(tmp_path):
    code = textwrap.dedent("""
        from repro.kernels.fake.kernel import fake_kernel

        def fake_op(x):
            return fake_kernel(x)
        """)
    fs = _active(_findings(tmp_path, "ops.py", code,
                           passes=("kernel-contract",)))
    assert len(fs) == 1 and fs[0].code == "FC-NO-PAD-GUARD"
    guarded = textwrap.dedent("""
        from repro.kernels.fake.kernel import fake_kernel

        def fake_op(x, bk=128):
            pad = (-x.shape[0]) % bk
            return fake_kernel(x)
        """)
    assert not _active(_findings(tmp_path, "ops.py", guarded,
                                 passes=("kernel-contract",)))


def test_kernel_prefetch_arity_mismatch_found(tmp_path):
    code = textwrap.dedent("""
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def build(b, nq):
            def q_map(i, j, idx_ref):   # needs 2 grid + 2 prefetch = 4
                return (i, j)
            return pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(b, nq),
                in_specs=[pl.BlockSpec((1, 8), q_map)])
        """)
    fs = _active(_findings(tmp_path, "kernel.py", code,
                           passes=("kernel-contract",)))
    assert len(fs) == 1 and fs[0].code == "FC-PREFETCH-ARITY"
    assert "2 grid indices + 2 prefetch" in fs[0].message


# ---------------------------------------------------------------------------
# pass 5: ResponseFuture leak lint
# ---------------------------------------------------------------------------

FUTURE_LEAK_FIXTURE = """
from repro.serving.api import ResponseFuture

class Engine:
    def submit(self, request):
        fut = ResponseFuture(request){pragma}
        self.accepted += 1
        return None
"""


def test_future_leak_found(tmp_path):
    code = FUTURE_LEAK_FIXTURE.replace("{pragma}", "")
    fs = _active(_findings(tmp_path, "m.py", code,
                           passes=("future-leak",)))
    assert len(fs) == 1 and fs[0].code == "FC-FUTURE"
    assert "'fut'" in fs[0].message and "submit" in fs[0].message


def test_future_leak_pragma_suppresses(tmp_path):
    code = FUTURE_LEAK_FIXTURE.replace(
        "{pragma}", "  # flamecheck: future-ok(fixture builds a dead one)")
    fs = _findings(tmp_path, "m.py", code, passes=("future-leak",))
    assert len(fs) == 1 and fs[0].suppressed
    assert not _active(fs)


def test_future_bare_drop_found(tmp_path):
    code = textwrap.dedent("""
        from repro.serving.api import ResponseFuture

        def probe(request):
            ResponseFuture(request)
        """)
    fs = _active(_findings(tmp_path, "m.py", code,
                           passes=("future-leak",)))
    assert len(fs) == 1 and fs[0].code == "FC-FUTURE"
    assert "dropped" in fs[0].message


def test_future_discharged_forms_clean(tmp_path):
    """Every legitimate way out of the obligation: resolve it, return it,
    hand it to a call (positionally, by keyword, inside a tuple), store it
    into shared state, or resolve it from a nested closure."""
    code = textwrap.dedent("""
        from repro.serving.api import ResponseFuture

        class Engine:
            def resolved(self, request):
                fut = ResponseFuture(request)
                fut.set_exception(RuntimeError("shed"))

            def returned(self, request):
                fut = ResponseFuture(request)
                return fut

            def handed_positional(self, request):
                fut = ResponseFuture(request)
                self._register(fut)

            def handed_keyword(self, request):
                fut = ResponseFuture(request)
                self._record(key=(1, 2), fut=fut)

            def stored(self, request):
                fut = ResponseFuture(request)
                self._futs[id(request)] = fut

            def closure_resolves(self, request):
                fut = ResponseFuture(request)

                def on_timeout():
                    fut.set_exception(TimeoutError())
                self._watchdog.append(on_timeout)
        """)
    assert not _active(_findings(tmp_path, "m.py", code,
                                 passes=("future-leak",)))


# ---------------------------------------------------------------------------
# pragma hygiene (--strict) and the CLI contract
# ---------------------------------------------------------------------------

def test_strict_flags_unused_pragma_and_empty_reason(tmp_path):
    code = textwrap.dedent("""
        X = 1  # flamecheck: unguarded-ok(nothing here needs a lock)
        Y = 2  # flamecheck: host-sync-ok()
        """)
    fs = _findings(tmp_path, "m.py", code, strict=True)
    codes = sorted(f.code for f in fs)
    assert codes == ["FC-PRAGMA-REASON", "FC-PRAGMA-UNUSED",
                     "FC-PRAGMA-UNUSED"]


def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=str(cwd))


def test_cli_exit_codes(tmp_path):
    clean = tmp_path / "clean.py"
    clean.write_text("def f():\n    return 1\n")
    bad = tmp_path / "m.py"
    bad.write_text(LOCK_FIXTURE.replace("{pragma}", ""))
    assert _run_cli(["--strict", str(clean)], tmp_path).returncode == 0
    r = _run_cli(["--strict", str(bad)], tmp_path)
    assert r.returncode == 1
    assert "FC-LOCK" in r.stdout
    assert _run_cli(["--passes", "nonsense", str(clean)],
                    tmp_path).returncode == 2


def test_cli_json_output(tmp_path):
    import json
    bad = tmp_path / "m.py"
    bad.write_text(LOCK_FIXTURE.replace("{pragma}", ""))
    r = _run_cli(["--json", str(bad)], tmp_path)
    assert r.returncode == 1
    data = json.loads(r.stdout)
    assert len(data) == 1 and data[0]["code"] == "FC-LOCK"


def test_repo_is_baseline_clean():
    """The shipped tree must stay flamecheck-clean in strict mode —
    the same gate scripts/ci.sh runs."""
    sources = load_sources(default_paths())
    assert sources, "default target set resolved to no files"
    active = _active(run_passes(sources, strict=True))
    assert not active, "\n".join(f.format() for f in active)
