"""Sharding rule resolution + roofline HLO parsing (host-side units)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._propcheck import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import roofline as RL
from repro import sharding as shd
from repro.configs import get_config, get_shape


@pytest.fixture(scope="module")
def mesh():
    # single host device: a (1, 1) mesh still exercises the rule machinery
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_to_spec_basic(mesh):
    spec = shd.logical_to_spec(("batch", None, "mlp"), (16, 8, 64), mesh)
    assert isinstance(spec, P)


def test_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 7 is not divisible by anything > 1 -> must resolve without error
    spec = shd.logical_to_spec(("heads",), (7,), mesh)
    assert spec == P() or spec == P(None) or True


@given(st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_spec_never_overpartitions(d1, d2):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = shd.logical_to_spec(("batch", "mlp"), (d1, d2), mesh)
    # on a 1x1 mesh every axis divides; just must not raise and be a P
    assert isinstance(spec, P)


def test_rules_for_shape_batch1():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = shd.rules_for_shape(mesh, global_batch=1)
    assert rules["cache_seq"] == ("data", "model")
    rules2 = shd.rules_for_shape(mesh, global_batch=256)
    assert rules2["cache_seq"] == ()


def test_constrain_ctx_noop_outside_context():
    x = jnp.ones((4, 4))
    assert shd.constrain_ctx(x, "batch", None) is x


SAMPLE_HLO = """
ENTRY %main {
  %p0 = bf16[16,8192]{1,0} parameter(0)
  %all-gather.1 = bf16[256,8192]{1,0} all-gather(%p0), dimensions={0}
  %all-reduce.2 = f32[1024]{0} all-reduce(%x), to_apply=%add
  %ar3 = (f32[512]{0}, f32[512]{0}) all-reduce(%a, %b), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(%z), dimensions={0}
  %cp = u32[4]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(%m, %n)
}
"""


def test_collective_parse():
    out = RL.collective_bytes_from_hlo(SAMPLE_HLO)
    assert out["all-gather"] == 256 * 8192 * 2
    assert out["all-reduce"] == (1024 * 4 + 2 * 512 * 4) * 2   # 2x ring factor
    assert out["reduce-scatter"] == 64 * 32 * 4
    assert out["all-to-all"] == 8 * 128 * 2
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_model_flops_kinds():
    cfg = get_config("h2o-danube-3-4b")
    tr = RL.model_flops(cfg, get_shape("train_4k"))
    pf = RL.model_flops(cfg, get_shape("prefill_32k"))
    dc = RL.model_flops(cfg, get_shape("decode_32k"))
    assert tr == pytest.approx(6 * cfg.param_count() * 4096 * 256, rel=1e-6)
    assert pf == pytest.approx(2 * cfg.param_count() * 32768 * 32, rel=1e-6)
    assert dc == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)


def test_moe_model_flops_use_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    fl = RL.model_flops(kimi, get_shape("train_4k"))
    assert fl < 6 * kimi.param_count() * 4096 * 256 * 0.1   # far below total


def test_analytic_hbm_decode_cache_dominated():
    cfg = get_config("qwen2-72b")
    by = RL.analytic_hbm_bytes(cfg, get_shape("decode_32k"))
    # KV cache read per token: 80L*2*8h*128d*32768*2B*128batch ~ 1.4e12
    assert by > 1e12


def test_report_dominant_and_ratio():
    cfg = get_config("h2o-danube-3-4b")
    shp = get_shape("train_4k")
    rep = RL.analyse("a", "s", "m", 256, {"flops": 1e14, "bytes accessed": 1e9},
                     SAMPLE_HLO, cfg, shp)
    assert rep.dominant in ("compute", "memory", "collective")
    assert 0 < rep.useful_ratio < 10
