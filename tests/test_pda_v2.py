"""PDA v2: byte-budgeted / quantized / device-resident history-KV pool +
incremental suffix extension.

Four layers of coverage:
  1. quantization hooks — int8/bf16 round-trip error and stored-byte bounds;
  2. HistoryKVPool v2 — byte-budget LRU model check (never exceeds budget,
     evicts strictly LRU, rejects oversized), host-tier spill/reload
     identity;
  3. the incremental-extension substrate — causal ``q_offset`` attention
     parity (chunked + pallas vs reference) and ``extend_history`` bitwise
     vs a full re-encode for arbitrary shared-prefix lengths;
  4. the serving stack — FlameEngine extension on tail-append staleness,
     KV-row dedup for multi-chunk requests, int8 score-drift bound, and
     byte-budget accounting through ServeMetrics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import climber as C
from repro.models import attention as A
from repro.models import build_model
from repro.serving.kv_cache import (HistoryKVPool, dequantize_kv,
                                    payload_bytes, quantize_kv,
                                    quantized_nbytes)
from repro.types import ClimberConfig
from tests._propcheck import given, settings, st

# int8 pool entries must stay inside this score drift vs a native pool
# (sigmoid outputs; measured ~2e-3 on the test config — the bound leaves
# an order of magnitude of headroom and fails loudly if quantization
# quality regresses)
INT8_SCORE_DRIFT_BOUND = 2e-2


# ---------------------------------------------------------------------------
# 1. quantization hooks
# ---------------------------------------------------------------------------

def _kv_tree(seed=0, shape=(1, 2, 16, 4, 8)):
    rng = np.random.default_rng(seed)
    return {"k": rng.normal(size=shape).astype(np.float32) * 3.0,
            "v": rng.normal(size=shape).astype(np.float32)}


def test_int8_round_trip_error_and_bytes():
    x = _kv_tree()
    pay, nbytes = quantize_kv(x, "int8")
    back = dequantize_kv(pay)
    for k in x:
        a, b = x[k], np.asarray(back[k])
        # per-(layer, head) absmax scaling: elementwise error <= scale/254
        scale = np.max(np.abs(a), axis=(2, 4), keepdims=True)
        assert np.all(np.abs(a - b) <= scale / 254 + 1e-7)
    raw = sum(a.size * 4 for a in x.values())
    assert nbytes < raw * 0.3           # ~4x capacity per byte budget


def test_bf16_round_trip_preserves_dtype():
    x = _kv_tree(1)
    pay, nbytes = quantize_kv(x, "bf16")
    back = dequantize_kv(pay)
    for k in x:
        assert np.asarray(back[k]).dtype == np.float32   # original dtype back
        assert np.abs(np.asarray(back[k]) - x[k]).max() <= \
            np.abs(x[k]).max() * 2 ** -8
    raw = sum(a.size * 4 for a in x.values())
    assert nbytes == raw // 2


def test_quantized_nbytes_matches_actual_payload():
    """The free admission precheck must agree exactly with the bytes the
    real quantization produces (budget decisions ride on it)."""
    x = _kv_tree(3)
    for dt in ("native", "bf16", "int8"):
        _, actual = quantize_kv(x, dt)
        assert quantized_nbytes(x, dt) == actual, dt


def test_native_passthrough_is_lossless():
    x = _kv_tree(2)
    pay, nbytes = quantize_kv(x, "native")
    back = dequantize_kv(pay)
    for k in x:
        np.testing.assert_array_equal(np.asarray(back[k]), x[k])
    assert nbytes == payload_bytes(pay) == sum(a.size * 4 for a in x.values())


# ---------------------------------------------------------------------------
# 2. pool v2: byte budget + spill tier
# ---------------------------------------------------------------------------

def _sized_kv(i, rows):
    return {"k": np.full((1, rows, 4), float(i), np.float32)}


_ROW_BYTES = 4 * 4      # one row of a _sized_kv leaf


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 6)),
                min_size=1, max_size=40),
       st.integers(4, 20))
@settings(max_examples=40, deadline=None)
def test_pool_byte_budget_lru_property(ops, budget_rows):
    """Model check: after any put sequence the pool holds the longest
    MRU-suffix of admitted entries that fits the byte budget, bytes_used
    never exceeds the budget, and oversized entries are rejected."""
    budget = budget_rows * _ROW_BYTES
    p = HistoryKVPool(slots=None, budget_bytes=budget)
    model = {}                        # key -> nbytes, insertion order = LRU
    for key, rows in ops:
        k = f"u{key}"
        nbytes = rows * _ROW_BYTES
        admitted = p.put(k, "f", _sized_kv(key, rows))
        if nbytes > budget:
            assert not admitted
        else:
            assert admitted
            model.pop(k, None)
            model[k] = nbytes
            while sum(model.values()) > budget:
                del model[next(iter(model))]          # strict LRU
        st_ = p.stats()
        assert st_["bytes"] <= budget
        assert p.keys() == list(model)
        assert st_["bytes"] == sum(model.values())


def test_pool_budget_and_slots_combine():
    p = HistoryKVPool(slots=2, budget_bytes=100 * _ROW_BYTES)
    for i in range(4):
        p.put(f"u{i}", "f", _sized_kv(i, 1))
    assert len(p) == 2 and p.keys() == ["u2", "u3"]   # slot bound still binds


def test_pool_spill_reload_identity():
    """An entry demoted to the host tier and promoted back must reload
    bitwise-identically (device -> host -> device round trip)."""
    ent = payload_bytes(quantize_kv(_sized_kv(0, 8), "native")[0])
    p = HistoryKVPool(slots=1, spill_bytes=8 * ent)
    kv0 = _kv_tree(7, shape=(1, 2, 8, 2, 4))
    p.put("a", "fa", kv0)
    p.put("b", "fb", _kv_tree(8, shape=(1, 2, 8, 2, 4)))   # a -> spill tier
    s = p.stats()
    assert s["spill_entries"] == 1 and s["spill_bytes"] > 0
    got = p.get("a", "fa")                                  # promote
    for k in kv0:
        np.testing.assert_array_equal(np.asarray(got[k]), kv0[k])
    s = p.stats()
    assert s["spill_hits"] == 1 and s["hits"] == 1
    # promotion re-admits under the slot bound: b was demoted in turn
    assert p.keys() == ["a"] and s["spill_entries"] == 1


def test_pool_spill_respects_budget():
    ent = payload_bytes(quantize_kv(_sized_kv(0, 4), "native")[0])
    p = HistoryKVPool(slots=1, spill_bytes=2 * ent)
    for i in range(5):
        p.put(f"u{i}", "f", _sized_kv(i, 4))
    s = p.stats()
    assert s["spill_bytes"] <= 2 * ent and s["spill_entries"] <= 2


def test_pool_stale_returns_extension_basis():
    p = HistoryKVPool(slots=4)
    p.put("u", "f1", _sized_kv(1, 4), hist_window=np.arange(8, dtype=np.int32))
    kv, status, basis = p.lookup("u", "f2", want_basis=True)
    assert kv is None and status == "stale"
    np.testing.assert_array_equal(basis.hist_window, np.arange(8))
    np.testing.assert_array_equal(np.asarray(basis.kv["k"]),
                                  _sized_kv(1, 4)["k"])
    assert len(p) == 0                   # stale entry is dropped either way


# ---------------------------------------------------------------------------
# 3. incremental-extension substrate
# ---------------------------------------------------------------------------

def test_causal_q_offset_matches_monolithic():
    """Suffix rows of a causal pass == causal attention of just those rows
    with q_offset, for all three impls (the extend_history substrate)."""
    S, P = 128, 37
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, S, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, 2, 32), jnp.float32)
    full = A.reference_attention(q, k, v, "causal")[:, P:]
    ref = A.reference_attention(q[:, P:], k, v, "causal", q_offset=P)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(full))
    ch = A.chunked_attention(q[:, P:], k, v, "causal", q_chunk=32, k_chunk=32,
                             q_offset=P)
    np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                               atol=2e-5, rtol=2e-5)
    from repro.kernels.flash_attention import ops as fa_ops
    pl = fa_ops.flash_attention(q[:, P:], k, v, "causal", q_offset=P,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_chunked_block_skip_unchanged_numerics():
    """The exact-causal block skip must not change chunked outputs (skipped
    blocks were numerically inert in the online softmax)."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 200, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 200, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 200, 2, 32), jnp.float32)
    for mode, kw in (("causal", {}), ("sumi", {"n_history": 150})):
        ref = A.reference_attention(q, k, v, mode, **kw)
        ch = A.chunked_attention(q, k, v, mode, q_chunk=64, k_chunk=32, **kw)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_chunked_visible_blocks_are_trimmed():
    """Structural check of the §Perf claim: the causal/sumi jnp paths visit
    only the mask-visible KV chunks, not all of them."""
    vis = A._visible_kv_blocks("causal", 0, q_chunk=32, k_chunk=32, nk=8,
                               sk=256, n_history=0, q_offset=0)
    assert vis == [0]                      # first q chunk sees one KV chunk
    vis = A._visible_kv_blocks("causal", 7, q_chunk=32, k_chunk=32, nk=8,
                               sk=256, n_history=0, q_offset=0)
    assert vis == list(range(8))           # last sees all
    # cached-candidate path: history chunks + own diagonal only
    vis = A._visible_kv_blocks("sumi", 3, q_chunk=16, k_chunk=32, nk=8,
                               sk=256, n_history=128, q_offset=128)
    assert vis == [0, 1, 2, 3, 5]          # 4 history chunks + self chunk


@pytest.fixture(scope="module")
def climber():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=3000, d_model=128, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    params, _ = C.climber_init(jax.random.key(0), cfg)
    ks = jax.random.split(jax.random.key(1), 3)
    batch = {"history": jax.random.randint(ks[0], (2, 64), 0, 3000),
             "candidates": jax.random.randint(ks[1], (2, 16), 0, 3000),
             "side": jax.random.normal(ks[2], (2, 12))}
    return cfg, params, batch


@pytest.mark.parametrize("impl", ["reference", "chunked"])
@pytest.mark.parametrize("prefix_len", [0, 20, 32, 50, 64])
def test_extend_history_bitwise_vs_full_reencode(climber, impl, prefix_len):
    """The acceptance gate: re-encoding only the suffix + side token against
    a cached prefix is bitwise-identical to a full re-encode whenever the
    trusted prefix actually matches (any prefix length, both jnp impls)."""
    cfg, params, batch = climber
    n = batch["history"].shape[1]
    rng = np.random.default_rng(3)
    hist2 = np.array(batch["history"])
    if prefix_len < n:
        hist2[:, prefix_len:] = rng.integers(0, 3000, (2, n - prefix_len))
    b2 = {"history": jnp.asarray(hist2),
          "side": batch["side"] + 0.5}        # side always moves
    kv1 = C.encode_history(params, batch, cfg, impl=impl)
    fresh = C.encode_history(params, b2, cfg, impl=impl)
    ext = C.extend_history(params, kv1, b2, cfg, prefix_len=prefix_len,
                           impl=impl)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ext, fresh)
    # and the scores built on the extended KV match exactly too
    s_ext = C.score_candidates(params, ext, batch["candidates"], cfg,
                               impl=impl)
    s_new = C.score_candidates(params, fresh, batch["candidates"], cfg,
                               impl=impl)
    np.testing.assert_array_equal(np.asarray(s_ext), np.asarray(s_new))


def test_extend_history_side_only_refresh(climber):
    """The dominant serving case: history window unchanged, side features
    moved (tail-append beyond the window) — prefix_len == n re-encodes one
    token per block and still matches a full re-encode bitwise."""
    cfg, params, batch = climber
    b2 = {"history": batch["history"], "side": batch["side"] * -0.3}
    kv1 = C.encode_history(params, batch, cfg)
    fresh = C.encode_history(params, b2, cfg)
    ext = C.extend_history(params, kv1, b2, cfg,
                           prefix_len=batch["history"].shape[1])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ext, fresh)


def test_history_item_kv_is_side_independent(climber):
    """The property the extension relies on: with the side token riding at
    the END of each block prefix, the history-item K/V rows (positions
    0..w-1) must not depend on the side features at all."""
    cfg, params, batch = climber
    kv1 = C.encode_history(params, batch, cfg)
    kv2 = C.encode_history(params, dict(batch, side=batch["side"] + 9.0), cfg)
    w = batch["history"].shape[1] // cfg.climber.num_blocks
    for b in kv1:
        for kk in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(kv1[b][kk][:, :, :w]),
                np.asarray(kv2[b][kk][:, :, :w]))
            assert np.abs(np.asarray(kv1[b][kk][:, :, w])
                          - np.asarray(kv2[b][kk][:, :, w])).max() > 1e-6


# ---------------------------------------------------------------------------
# 4. serving stack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=5_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def _engine(bundle, params, **kw):
    from repro.core.pda import RemoteFeatureStore
    from repro.serving import FlameEngine
    base = dict(n_history=64, buckets=(16, 8), n_streams=2,
                feature_mode="sync",
                store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                window_s=0.004, max_batch=2, n_workers=2)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


def test_engine_tail_append_uses_extension(serving_setup):
    """Same user, history extended beyond the model window: the stale hit
    must be served by suffix extension (one token per block), and the
    scores must match a from-scratch engine on the new history."""
    cfg, bundle, params = serving_setup
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  incremental_history=True)
    fresh = _engine(bundle, params, history_cache=True, pool_slots=4)
    rng = np.random.default_rng(0)
    h1 = rng.integers(0, 5000, 80).astype(np.int32)          # window = 64
    h2 = np.concatenate([h1, rng.integers(0, 5000, 8).astype(np.int32)])
    cand = rng.integers(0, 5000, 12).astype(np.int32)
    try:
        eng.serve(h1, cand, user_id=1)                       # encode
        out = eng.serve(h2, cand, user_id=1)                 # stale -> extend
        m = eng.metrics()
        assert m["pool_extensions"] == 1 and m["pool_stale"] == 1
        assert m["dso_dispatches_extend"] == 1
        assert m["dso_dispatches_encode"] == 1               # only the first
        ref = fresh.serve(h2, cand, user_id=9)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32),
                                   atol=2e-3, rtol=2e-3)
        # a subsequent identical request is a plain hit on the extended entry
        again = eng.serve(h2, cand, user_id=1)
        np.testing.assert_array_equal(out, again)
    finally:
        eng.shutdown()
        fresh.shutdown()


def test_engine_unrelated_history_reencodes(serving_setup):
    """A stale hit with NO shared window prefix must fall back to a full
    re-encode (extension buckets exist but none fits)."""
    cfg, bundle, params = serving_setup
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  incremental_history=True, extend_buckets=(64, 32))
    rng = np.random.default_rng(1)
    h1 = rng.integers(0, 5000, 64).astype(np.int32)
    h2 = rng.integers(0, 5000, 64).astype(np.int32)          # fresh draw
    assert h1[0] != h2[0]        # shared prefix < smallest bucket (32)
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        eng.serve(h1, cand, user_id=2)
        eng.serve(h2, cand, user_id=2)
        m = eng.metrics()
        assert m["pool_extensions"] == 0
        assert m["dso_dispatches_encode"] == 2
    finally:
        eng.shutdown()


def test_engine_partial_prefix_extension(serving_setup):
    """A mid-window history change extends from the largest trusted-prefix
    bucket <= the shared prefix, and scores still match a fresh engine."""
    cfg, bundle, params = serving_setup
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  incremental_history=True, extend_buckets=(64, 32))
    fresh = _engine(bundle, params, history_cache=True, pool_slots=4)
    rng = np.random.default_rng(2)
    h1 = rng.integers(0, 5000, 64).astype(np.int32)
    h2 = h1.copy()
    h2[40:] = rng.integers(0, 5000, 24)                      # shared prefix 40
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        eng.serve(h1, cand, user_id=3)
        out = eng.serve(h2, cand, user_id=3)                 # extend @ 32
        m = eng.metrics()
        assert m["pool_extensions"] == 1
        ref = fresh.serve(h2, cand, user_id=9)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32),
                                   atol=2e-3, rtol=2e-3)
    finally:
        eng.shutdown()
        fresh.shutdown()


@pytest.mark.parametrize("pool_dtype", ["native", "int8"])
def test_engine_multi_chunk_dedup_correctness(serving_setup, pool_dtype):
    """A request split into same-bucket chunks rides one dispatch with its
    KV rows stacked ONCE; scores must match the full-pass engine and stay
    bitwise-stable across repeats.  The int8 variant exercises the
    (key, fingerprint) dedup token: quantized lookups dequantize to fresh
    arrays, so object identity alone could never match."""
    cfg, bundle, params = serving_setup
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  window_s=0.02, kv_dedup=True, pool_dtype=pool_dtype)
    eng_full = _engine(bundle, params)
    rng = np.random.default_rng(4)
    hist = rng.integers(0, 5000, 64).astype(np.int32)
    cand = rng.integers(0, 5000, 32).astype(np.int32)        # 2x bucket 16
    try:
        a = eng.serve(hist, cand, user_id=5)
        m = eng.metrics()
        assert m["dso_dedup_rows_saved"] >= 1
        b = eng_full.serve(hist, cand)
        np.testing.assert_allclose(a.astype(np.float32),
                                   b.astype(np.float32),
                                   atol=2e-3, rtol=2e-3)
        # repeat-stability must be bitwise hit-to-hit (the int8 miss path
        # scores against the pre-quantization KV, so compare two hits)
        hit1 = eng.serve(hist, cand, user_id=5)
        hit2 = eng.serve(hist, cand, user_id=5)
        np.testing.assert_array_equal(hit1, hit2)
        np.testing.assert_allclose(hit1.astype(np.float32),
                                   a.astype(np.float32),
                                   atol=2e-2, rtol=2e-2)
    finally:
        eng.shutdown()
        eng_full.shutdown()


def test_engine_int8_pool_score_drift_bound(serving_setup):
    """int8 pool entries must keep hit-path scores within the stated drift
    bound of a native pool (the users-per-replica trade documented in
    docs/ARCHITECTURE.md)."""
    cfg, bundle, params = serving_setup
    rng = np.random.default_rng(5)
    hist = rng.integers(0, 5000, 64).astype(np.int32)
    cand = rng.integers(0, 5000, 12).astype(np.int32)
    outs, bytes_ = {}, {}
    for dt in ("native", "int8"):
        eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                      pool_dtype=dt)
        try:
            eng.serve(hist, cand, user_id=6)          # miss: encode + put
            outs[dt] = eng.serve(hist, cand, user_id=6)   # hit through pool
            bytes_[dt] = eng.metrics()["pool_bytes"]
        finally:
            eng.shutdown()
    drift = np.abs(outs["int8"].astype(np.float32)
                   - outs["native"].astype(np.float32)).max()
    assert drift <= INT8_SCORE_DRIFT_BOUND, drift
    assert bytes_["int8"] < bytes_["native"] * 0.62   # bf16-native leaves


def test_engine_byte_budget_evicts_and_reports(serving_setup):
    """pool_budget_bytes bounds the engine's pool; bytes_used surfaces as a
    ServeMetrics gauge and never exceeds the budget."""
    cfg, bundle, params = serving_setup
    probe = _engine(bundle, params, history_cache=True, pool_slots=64)
    rng = np.random.default_rng(6)
    hists = [rng.integers(0, 5000, 64).astype(np.int32) for _ in range(4)]
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        probe.serve(hists[0], cand, user_id=0)
        entry = probe.metrics()["pool_bytes"]
    finally:
        probe.shutdown()
    budget = int(entry * 2.5)                       # fits 2 entries
    eng = _engine(bundle, params, history_cache=True, pool_slots=64,
                  pool_budget_bytes=budget)
    try:
        for u, h in enumerate(hists):
            eng.serve(h, cand, user_id=u)
        m = eng.metrics()
        assert m["pool_entries"] == 2
        assert m["pool_evictions"] == 2
        assert m["pool_bytes"] <= budget
        assert m["pool_bytes_used"] == m["pool_bytes"]    # ServeMetrics gauge
    finally:
        eng.shutdown()
