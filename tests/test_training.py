"""Training substrate: AdamW, loss descent, checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import GRInteractionDataset, TokenDataset, make_batch_iterator
from repro.models import build_model
from repro.training import checkpoint
from repro.training.loop import train
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      global_norm)


def test_adamw_quadratic_convergence():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    _, _, m = adamw_update(cfg, {"w": jnp.full(3, 100.0)}, opt, params)
    assert float(m["grad_norm"]) > 100.0


def test_global_norm():
    assert abs(float(global_norm({"a": jnp.array([3.0]),
                                  "b": jnp.array([4.0])})) - 5.0) < 1e-6


def test_lm_loss_decreases():
    cfg = reduced_config("h2o-danube-3-4b")
    bundle = build_model(cfg)
    ds = TokenDataset(vocab_size=cfg.vocab_size, branching=4)
    it = make_batch_iterator(ds, 8, seq_len=64)
    _, _, hist = train(bundle, it, 40, AdamWConfig(lr=2e-3, warmup_steps=5),
                       log_every=40)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.05


def test_climber_training_learns_signal():
    """Climber trained on planted-preference data beats the trivial loss."""
    import dataclasses
    from repro.configs import get_config
    from repro.types import ClimberConfig
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=2000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    ds = GRInteractionDataset(n_items=2000, n_users=200, seed=0)
    it = make_batch_iterator(ds, 16, n_history=32, n_candidates=8)
    _, _, hist = train(bundle, it, 60, AdamWConfig(lr=3e-3, warmup_steps=5),
                       log_every=60, impl="reference")
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip():
    cfg = reduced_config("gemma3-12b")
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        checkpoint.save(path, params, step=42)
        restored, step = checkpoint.restore(path, params)
        assert step == 42
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.msgpack")
        checkpoint.save(path, {"a": jnp.zeros(2)}, step=0)
        with pytest.raises(KeyError):
            checkpoint.restore(path, {"a": jnp.zeros(2), "b": jnp.zeros(3)})
