"""DSO v2: segment-packed ragged dispatch + deadline-aware flushing.

Layers of coverage:

  1. packer fuzz — :class:`SegmentPacker` placements never split a segment
     across rows (a segment IS one request's chunk, so no segment ever
     crosses a request boundary), never overlap within a row, never exceed
     the row/KV capacity, and same-identity segments share one KV slot;
  2. EDF flush order — pending chunks pop earliest-deadline-first with a
     shortest-remaining-work tie-break (deadline-less chunks last), and
     deadline overruns land in the ``deadline_misses`` metric;
  3. model-level packing parity — ``score_candidates`` with a
     per-candidate seg index is BITWISE identical to the unpacked
     per-user rows, per impl reference/chunked/fused, across ragged
     segment layouts including 1-candidate segments;
  4. engine level — the packed engine's concurrent scores are bitwise
     the same engine's sequential scores (the coalescing contract; one
     executable, placement-invariant), packed-vs-unpacked engines agree
     at the cross-AOT-executable tolerance with ``padded_fraction``
     reduced, and the quantized extend basis ships raw (no host dequant).
"""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._propcheck import given, settings, st

from repro.configs import get_config
from repro.core.dso import (CoalescePolicy, CoalescingOrchestrator,
                            SegmentPacker, _PendingChunk)
from repro.core.pda import RemoteFeatureStore
from repro.models import build_model
from repro.serving import (DeadlineExceeded, FlameEngine, ServeMetrics,
                           ServeRequest)
from repro.serving.kv_cache import (HistoryKVPool, dequantize_kv,
                                    quantize_kv, raw_kv_view)
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload_async)
from repro.types import ClimberConfig


@pytest.fixture(scope="module")
def climber_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=10_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def _store():
    return RemoteFeatureStore(latency_s=0.0, feature_dim=12)


def _flame(bundle, params, **kw):
    base = dict(n_history=64, buckets=(32, 16), n_streams=2,
                feature_mode="off", store=_store(), window_s=0.01,
                max_batch=4, n_workers=4, history_cache=True, pool_slots=32)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


# ---------------------------------------------------------------------------
# 1. packer fuzz
# ---------------------------------------------------------------------------

SEGMENTS = st.lists(st.tuples(st.integers(1, 16), st.integers(0, 5)),
                    min_size=1, max_size=40)


@given(SEGMENTS, st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=150, deadline=None)
def test_packer_invariants(segs, max_rows, max_kv):
    bucket = 16
    packer = SegmentPacker(bucket, max_rows, max_kv)
    placed = []
    for valid, ident in segs:
        p = packer.try_add(valid, ident)
        if p is not None:
            placed.append((valid, ident, p))
    assert placed, "an empty packer must accept any bucket-sized segment"
    rows = {}
    for valid, ident, (row, off, slot) in placed:
        # a segment never crosses a row (request) boundary
        assert 0 <= row < max_rows
        assert 0 <= off and off + valid <= bucket
        # same identity -> same KV slot, distinct identities stay bounded
        assert slot == packer.slot_of[ident]
        rows.setdefault(row, []).append((off, off + valid))
    assert packer.n_slots <= max_kv
    assert len(rows) == packer.n_rows <= max_rows
    for intervals in rows.values():
        intervals.sort()
        for (a0, a1), (b0, b1) in zip(intervals, intervals[1:]):
            assert a1 <= b0, "segments overlap within a row"
    # fill accounting matches the placements
    for row, intervals in rows.items():
        assert packer.fills[row] == sum(b - a for a, b in intervals)


def test_packer_rejects_oversized_and_fills():
    p = SegmentPacker(8, max_rows=2, max_kv=2)
    with pytest.raises(ValueError):
        p.try_add(9, "a")
    assert p.try_add(8, "a") == (0, 0, 0)
    assert p.try_add(5, "b") == (1, 0, 1)
    assert p.try_add(4, "a") is None        # no row has 4 slots left
    assert p.try_add(3, "c") is None        # KV capacity exhausted
    assert p.try_add(3, "b") == (1, 5, 1)   # existing ident still packs
    assert p.is_full()


# ---------------------------------------------------------------------------
# 2. EDF ordering + deadline accounting
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 1000), st.integers(1, 64)),
                min_size=2, max_size=12))
@settings(max_examples=100, deadline=None)
def test_pending_chunk_edf_ordering(items):
    """Heap order: earliest deadline first (None last), then shortest
    remaining work, then FIFO sequence."""
    chunks = []
    for dl, rem in items:
        chunks.append(_PendingChunk(
            args=(), future=None,
            deadline=None if dl == 0 else float(dl), remaining=rem))
    got = sorted(chunks)
    keys = [(c.deadline if c.deadline is not None else float("inf"),
             c.remaining, c.seq) for c in got]
    assert keys == sorted(keys)


def test_orchestrator_flushes_in_edf_order():
    """Preloaded same-bucket chunks dispatch earliest-deadline-first with
    SRW tie-breaks, not FIFO."""
    order = []

    def build(bucket, batch):
        fn = jax.jit(lambda x: x * 2.0).lower(
            jax.ShapeDtypeStruct((batch, bucket), jnp.float32)).compile()

        def run(x):
            order.append(int(np.asarray(x)[0, 0]))
            return fn(x)
        return run

    def pad_slice(request, chunk):
        return (request[0],)

    def gather(rows, chunks, m):
        return rows[0]

    dso = CoalescingOrchestrator(
        build, buckets=[4], pad_slice_fn=pad_slice, gather_fn=gather,
        policy=CoalescePolicy(enabled=True, max_batch=1, window_s=0.0),
        n_streams=1)
    base = 1000.0   # far-future absolute deadlines: order decided by value
    plan = [  # (tag, deadline, m-for-SRW)
        (0, base + 0.30, 4), (1, base + 0.10, 4), (2, None, 4),
        (3, base + 0.20, 4), (4, base + 0.10, 3), (5, None, 3),
    ]
    cond = dso._cond[(dso._DEFAULT_KIND, 4)]
    futs = []
    with cond:        # workers can't pop until we release the condition
        for tag, dl, m in plan:
            x = np.full((1, 4), float(tag), np.float32)
            futs.append(dso.submit((x,), m, deadline=dl))
    for f in futs:
        f.result()
    dso.shutdown()
    # EDF: 4 (dl .10, SRW 3) before 1 (dl .10, SRW 4), then .20, .30;
    # deadline-less last, SRW-ordered (5 before 2)
    assert order == [4, 1, 3, 0, 5, 2]


def test_serve_metrics_counters():
    m = ServeMetrics()
    threads = [threading.Thread(target=lambda: [m.incr("deadline_misses")
                                                for _ in range(50)])
               for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.summary()["deadline_misses"] == 200


def test_engine_deadline_miss_accounting(climber_setup):
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, pack_tails=True, deadline_s=100.0)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, 1000, 64).astype(np.int32)
    for _ in range(3):   # generous engine default: everything meets it
        eng.serve(hist, rng.integers(0, 1000, 12).astype(np.int32),
                  user_id=1)
    m = eng.metrics()
    assert m.get("deadline_met", 0) == 3 and "deadline_misses" not in m
    # per-request override: a 1ns budget that is still live at admission
    # (arrival stamped slightly in the future, so the admission check
    # passes deterministically) must be MISSED by the worker
    fut = eng.submit(ServeRequest(
        history=hist, candidates=rng.integers(0, 1000, 12).astype(np.int32),
        user_id=1, deadline_s=1e-9,
        arrival_t=time.perf_counter() + 5e-4))
    fut.result(timeout=60)
    assert eng.metrics()["deadline_misses"] == 1
    # a budget already exhausted when submit() runs is SHED at admission:
    # no executor work, no ResponseFuture, a dedicated counter
    with pytest.raises(DeadlineExceeded):
        eng.submit(ServeRequest(
            history=hist,
            candidates=rng.integers(0, 1000, 12).astype(np.int32),
            user_id=1, deadline_s=1e-9,
            arrival_t=time.perf_counter() - 1.0))
    m = eng.metrics()
    assert m["deadline_shed"] == 1
    assert m["deadline_misses"] == 1    # shedding is not a miss
    eng.shutdown()


# ---------------------------------------------------------------------------
# 3. model-level packing parity (bitwise, per impl)
# ---------------------------------------------------------------------------

RAGGED_LAYOUTS = [
    # (m_total, segments as (count, user)) — incl. 1-candidate segments
    (1, ((1, 0),)),
    (7, ((3, 0), (4, 2))),
    (16, ((1, 1), (1, 0), (14, 2))),
    (16, ((5, 0), (11, 1))),
]


@pytest.mark.parametrize("impl", ["reference", "chunked", "fused"])
def test_packed_scoring_bitwise_vs_unpacked(climber_setup, impl):
    """score_candidates over a segment-packed row == the same candidates
    scored on unpacked per-user rows, for every impl.

    reference/chunked are BITWISE: the packed segment attention mirrors
    the reference op sequence with identical reduction lengths, and masked
    co-segment positions contribute exact zeros.  The fused jnp path is
    gated at a tight tolerance instead: its per-candidate gathered einsum
    contracts the same dot products but XLA may reassociate the head-dim
    reduction differently than the shared-history GEMM (low-bit only;
    engine-level packed-vs-unpacked rides the same cross-executable
    tolerance every other A/B in this repo uses)."""
    cfg, bundle, params = climber_setup
    rng = np.random.default_rng(3)
    n_hist = 64
    kvs = []
    for u in range(3):
        batch = {"history": jnp.asarray(
            rng.integers(0, 10_000, (1, n_hist)).astype(np.int32)),
            "side": jnp.asarray(rng.standard_normal((1, 12)), jnp.float32)}
        kvs.append(bundle.encode_history(params, batch, impl="chunked"))
    kv_stack = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *kvs)

    for m_total, segments in RAGGED_LAYOUTS:
        cand = rng.integers(0, 10_000, (1, m_total)).astype(np.int32)
        seg = np.zeros((1, m_total), np.int32)
        off = 0
        for count, user in segments:
            seg[0, off:off + count] = user
            off += count
        assert off == m_total
        packed = np.asarray(bundle.score_candidates(
            params, kv_stack, jnp.asarray(cand), impl=impl,
            row_index=jnp.asarray(seg)))
        off = 0
        for count, user in segments:
            unpacked = np.asarray(bundle.score_candidates(
                params, kvs[user], jnp.asarray(cand), impl=impl))
            a, b = packed[0, off:off + count], unpacked[0, off:off + count]
            if impl == "fused":
                np.testing.assert_allclose(
                    a, b, atol=1e-3, rtol=0,
                    err_msg=f"impl={impl} layout={segments} segment@{off}")
            else:
                np.testing.assert_array_equal(
                    a, b,
                    err_msg=f"impl={impl} layout={segments} segment@{off}")
            off += count


def test_packed_extend_index_rejected(climber_setup):
    """Suffix extension is causal — the per-candidate seg index must be
    rejected, not silently mis-scored."""
    from repro.core import sumi
    k = jax.random.normal(jax.random.key(0), (1, 4, 2, 16))
    with pytest.raises(ValueError, match="causal"):
        sumi.extend_attention(k, k, k, k, k, impl="chunked",
                              row_index=jnp.zeros((1, 4), jnp.int32))


# ---------------------------------------------------------------------------
# 4. engine level
# ---------------------------------------------------------------------------

def _ragged_requests(n, seed=5, n_users=4, n_hist=64):
    tc = TrafficConfig(candidate_counts=(3, 7, 19, 33),
                       distribution="jittered", n_requests=n,
                       n_history=n_hist, seed=seed, n_users=n_users)
    reqs = generate_traffic(tc, n_items=10_000)
    rng = np.random.default_rng(seed + 1)
    for u in range(2):   # M=1 rides along (the hardest ragged case)
        reqs.append({"history": reqs[u]["history"],
                     "user_id": reqs[u]["user_id"],
                     "candidates": rng.integers(0, 10_000, 1)
                     .astype(np.int32)})
    return reqs


@pytest.mark.parametrize("impl", ["chunked", "fused"])
def test_packed_engine_concurrent_bitwise_matches_sequential(climber_setup,
                                                             impl):
    """The tentpole contract: concurrent packed serving (segments of many
    requests sharing rows at arbitrary offsets) is bitwise-identical to
    the same engine serving sequentially — one executable, and segment
    placement is bitwise-invariant."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, pack_tails=True, impl=impl)
    reqs = _ragged_requests(14)
    for r in reqs[:6]:   # warm the pool: hot-hit steady state
        eng.serve(r["history"], r["candidates"], user_id=r.get("user_id"))
    sequential = [eng.serve(r["history"], r["candidates"],
                            user_id=r.get("user_id")) for r in reqs]
    concurrent = run_workload_async(eng, reqs)["outputs"]
    for s, c in zip(sequential, concurrent):
        np.testing.assert_array_equal(s, c)
    m = eng.metrics()
    assert m["dso_packed_segments"] > 0
    eng.shutdown()


def test_packed_engine_matches_unpacked_and_reclaims_padding(climber_setup):
    """Packed vs unpacked engines: scores agree at the cross-AOT-executable
    tolerance (different XLA fusions; bitwise is asserted within one
    executable above and at the model level), and the packed side
    dispatches measurably less candidate padding."""
    cfg, bundle, params = climber_setup
    reqs = _ragged_requests(16)
    outs, engines = {}, {}
    for pack in (False, True):
        eng = _flame(bundle, params, pack_tails=pack, impl="fused")
        for r in reqs[:6]:
            eng.serve(r["history"], r["candidates"],
                      user_id=r.get("user_id"))
        outs[pack] = run_workload_async(eng, reqs)["outputs"]
        engines[pack] = eng
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=0)
    pf_un = engines[False].metrics()["dso_padded_fraction"]
    pf_pk = engines[True].metrics()["dso_padded_fraction"]
    m = engines[True].metrics()
    assert m["dso_packed_segments"] > 0 and m["dso_packed_rows"] > 0
    assert pf_pk < pf_un, (pf_pk, pf_un)
    # the padded-fraction / queue-delay gauges surface through ServeMetrics
    assert "padded_fraction" in m and "queue_delay_ms" in m
    for eng in engines.values():
        eng.shutdown()


def test_pack_tails_requires_history_cache(climber_setup):
    cfg, bundle, params = climber_setup
    with pytest.raises(ValueError, match="history_cache"):
        _flame(bundle, params, history_cache=False, pack_tails=True)


# ---------------------------------------------------------------------------
# 5. quantized extend basis (raw, no host dequant)
# ---------------------------------------------------------------------------

def test_pool_raw_basis_returns_stored_representation(climber_setup):
    cfg, bundle, params = climber_setup
    pool = HistoryKVPool(4, dtype="int8")
    kv = {"b0": {"k": np.ones((1, 2, 5, 2, 16), np.float32)}}
    pool.put("u", "fp0", kv, hist_window=np.arange(5))
    _, status, basis = pool.lookup("u", "fp-new", want_basis=True,
                                   raw_basis=True)
    assert status == "stale"
    leaf = basis.kv["b0"]["k"]
    assert isinstance(leaf, tuple)
    values, scale = leaf
    assert values.dtype == np.int8 and scale.dtype == np.float32


def test_extend_history_raw_basis_bitwise(climber_setup):
    """extend_history over a RAW (stored int8) basis == the same extension
    over the host-dequantized basis, bit for bit — the in-graph dequant is
    the same formula as the pool's dequantize_leaf."""
    cfg, bundle, params = climber_setup
    rng = np.random.default_rng(11)
    n = 64
    batch = {"history": jnp.asarray(
        rng.integers(0, 10_000, (1, n)).astype(np.int32)),
        "side": jnp.asarray(rng.standard_normal((1, 12)), jnp.float32)}
    kv = bundle.encode_history(params, batch, impl="chunked")
    payload, _ = quantize_kv(jax.tree.map(np.asarray, kv), "int8")
    for impl in ("chunked", "fused"):
        out_raw = bundle.extend_history(params, raw_kv_view(payload), batch,
                                        prefix_len=n, impl=impl)
        out_deq = bundle.extend_history(params, dequantize_kv(payload),
                                        batch, prefix_len=n, impl=impl)
        for a, b in zip(jax.tree.leaves(out_raw), jax.tree.leaves(out_deq)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_incremental_engine_extends_from_raw_basis(climber_setup):
    """End to end: the fused int8 engine serves tail-append (stale) traffic
    through the extend family compiled against raw pool specs."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, pack_tails=True, impl="fused",
                 pool_dtype="int8", incremental_history=True)
    rng = np.random.default_rng(2)
    hists = {u: rng.integers(0, 10_000, 80).astype(np.int32)
             for u in range(3)}
    outs = []
    for _ in range(3):
        for u in range(3):
            hists[u] = np.concatenate(
                [hists[u], rng.integers(0, 10_000, 4).astype(np.int32)])
            outs.append(eng.serve(
                hists[u], rng.integers(0, 10_000, 9).astype(np.int32),
                user_id=u))
    m = eng.metrics()
    assert m["pool_extensions"] > 0
    assert all(np.isfinite(o).all() for o in outs)
    eng.shutdown()
