"""API v2: registry, pipeline, backpressure, and the coalescing DSO's two
contract guarantees — bitwise-identical scores and fewer dispatches."""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.pda import RemoteFeatureStore
from repro.models import build_model
from repro.serving import (AdmissionQueueFull, FlameEngine, ServeMetrics,
                           ServeRequest, ServingEngine, available_engines,
                           create_engine)
from repro.serving.scheduler import (TrafficConfig, generate_traffic,
                                     run_workload_async)
from repro.types import ClimberConfig


@pytest.fixture(scope="module")
def climber_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=10_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def _store():
    return RemoteFeatureStore(latency_s=0.0, feature_dim=12)


def _flame(bundle, params, **kw):
    base = dict(n_history=64, buckets=(32, 16), n_streams=2,
                feature_mode="off", store=_store(), window_s=0.05)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


def test_registry_names_and_unknown():
    assert {"flame", "implicit", "text"} <= set(available_engines())
    with pytest.raises(KeyError, match="unknown engine"):
        create_engine("nope")


def test_engines_satisfy_protocol(climber_setup):
    cfg, bundle, params = climber_setup
    eng = create_engine("flame", bundle, params, n_history=64,
                        buckets=(16,), feature_mode="off", store=_store())
    assert isinstance(eng, ServingEngine)
    eng.shutdown()


def test_submit_returns_future_with_response(climber_setup):
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params)
    rng = np.random.default_rng(0)
    req = ServeRequest(history=rng.integers(0, 1000, 64).astype(np.int32),
                       candidates=rng.integers(0, 1000, 24).astype(np.int32))
    fut = eng.submit(req)
    resp = fut.result(timeout=60)
    assert resp.request_id == req.request_id
    assert resp.output.shape == (24, 3)
    assert resp.latency_s > 0
    assert {"queue_s", "features_s", "execute_s"} <= set(resp.timings)
    m = eng.metrics()
    assert m["requests"] == 1 and m["dso_chunks"] == 2
    eng.shutdown()


def test_coalesced_concurrent_bitwise_matches_sequential(climber_setup):
    """The tentpole correctness contract: scores under concurrent jittered
    traffic (chunks coalesced across requests) are bitwise-identical to the
    same engine serving the same requests one at a time."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, coalesce=True, max_batch=4, n_workers=4)
    tc = TrafficConfig(candidate_counts=(16, 32, 64), distribution="jittered",
                       n_requests=12, n_history=64, seed=7)
    reqs = generate_traffic(tc, n_items=10_000)
    sequential = [eng.serve(r["history"], r["candidates"]) for r in reqs]
    concurrent = run_workload_async(eng, reqs)["outputs"]
    for s, c in zip(sequential, concurrent):
        np.testing.assert_array_equal(s, c)
    eng.shutdown()


def test_coalescing_reduces_dispatch_count(climber_setup):
    """16 single-chunk requests (M == smallest bucket) in flight together:
    with coalescing the dispatcher must merge chunks from different requests
    (dispatches < chunks); without it, every chunk dispatches alone."""
    cfg, bundle, params = climber_setup
    rng = np.random.default_rng(3)
    reqs = [{"history": rng.integers(0, 1000, 64).astype(np.int32),
             "candidates": rng.integers(0, 1000, 16).astype(np.int32)}
            for _ in range(16)]

    on = _flame(bundle, params, buckets=(16,), coalesce=True, max_batch=4,
                n_workers=4)
    run_workload_async(on, reqs)
    m_on = on.metrics()
    on.shutdown()
    assert m_on["dso_chunks"] == 16
    assert m_on["dso_dispatches"] < m_on["dso_chunks"]
    assert m_on["dso_avg_fill"] > 1.0

    off = _flame(bundle, params, buckets=(16,), coalesce=False, n_workers=4)
    run_workload_async(off, reqs)
    m_off = off.metrics()
    off.shutdown()
    assert m_off["dso_dispatches"] == m_off["dso_chunks"] == 16
    assert m_off["dso_batch_axis"] == 1


def test_admission_queue_backpressure(climber_setup):
    """n_workers=0 never drains: the bounded queue must fill and submit
    must raise AdmissionQueueFull instead of growing without bound."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, buckets=(16,), max_pending=2, n_workers=0)
    rng = np.random.default_rng(0)

    def req():
        return ServeRequest(
            history=rng.integers(0, 1000, 64).astype(np.int32),
            candidates=rng.integers(0, 1000, 16).astype(np.int32))

    eng.submit(req(), timeout=0)
    eng.submit(req(), timeout=0)
    with pytest.raises(AdmissionQueueFull):
        eng.submit(req(), timeout=0)
    assert eng.metrics()["pending"] == 2
    eng.shutdown()


def test_malformed_request_fails_alone(climber_setup):
    """A bad-shape request must fail through its own future *before* its
    chunks reach the shared coalescing queue — co-riding healthy requests
    must be unaffected."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, n_workers=2)
    rng = np.random.default_rng(5)
    bad = ServeRequest(history=rng.integers(0, 1000, 10).astype(np.int32),
                       candidates=rng.integers(0, 1000, 16).astype(np.int32))
    good = ServeRequest(history=rng.integers(0, 1000, 64).astype(np.int32),
                        candidates=rng.integers(0, 1000, 16).astype(np.int32))
    fb, fg = eng.submit(bad), eng.submit(good)
    with pytest.raises(ValueError, match="history"):
        fb.result(timeout=60)
    assert fg.result(timeout=60).output.shape == (16, 3)
    with pytest.raises(ValueError, match="candidates"):
        eng.submit(ServeRequest(
            history=rng.integers(0, 1000, 64).astype(np.int32),
            candidates=None)).result(timeout=60)
    eng.shutdown()


def test_implicit_engine_same_protocol(climber_setup):
    cfg, bundle, params = climber_setup
    eng = create_engine("implicit", bundle, params, n_history=64,
                        feature_mode="off", store=_store(), n_workers=2)
    rng = np.random.default_rng(1)
    reqs = [{"history": rng.integers(0, 1000, 64).astype(np.int32),
             "candidates": rng.integers(0, 1000, m).astype(np.int32)}
            for m in (8, 12, 8)]
    outs = run_workload_async(eng, reqs)["outputs"]
    assert [o.shape for o in outs] == [(8, 3), (12, 3), (8, 3)]
    m = eng.metrics()
    assert m["requests"] == 3
    assert m["jit_compiles"] == 2          # 8 and 12 are the novel shapes
    eng.shutdown()


def test_text_engine_submit_matches_generate():
    cfg = reduced_config("h2o-danube-3-4b")
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    eng = create_engine("text", bundle, params, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    direct = eng.generate([prompt], n_tokens=4)[0]
    resp = eng.submit(ServeRequest(history=prompt, n_tokens=4)).result(
        timeout=120)
    np.testing.assert_array_equal(resp.output, direct)
    assert eng.metrics()["requests"] == 1
    eng.shutdown()


def test_serve_metrics_record_is_thread_safe():
    m = ServeMetrics()
    n_threads, per_thread = 8, 200

    def hammer():
        for _ in range(per_thread):
            m.record(2, 0.001)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    s = m.summary()
    assert s["requests"] == n_threads * per_thread
    assert m.items == 2 * n_threads * per_thread
    assert len(m.latencies) == n_threads * per_thread
