"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._propcheck import given, settings, st

from repro.kernels.rwkv6_scan import ops as rwkv_ops
from repro.kernels.rwkv6_scan import ref as rwkv_ref
from repro.models import attention as A


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 64),
       st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_mask_modes_are_subsets_of_full(sq, sk, n_hist, window):
    """Every mask is a subset of full; causal ⊆ full; sliding ⊆ causal."""
    causal = np.asarray(A.make_mask(sq, sk, "causal"))
    sliding = np.asarray(A.make_mask(sq, sk, "sliding", window=window))
    sumi = np.asarray(A.make_mask(sq, sk, "sumi", n_history=n_hist))
    assert (~causal | np.asarray(A.make_mask(sq, sk, "full"))).all()
    assert (~sliding | causal).all()
    # every row attends to something when k covers the diagonal
    if sk >= sq:
        assert causal.any(axis=1).all()
        assert sumi.any(axis=1).all()


@given(st.integers(0, 32), st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_sumi_candidates_never_see_each_other(n_hist, m):
    mask = np.asarray(A.make_mask(n_hist + m, n_hist + m, "sumi",
                                  n_history=n_hist))
    cand = mask[n_hist:, n_hist:]
    assert (cand == np.eye(m, dtype=bool)).all()


@given(st.integers(1, 4), st.integers(8, 80), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_rwkv_chunked_equals_sequential(seed, s, h):
    """The kernel's chunked formulation == the token-by-token recurrence for
    arbitrary decays (the invariant that makes chunked serving legal)."""
    d = 16
    ks = jax.random.split(jax.random.key(seed), 5)
    r = jax.random.normal(ks[0], (1, s, h, d))
    k = jax.random.normal(ks[1], (1, s, h, d))
    v = jax.random.normal(ks[2], (1, s, h, d))
    wl = -jnp.exp(jax.random.normal(ks[3], (1, s, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    o, _ = rwkv_ops.rwkv6_scan(r, k, v, wl, u, chunk=16)

    def to_bh(x):
        return jnp.moveaxis(x, 2, 1).reshape(h, s, d)

    oref, _ = rwkv_ref.reference(to_bh(r), to_bh(k), to_bh(v), to_bh(wl),
                                 u.reshape(h, d))
    oref = jnp.moveaxis(oref.reshape(1, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               atol=5e-3, rtol=5e-3)


@given(st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_softmax_attention_rows_convex(sq_blocks, seed):
    """Attention outputs are convex combinations of V rows: bounded by
    min/max of V per dim."""
    sq = sq_blocks * 8
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, 2, 8))
    k = jax.random.normal(ks[1], (1, sq, 2, 8))
    v = jax.random.normal(ks[2], (1, sq, 2, 8))
    out = np.asarray(A.reference_attention(q, k, v, "causal"), np.float32)
    vmin = np.asarray(v, np.float32).min()
    vmax = np.asarray(v, np.float32).max()
    assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()


@given(st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_cross_entropy_uniform_bound(v):
    from repro.models.model import cross_entropy
    logits = jnp.zeros((2, 4, v))
    tgt = jnp.zeros((2, 4), jnp.int32)
    ce = float(cross_entropy(logits, tgt, jnp.ones((2, 4))))
    assert abs(ce - np.log(v)) < 1e-4
