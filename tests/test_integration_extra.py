"""Extra integration coverage: full-model Pallas path, cost-transparent
unrolling equivalence, KV-cache slot manager, data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import flags
from repro.configs import get_config, reduced_config
from repro.core.climber import climber_forward, climber_init
from repro.data import GRInteractionDataset, TokenDataset
from repro.models import attention as A
from repro.models import build_model
from repro.types import ClimberConfig


def test_full_model_pallas_path_matches_reference():
    """The FKE kernels (mask-aware flash attention + fused FFN) swap into the
    whole Climber forward and agree with the reference path."""
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=3000, d_model=128, d_ff=256,
        n_heads=4, n_kv_heads=4, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    params, _ = climber_init(jax.random.key(0), cfg)
    batch = {
        "history": jax.random.randint(jax.random.key(1), (1, 128), 0, 3000),
        "candidates": jax.random.randint(jax.random.key(2), (1, 32), 0, 3000),
        "side": jax.random.normal(jax.random.key(3), (1, 12)),
    }
    ref = climber_forward(params, batch, cfg, impl="reference")
    pal = climber_forward(params, batch, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(pal, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_cost_transparent_unroll_same_numerics():
    """Unrolled (roofline-variant) scans == scanned lowering numerically."""
    q = jax.random.normal(jax.random.key(0), (1, 256, 2, 32))
    k = jax.random.normal(jax.random.key(1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.key(2), (1, 256, 2, 32))
    base = A.chunked_attention(q, k, v, "causal", q_chunk=64, k_chunk=64)
    with flags.cost_transparent():
        unrolled = A.chunked_attention(q, k, v, "causal", q_chunk=64,
                                       k_chunk=64)
    np.testing.assert_allclose(np.asarray(base), np.asarray(unrolled),
                               atol=1e-6, rtol=1e-6)


def test_kv_cache_manager_slots():
    from repro.serving.kv_cache import KVCacheManager
    cfg = reduced_config("h2o-danube-3-4b")
    bundle = build_model(cfg)
    kv = KVCacheManager(bundle, batch=3, max_len=32)
    assert kv.free_slots() == [0, 1, 2]
    s0 = kv.assign(10, prompt_len=5)
    s1 = kv.assign(11, prompt_len=7)
    assert kv.free_slots() == [2]
    assert kv.lengths()[s0] == 5 and kv.lengths()[s1] == 7
    kv.release(s0)
    assert 0 in kv.free_slots()
    s2 = kv.assign(12, prompt_len=3)
    assert s2 == 0


def test_gr_dataset_planted_signal():
    ds = GRInteractionDataset(n_items=1000, n_users=50, seed=0)
    rng = np.random.default_rng(0)
    # label rate should correlate with affinity by construction
    r = ds.sample_request(rng, 32, 64)
    assert r["history"].shape == (32,) and r["candidates"].shape == (64,)
    assert r["labels"].shape == (64, 3)
    assert set(np.unique(r["labels"])).issubset({0.0, 1.0})
    # zipf popularity: repeated sampling concentrates on few items
    many = np.concatenate([ds.sample_request(rng, 64, 1)["history"]
                           for _ in range(20)])
    top_share = np.mean(np.isin(many, np.arange(50)))
    assert top_share > 0.2


def test_token_dataset_markov_structure():
    ds = TokenDataset(vocab_size=64, branching=2, seed=0)
    rng = np.random.default_rng(0)
    b = ds.batch(rng, 4, 128)["tokens"]
    # every transition must be one of the 2 allowed successors
    for row in b:
        for t in range(1, len(row)):
            assert row[t] in ds.successors[row[t - 1]]


def test_decode_beyond_window_ring_semantics():
    """SWA ring cache: decoding far past the window stays correct vs a
    full-context reference."""
    cfg = reduced_config("h2o-danube-3-4b")   # swa window 64 (reduced)
    assert cfg.sliding_window == 64
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    S = 80                                     # beyond the 64 window
    toks = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)
    caches, _ = bundle.cache_init(1, 128)
    _, c2 = bundle.prefill(params, {"tokens": toks}, caches=caches,
                           impl="reference")
    nt = jax.random.randint(jax.random.key(2), (1, 1), 0, cfg.vocab_size)
    dec, _ = bundle.decode_step(params, c2, {"tokens": nt,
                                             "cur_index": jnp.int32(S)})
    full = bundle.prefill(params, {"tokens": jnp.concatenate([toks, nt], 1)},
                          impl="reference")
    np.testing.assert_allclose(np.asarray(full[:, -1], np.float32),
                               np.asarray(dec[:, 0], np.float32),
                               atol=0.08, rtol=0.05)


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV cache (the §Perf decode-memory optimization) stays within
    quantization tolerance of the bf16 cache path."""
    cfg = reduced_config("qwen2-72b")
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    B, S = 2, 48
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    nt = jax.random.randint(jax.random.key(3), (B, 1), 0, cfg.vocab_size)
    outs = {}
    for quant in (False, True):
        caches, _ = bundle.cache_init(B, S + 4, quant=quant)
        _, c2 = bundle.prefill(params, {"tokens": toks}, caches=caches,
                               impl="reference")
        dec, _ = bundle.decode_step(params, c2, {"tokens": nt,
                                                 "cur_index": jnp.int32(S)})
        outs[quant] = np.asarray(dec[:, 0], np.float32)
    assert np.abs(outs[True] - outs[False]).max() < 0.2
    # int8 cache leaves are actually int8
    caches, _ = bundle.cache_init(B, 16, quant=True)
    kinds = {str(l.dtype) for l in jax.tree.leaves(caches)}
    assert "int8" in kinds
