"""Generative candidate decode (ISSUE 8): oracle-backed decode suite.

Layers of coverage:

  1. step identity — one greedy decode step at ``lengths == S`` is BITWISE
     ``score_candidates(M=V)`` + argmax (reference/chunked; bf16-tight
     allclose for the block-reordered pallas route), and a PADDED beam
     cache decodes bitwise like the unpadded one (masked positions get
     exact-zero softmax weight, the placement-invariance the engine's
     fixed-shape caches rely on);
  2. attention oracle — ``sumi.decode_candidate_attention`` against the
     fp32 ``kernels/flash_decode/ref.decode_with_self`` ground truth;
  3. N-step greedy — an incrementally-grown beam cache
     (``decode_logits`` + ``append_token``) reproduces, token for token, a
     pure-Python decode loop over the MONOLITHIC reference forward (the
     repo's ground-truth path: no beam caches, no scatter, the whole
     sequence re-assembled and re-scored from scratch every step);
  4. beam search — the engine's ``BeamConfig`` result on a toy universe
     equals exhaustive enumeration of every sequence ranked by cumulative
     log-probability (width >= V^(N-1) makes beam search provably exact),
     plus propcheck invariants on ``generate.beam_step``: scores
     monotonically non-increasing, no duplicate live hypotheses, finished
     hypotheses pass through frozen and are never re-expanded;
  5. engine/packing — concurrent multi-request decode is bitwise the
     sequential decode of the same engine, the pack_tails engine emits
     bitwise the unpacked engine's sequences, and a beam evicted from a
     tiny pool mid-generation replays (re-encode + re-append) to the same
     sequences, counted by ``gen_replays``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._propcheck import given, settings, st

from repro.configs import get_config
from repro.core import climber as C
from repro.core.pda import RemoteFeatureStore
from repro.core import sumi
from repro.kernels.flash_decode import ref as fd_ref
from repro.models import build_model
from repro.serving import FlameEngine, ServeRequest
from repro.serving.api import BeamConfig, TopKConfig
from repro.serving import generate as G
from repro.serving.scheduler import run_workload_async
from repro.types import ClimberConfig

N_HIST = 16
VOCAB = 64


def _cfg():
    return dataclasses.replace(
        get_config("climber"), vocab_size=VOCAB, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))


@pytest.fixture(scope="module")
def climber_setup():
    cfg = _cfg()
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    ks = jax.random.split(jax.random.key(1), 3)
    batch = {"history": jax.random.randint(ks[0], (1, N_HIST), 0, VOCAB),
             "side": jax.random.normal(ks[2], (1, 12))}
    return cfg, bundle, params, batch


def _s0(cfg):
    """Per-block cache length: history sub-sequence + the side token."""
    return N_HIST // cfg.climber.num_blocks + 1


def _pad_tree(kv, extra: int):
    """Pad every [B,L,S,Hkv,D] leaf by ``extra`` sequence slots (axis 2)
    with a NON-ZERO fill: equality through the padded cache then proves
    the length mask, not lucky zeros."""
    return jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, extra), (0, 0), (0, 0)],
                          constant_values=3.75), kv)


def _step_logprobs(probs_bmt: np.ndarray) -> np.ndarray:
    """The engine's ranking statistic: fp64 log-softmax over the token
    universe of the per-candidate TASK-SUM of sigmoid probabilities."""
    return G.log_softmax(np.asarray(probs_bmt, np.float32).sum(-1))


# ---------------------------------------------------------------------------
# 1. one decode step IS score_candidates + argmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["reference", "chunked", "pallas"])
def test_decode_step_is_score_candidates(climber_setup, impl):
    cfg, bundle, params, batch = climber_setup
    kv = bundle.encode_history(params, batch, impl=impl)
    cand = jax.random.randint(jax.random.key(7), (1, 8), 0, VOCAB)
    lengths = np.asarray([_s0(cfg)], np.int32)
    want = np.asarray(bundle.score_candidates(params, kv, cand, impl=impl))
    got = np.asarray(bundle.decode_logits(params, kv, cand, lengths,
                                          impl=impl))
    if impl == "pallas":
        np.testing.assert_allclose(got, want, atol=5e-3, rtol=5e-3)
    else:
        np.testing.assert_array_equal(got, want)
    # the greedy decision is the score-path argmax
    assert int(np.argmax(_step_logprobs(got[0]))) == \
        int(np.argmax(_step_logprobs(want[0])))


@pytest.mark.parametrize("impl", ["reference", "chunked"])
def test_padded_cache_decodes_bitwise(climber_setup, impl):
    cfg, bundle, params, batch = climber_setup
    kv = bundle.encode_history(params, batch, impl=impl)
    cand = jax.random.randint(jax.random.key(8), (1, 6), 0, VOCAB)
    lengths = np.asarray([_s0(cfg)], np.int32)
    want = np.asarray(bundle.decode_logits(params, kv, cand, lengths,
                                           impl=impl))
    got = np.asarray(bundle.decode_logits(params, _pad_tree(kv, 5), cand,
                                          lengths, impl=impl))
    np.testing.assert_array_equal(got, want)


def test_decode_attention_matches_fp32_oracle():
    """sumi.decode_candidate_attention (reference route) against the
    kernels/flash_decode fp32 ground truth, padded rows included."""
    rng = np.random.default_rng(3)
    b, m, s, h, hkv, d = 3, 5, 11, 4, 2, 8
    q = rng.standard_normal((b, m, h, d)).astype(np.float32)
    kh = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    vh = rng.standard_normal((b, s, hkv, d)).astype(np.float32)
    kc = rng.standard_normal((b, m, hkv, d)).astype(np.float32)
    vc = rng.standard_normal((b, m, hkv, d)).astype(np.float32)
    lengths = np.asarray([11, 7, 4], np.int32)
    got = np.asarray(sumi.decode_candidate_attention(
        jnp.asarray(q), jnp.asarray(kh), jnp.asarray(vh), jnp.asarray(kc),
        jnp.asarray(vc), lengths, impl="reference"))
    want = np.asarray(fd_ref.decode_with_self(
        jnp.asarray(q), jnp.asarray(kh), jnp.asarray(vh),
        jnp.asarray(lengths), jnp.asarray(kc), jnp.asarray(vc)))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# 3. N-step greedy vs the monolithic pure-Python oracle
# ---------------------------------------------------------------------------

def _oracle_step_probs(params, batch, cfg, tokens, universe):
    """Ground-truth probabilities for the next decode step, WITHOUT beam
    caches: re-assemble every block's full sequence — history sub-sequence
    + side token + the tokens generated so far + the candidate universe —
    and run the monolithic SUMI forward from scratch (reference impl).
    The generated tokens join the causal prefix (position s0+g for token
    g), the universe sits at the shared next position, exactly the
    layout the incremental decode path maintains in its caches."""
    emb = params["embed"]["embedding"]
    tok_e = jnp.take(emb, jnp.asarray([list(tokens)], jnp.int32), axis=0) \
        if tokens else None
    cand_e = jnp.take(emb, jnp.asarray([list(universe)], jnp.int32), axis=0)
    n_hist = _s0(cfg) + len(tokens)
    outs = []
    for i, xb in enumerate(C._history_block_inputs(params, batch, cfg)):
        parts = [xb] + ([tok_e.astype(xb.dtype)] if tok_e is not None
                        else []) + [cand_e.astype(xb.dtype)]
        seq = jnp.concatenate(parts, axis=1)
        out = C._block_forward(params["blocks"][f"b{i}"], seq, n_hist, cfg,
                               "reference")
        outs.append(out[:, n_hist:])
    h = jnp.stack(outs, axis=2)
    return np.asarray(jax.nn.sigmoid(C._fuse_and_head(params, h, cfg)))


@pytest.mark.parametrize("impl", ["reference", "chunked"])
def test_nstep_greedy_matches_monolithic_oracle(climber_setup, impl):
    cfg, bundle, params, batch = climber_setup
    steps, universe = 5, np.arange(12, dtype=np.int32)
    s0 = _s0(cfg)
    kv = _pad_tree(bundle.encode_history(params, batch, impl=impl), steps)
    tokens, oracle_tokens = [], []
    for g in range(steps):
        lengths = np.asarray([s0 + g], np.int32)
        probs = np.asarray(bundle.decode_logits(
            params, kv, universe[None], lengths, impl=impl))
        want = _oracle_step_probs(params, batch, cfg, oracle_tokens,
                                  universe)
        if impl == "reference":
            # same fp32 math, different assembly: monolithic re-encode vs
            # incrementally appended cache — bitwise is the contract
            np.testing.assert_array_equal(probs, want)
        else:
            np.testing.assert_allclose(probs, want, atol=1e-6, rtol=1e-6)
        lp, wlp = _step_logprobs(probs[0]), _step_logprobs(want[0])
        tok = int(universe[np.argmax(lp)])
        oracle_tokens.append(int(universe[np.argmax(wlp)]))
        assert tok == oracle_tokens[-1], f"diverged at step {g}"
        tokens.append(tok)
        kv = bundle.append_token(params, kv, np.asarray([[tok]], np.int32),
                                 lengths, impl=impl)
    assert tokens == oracle_tokens


# ---------------------------------------------------------------------------
# 4a. propcheck: beam_step invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(2, 6),
       st.integers(0, 1))
@settings(max_examples=60, deadline=None)
def test_beam_step_invariants(seed, width, vocab, use_eos):
    rng = np.random.default_rng(seed)
    width = min(width, vocab)
    universe = np.sort(rng.choice(50, size=vocab, replace=False))
    eos = int(universe[0]) if use_eos else None
    # seed: top-width distinct single-token hypotheses
    lp0 = G.log_softmax(rng.standard_normal(vocab))
    order = np.argsort(-lp0, kind="stable")[:width]
    cum = lp0[order]
    seqs = [(int(universe[o]),) for o in order]
    fin = np.asarray([eos is not None and t[0] == eos for t in seqs])
    for _ in range(3):
        step_lp = G.log_softmax(rng.standard_normal((len(cum), vocab)),
                                axis=-1)
        new_cum, new_seqs, new_fin, parents = G.beam_step(
            cum, seqs, fin, step_lp, width, eos, universe)
        # scores monotonically non-increasing (log-probs are <= 0)
        assert new_cum.max() <= cum.max() + 1e-9
        assert (np.diff(new_cum) <= 1e-12).all(), "result not best-first"
        # no duplicate live hypotheses
        live = [new_seqs[i] for i in range(len(new_seqs)) if not new_fin[i]]
        assert len(live) == len(set(live))
        for slot in range(len(new_cum)):
            p = int(parents[slot])
            if fin[p]:
                # finished hypotheses pass through frozen: same tokens,
                # same score, still finished — never re-expanded
                assert new_seqs[slot] == seqs[p]
                assert new_cum[slot] == cum[p]
                assert new_fin[slot]
            else:
                assert new_seqs[slot][:-1] == seqs[p]
                assert new_seqs[slot][-1] in universe
        cum, seqs, fin = new_cum, new_seqs, new_fin


# ---------------------------------------------------------------------------
# engine fixtures
# ---------------------------------------------------------------------------

def _engine(bundle, params, **kw):
    base = dict(n_history=N_HIST, buckets=(8, 4), n_streams=2,
                feature_mode="off",
                store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                window_s=0.01, max_batch=4, n_workers=4,
                history_cache=True, pool_slots=32,
                generate=6, gen_vocab=16)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


@pytest.fixture(scope="module")
def engines(climber_setup):
    cfg, bundle, params, _ = climber_setup
    plain = _engine(bundle, params)
    packed = _engine(bundle, params, pack_tails=True)
    yield plain, packed
    plain.shutdown()
    packed.shutdown()


def _requests(n, seed=0):
    """Ragged generative traffic: universes of 3..11 ids (sub-bucket tails
    so pack_tails has something to pack), mixed top-k / beam."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        m = int(rng.integers(3, 12))
        reqs.append({
            "history": rng.integers(0, VOCAB, N_HIST).astype(np.int32),
            "candidates": rng.integers(0, VOCAB, m).astype(np.int32),
            "user_id": int(i),
            "generate": (TopKConfig(k=2, steps=4) if i % 2 else
                         BeamConfig(width=3, steps=4)),
        })
    return reqs


# ---------------------------------------------------------------------------
# 4b. beam search == exhaustive enumeration
# ---------------------------------------------------------------------------

def test_engine_beam_equals_exhaustive(climber_setup, engines):
    """width >= V^(steps-1) keeps every prefix alive, so beam search must
    return exactly the global top-width of ALL V^steps sequences ranked by
    cumulative log-probability — computed here by brute-force enumeration
    through the model-level decode surface."""
    cfg, bundle, params, _ = climber_setup
    eng, _ = engines
    universe = np.asarray([5, 11, 23, 42], np.int32)   # V=4
    steps, width = 3, 16                               # 16 = 4^2
    rng = np.random.default_rng(17)
    hist = rng.integers(0, VOCAB, N_HIST).astype(np.int32)
    out = eng.serve(hist, candidates=universe, user_id=777,
                    generate=BeamConfig(width=width, steps=steps))
    assert out.shape == (width, steps)

    # exhaustive oracle: grow every prefix's cache explicitly.  The bundle
    # fns are JIT-WRAPPED: on this backend eager execution rounds matmuls
    # differently from compiled code (~1e-2 on KV leaves), while compiled
    # execution is row-wise batch-invariant — jitted calls here reproduce
    # the engine's AOT executors bitwise, so the comparison stays exact.
    dec = jax.jit(lambda kvt, c, l: bundle.decode_logits(
        params, kvt, c, l, impl=eng.impl))
    app = jax.jit(lambda kvt, t, l: bundle.append_token(
        params, kvt, t, l, impl=eng.impl))
    enc = jax.jit(lambda h, s: bundle.encode_history(
        params, {"history": h, "side": s}, impl=eng.impl))
    side = eng._side_features(hist)
    root = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, steps), (0, 0), (0, 0)]),
        enc(jnp.asarray(hist[None]), jnp.asarray(side)))
    s0 = _s0(cfg)
    level = {(): (0.0, root)}
    table = {}
    for g in range(steps):
        nxt = {}
        lens = np.asarray([s0 + g], np.int32)
        for prefix, (score, kv) in level.items():
            probs = np.asarray(dec(kv, universe[None], lens))
            lp = _step_logprobs(probs[0])
            for j, tok in enumerate(universe):
                seq = prefix + (int(tok),)
                if g < steps - 1:
                    nxt[seq] = (score + lp[j], app(
                        kv, np.asarray([[tok]], np.int32), lens))
                else:
                    table[seq] = score + lp[j]
        level = nxt
    ranked = sorted(table.items(), key=lambda kvp: -kvp[1])
    want = np.asarray([list(seq) for seq, _ in ranked[:width]], np.int32)
    np.testing.assert_array_equal(out, want)
    # and the returned rows really are the global top-width by score
    eng_scores = np.asarray([table[tuple(int(t) for t in row)]
                             for row in out])
    assert (np.diff(eng_scores) <= 0).all(), "rows not best-first"


# ---------------------------------------------------------------------------
# 5. engine: packed / concurrent / sequential equality + pool interaction
# ---------------------------------------------------------------------------

def test_concurrent_packed_decode_equals_sequential(engines):
    plain, packed = engines
    reqs = _requests(6, seed=1)
    # sequential ground truth: one request in flight at a time
    seq_out = []
    for r in reqs:
        seq_out.append(plain.serve(r["history"], candidates=r["candidates"],
                                   user_id=r["user_id"],
                                   generate=r["generate"]))
    # concurrent on the same engine (warm pool): placement in coalesced /
    # packed dispatches must not change a single token
    res = run_workload_async(plain, reqs)
    for got, want in zip(res["outputs"], seq_out):
        np.testing.assert_array_equal(got, want)
    # concurrent on the pack_tails engine: segment-packed per-step ragged
    # batching of in-flight beams, still bitwise
    res_p = run_workload_async(packed, reqs)
    for got, want in zip(res_p["outputs"], seq_out):
        np.testing.assert_array_equal(got, want)
    assert packed.metrics()["dso_packed_segments"] > 0


def test_evicted_beam_replays_to_same_sequences(climber_setup, engines):
    """A beam whose parked cache is evicted (or rejected) mid-generation
    re-encodes its base history and replays its appends — same tokens, at
    replay cost, counted by ``gen_replays``."""
    cfg, bundle, params, _ = climber_setup
    plain, _ = engines
    tiny = _engine(bundle, params, pool_slots=1)
    try:
        rng = np.random.default_rng(23)
        hist = rng.integers(0, VOCAB, N_HIST).astype(np.int32)
        universe = rng.integers(0, VOCAB, 9).astype(np.int32)
        gen = BeamConfig(width=3, steps=5)
        want = plain.serve(hist, candidates=universe, user_id=901,
                           generate=gen)
        got = tiny.serve(hist, candidates=universe, user_id=901,
                         generate=gen)
        np.testing.assert_array_equal(got, want)
        assert tiny.metrics().get("gen_replays", 0) > 0, \
            "a 1-slot pool must force at least one beam replay"
    finally:
        tiny.shutdown()


def test_generate_request_validation(engines):
    eng, _ = engines
    hist = np.arange(N_HIST, dtype=np.int32)
    with pytest.raises(ValueError, match="capacity"):
        eng.serve(hist, generate=TopKConfig(k=2, steps=99))
    with pytest.raises(ValueError, match="top-k"):
        # top-k can seed at most |universe| independent greedy beams
        eng.serve(hist, candidates=np.asarray([1, 2, 3], np.int32),
                  generate=TopKConfig(k=8, steps=2))
    with pytest.raises(ValueError, match="TopKConfig"):
        eng.serve(hist, generate=42)


def test_generate_metrics_surface(engines):
    """After the suites above, the decode observability must be populated:
    decode rounds counted, generation rate derived, no beams left behind."""
    eng, _ = engines
    m = eng.metrics()
    assert m["decode_steps"] > 0
    assert m["gen_tokens"] > 0
    assert m["gen_tokens_per_s"] > 0
    assert m["beams_in_flight"] == 0
    assert m.get("dso_dispatches_decode", 0) > 0
    assert m.get("dso_dispatches_append", 0) > 0
