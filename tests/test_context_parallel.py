"""Context-parallel attention (§Perf hillclimb 3): exactness vs reference.

Multi-shard case runs in a subprocess with 8 forced host devices (2x4 mesh)
so the main pytest process keeps 1 device."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.models.attention import (context_parallel_attention,
                                    reference_attention)

SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.models.attention import context_parallel_attention, reference_attention

mesh = make_mesh((2, 4), ("data", "model"))
ks = jax.random.split(jax.random.key(0), 3)
q = jax.random.normal(ks[0], (2, 256, 4, 32))
k = jax.random.normal(ks[1], (2, 256, 2, 32))
v = jax.random.normal(ks[2], (2, 256, 2, 32))
for mode, w in [("sliding", 64), ("causal", 0), ("full", 0)]:
    out = jax.jit(lambda a, b, c: context_parallel_attention(
        a, b, c, mode, window=w, mesh=mesh))(q, k, v)
    ref = reference_attention(q, k, v, mode, window=w)
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    assert err < 1e-5, (mode, err)
print("OK")
"""


@pytest.mark.parametrize("mode,window", [("sliding", 64), ("causal", 0),
                                         ("full", 0)])
def test_cp_attention_single_device(mode, window):
    mesh = make_mesh((1, 1), ("data", "model"))
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    out = context_parallel_attention(q, k, v, mode, window=window, mesh=mesh)
    ref = reference_attention(q, k, v, mode, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-6)


def test_cp_attention_multi_shard_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env, capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout


def test_cp_halo_masks_wraparound():
    """Shard 0's halo comes from the LAST shard (ring ppermute) and must be
    fully masked: changing the tail of the sequence must not affect the
    first window of outputs under sliding attention."""
    mesh = make_mesh((1, 1), ("data", "model"))
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    out1 = context_parallel_attention(q, k, v, "sliding", window=32, mesh=mesh)
    k2 = k.at[:, -16:].set(99.0)
    v2 = v.at[:, -16:].set(99.0)
    out2 = context_parallel_attention(q, k2, v2, "sliding", window=32,
                                      mesh=mesh)
    np.testing.assert_array_equal(np.asarray(out1[:, :32]),
                                  np.asarray(out2[:, :32]))
