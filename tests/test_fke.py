"""FKE (fused candidate-scoring engine) parity suite.

Layers of coverage:

  1. oracle contract — ``kernels/fused_score/ref.py`` (dequantize → gather
     → concat → reference attention) is BITWISE identical to the framework
     reference path it replaces;
  2. op parity — the Pallas kernel (interpret mode) and the fused jnp fast
     path vs the oracle, swept over q_offset (history length), dedup
     row-index, int8/bf16 stored operands, and ragged (non-block-aligned)
     tails, for both cached-candidate and extend attention;
  3. model level — ``score_candidates`` / ``extend_history`` under
     ``impl="fused"`` vs the reference impl, including raw quantized pool
     views and row-index dispatch;
  4. serving level — the fused FlameEngine vs the full-pass engine across
     pool dtypes, dedup auto-enabled (and free) on the CPU backend, the
     default extension-bucket ladder + re-encode crossover policy, and the
     extension-refresh drift cap over a long stale-sweep session.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import climber as C
from repro.core import sumi
from repro.kernels.fused_score import ops as fs_ops
from repro.kernels.fused_score import ref as fs_ref
from repro.models import build_model
from repro.serving.kv_cache import (dequantize_kv, quantize_kv, quantize_leaf,
                                    raw_kv_specs, raw_kv_view)
from repro.types import ClimberConfig

TOL = 2e-5          # f32 operands: reassociated scale/softmax math
QTOL = 2e-2         # int8-quantized operands: quantization error dominates


def _mk(seed, b, m, h, hkv, d, s, u=None):
    ks = jax.random.split(jax.random.key(seed), 6)
    u = b if u is None else u
    return dict(
        q=jax.random.normal(ks[0], (b, m, h, d)),
        k_hist=jax.random.normal(ks[1], (u, s, hkv, d)),
        v_hist=jax.random.normal(ks[2], (u, s, hkv, d)),
        k_cand=jax.random.normal(ks[3], (b, m, hkv, d)),
        v_cand=jax.random.normal(ks[4], (b, m, hkv, d)),
    )


# ---------------------------------------------------------------------------
# 1. oracle contract
# ---------------------------------------------------------------------------

def test_oracle_bitwise_vs_framework_reference():
    """The fp32 oracle == the framework path (dequant + gather + concat +
    reference attention through core/sumi.py), bit for bit."""
    t = _mk(0, b=3, m=12, h=4, hkv=2, d=16, s=37, u=2)
    idx = jnp.array([1, 0, 1], jnp.int32)
    qk = quantize_leaf(t["k_hist"], "int8")
    qv = quantize_leaf(t["v_hist"], "int8")
    got = fs_ref.cached_reference(
        t["q"], qk.q, qv.q, t["k_cand"], t["v_cand"], k_scale=qk.scale,
        v_scale=qv.scale, row_index=idx, kv_dtype=jnp.float32)
    # framework path: sumi materializes dequant+gather then concat+reference
    exp = sumi.cached_candidate_attention(
        t["q"], qk.q, qv.q, t["k_cand"], t["v_cand"], impl="reference",
        k_scale=qk.scale, v_scale=qv.scale, row_index=idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_oracle_extend_bitwise_vs_framework_reference():
    t = _mk(1, b=2, m=9, h=2, hkv=2, d=16, s=25)
    got = fs_ref.extend_reference(t["q"], t["k_hist"], t["v_hist"],
                                  t["k_cand"], t["v_cand"])
    exp = sumi.extend_attention(t["q"], t["k_hist"], t["v_hist"],
                                t["k_cand"], t["v_cand"], impl="reference")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------------------
# 2. op parity sweeps (kernel + jnp fast path vs oracle)
# ---------------------------------------------------------------------------

CASES = [
    # b, m, h, hkv, d, s, u, idx?, dtype
    (2, 16, 4, 2, 32, 64, None, False, "native"),
    (3, 12, 4, 2, 16, 37, 2, True, "native"),       # ragged + dedup idx
    (2, 8, 2, 2, 16, 100, None, False, "int8"),
    (3, 20, 4, 1, 16, 51, 2, True, "int8"),         # gqa + ragged + idx
    (2, 16, 2, 2, 16, 33, None, False, "bf16"),
    (1, 5, 2, 2, 48, 7, None, False, "native"),     # tiny ragged tail
]


def _quant(t, dtype):
    if dtype == "native":
        return dict(t, k_scale=None, v_scale=None), TOL
    qk = quantize_leaf(t["k_hist"], dtype)
    qv = quantize_leaf(t["v_hist"], dtype)
    out = dict(t, k_hist=qk.q, v_hist=qv.q, k_scale=qk.scale,
               v_scale=qv.scale)
    return out, (QTOL if dtype == "int8" else TOL)


@pytest.mark.parametrize("case", CASES,
                         ids=[f"{c[8]}-s{c[5]}-m{c[1]}" + ("-idx" if c[7]
                              else "") for c in CASES])
@pytest.mark.parametrize("path", ["jnp", "kernel"])
def test_cached_op_parity(case, path):
    b, m, h, hkv, d, s, u, use_idx, dtype = case
    t = _mk(b * 131 + m * 17 + s, b, m, h, hkv, d, s, u)
    t, tol = _quant(t, dtype)
    idx = jnp.asarray(np.random.default_rng(0).integers(0, u or b, b),
                      jnp.int32) if use_idx else None
    ref = fs_ref.cached_reference(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx,
        kv_dtype=jnp.float32)
    got = fs_ops.fused_cached_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx,
        path=path)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_cached_seg_gemm_long_history_parity(monkeypatch):
    """Segment-packed (2-D ``row_index``) scoring at ``S >=
    _SEG_GEMM_MIN_S`` takes the dense-GEMM + one-hot-selection form
    instead of gathering an M-times-replicated [B,M,S,Hkv,D] history.
    The selection is algebraically exact, so the two forms must agree to
    plain f32 reassociation tolerance even on int8 operands (both read
    the same stored values; only contraction order differs)."""
    s = fs_ops._SEG_GEMM_MIN_S + 33
    b, m, h, hkv, d, u = 2, 10, 4, 2, 16, 3
    t = _mk(9, b, m, h, hkv, d, s, u)
    t, _ = _quant(t, "int8")
    idx2 = jnp.asarray(np.random.default_rng(1).integers(0, u, (b, m)),
                       jnp.int32)

    def call():
        return fs_ops.fused_cached_attention(
            t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
            k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx2,
            path="jnp")

    got = call()                                     # dense-GEMM form
    fs_ops._fused_jnp.clear_cache()
    monkeypatch.setattr(fs_ops, "_SEG_GEMM_MIN_S", s + 1)
    exp = call()                                     # gathered form
    fs_ops._fused_jnp.clear_cache()                  # drop patched trace
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("case", CASES,
                         ids=[f"{c[8]}-s{c[5]}-m{c[1]}" + ("-idx" if c[7]
                              else "") for c in CASES])
@pytest.mark.parametrize("path", ["jnp", "kernel"])
def test_extend_op_parity(case, path):
    """Extend (causal suffix vs cached prefix) over the same operand sweep
    — b rows, m suffix tokens, s prefix positions."""
    b, m, h, hkv, d, s, u, use_idx, dtype = case
    t = _mk(b * 131 + m * 17 + s + 7, b, m, h, hkv, d, s, u)
    t, tol = _quant(t, dtype)
    idx = jnp.asarray(np.random.default_rng(1).integers(0, u or b, b),
                      jnp.int32) if use_idx else None
    ref = fs_ref.extend_reference(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx,
        kv_dtype=jnp.float32)
    got = fs_ops.fused_extend_attention(
        t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
        k_scale=t["k_scale"], v_scale=t["v_scale"], row_index=idx,
        path=path)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_cached_q_offset_sweep():
    """Cached scoring is exact for any history length (the q_offset the
    candidates sit at), including block-straddling offsets."""
    for s in (1, 8, 63, 64, 65, 130):
        t = _mk(s, b=1, m=10, h=2, hkv=2, d=16, s=s)
        ref = fs_ref.cached_reference(t["q"], t["k_hist"], t["v_hist"],
                                      t["k_cand"], t["v_cand"])
        for path in ("jnp", "kernel"):
            got = fs_ops.fused_cached_attention(
                t["q"], t["k_hist"], t["v_hist"], t["k_cand"], t["v_cand"],
                path=path)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=TOL, rtol=TOL, err_msg=f"s={s}")


def test_fused_attention_dispatch_split():
    """models/attention.py impl="fused" on a pre-concatenated sumi call
    splits the KV axis and matches the reference dispatch."""
    from repro.models import attention as A
    t = _mk(9, b=2, m=8, h=4, hkv=2, d=16, s=40)
    k = jnp.concatenate([t["k_hist"], t["k_cand"]], axis=1)
    v = jnp.concatenate([t["v_hist"], t["v_cand"]], axis=1)
    ref = A.attention(t["q"], k, v, "sumi", impl="reference",
                      n_history=40, q_offset=40)
    got = A.attention(t["q"], k, v, "sumi", impl="fused",
                      n_history=40, q_offset=40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=TOL, rtol=TOL)


# ---------------------------------------------------------------------------
# 3. model level
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def climber_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=5_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"history": jnp.asarray(rng.integers(0, 5000, (1, 64)),
                                    jnp.int32),
             "candidates": jnp.asarray(rng.integers(0, 5000, (1, 12)),
                                       jnp.int32),
             "side": jnp.asarray(rng.normal(size=(1, 12)), jnp.float32)}
    return cfg, bundle, params, batch


def test_score_candidates_fused_parity(climber_setup):
    cfg, bundle, params, batch = climber_setup
    full = C.climber_forward(params, batch, cfg, impl="reference")
    kv = C.encode_history(params, batch, cfg, impl="reference")
    got = C.score_candidates(params, kv, batch["candidates"], cfg,
                             impl="fused")
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               atol=TOL, rtol=TOL)


def test_score_candidates_raw_quantized_views(climber_setup):
    """Raw int8 pool views + row_index through the fused impl track the
    dequantized framework path at the quantization tolerance, and the raw
    spec pytree matches the raw view structure."""
    cfg, bundle, params, batch = climber_setup
    kv = C.encode_history(params, batch, cfg, impl="reference")
    ref = C.score_candidates(params, dequantize_kv(quantize_kv(kv, "int8")[0]),
                             batch["candidates"], cfg, impl="reference")
    raw = raw_kv_view(quantize_kv(kv, "int8")[0])
    specs = raw_kv_specs(jax.eval_shape(lambda x: x, kv), "int8")
    assert jax.tree.structure(raw) == jax.tree.structure(specs)
    for leaf, spec in zip(jax.tree.leaves(raw), jax.tree.leaves(specs)):
        assert leaf.shape == spec.shape and leaf.dtype == spec.dtype
    got = C.score_candidates(params, raw, batch["candidates"], cfg,
                             impl="fused",
                             row_index=jnp.zeros((1,), jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=QTOL, rtol=QTOL)


def test_extend_history_fused_parity(climber_setup):
    cfg, bundle, params, batch = climber_setup
    kv = C.encode_history(params, batch, cfg, impl="reference")
    for prefix in (0, 17, 40, 64):
        got = C.extend_history(params, kv, batch, cfg, prefix_len=prefix,
                               impl="fused")
        exp = C.extend_history(params, kv, batch, cfg, prefix_len=prefix,
                               impl="reference")
        for b in got:
            for kk in ("k", "v"):
                np.testing.assert_allclose(
                    np.asarray(got[b][kk]), np.asarray(exp[b][kk]),
                    atol=TOL, rtol=TOL, err_msg=f"prefix={prefix}")


# ---------------------------------------------------------------------------
# 4. serving level
# ---------------------------------------------------------------------------

def _engine(bundle, params, **kw):
    from repro.core.pda import RemoteFeatureStore
    from repro.serving import FlameEngine
    base = dict(n_history=64, buckets=(16, 8), n_streams=2,
                feature_mode="sync",
                store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                window_s=0.004, max_batch=2, n_workers=2)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


@pytest.mark.parametrize("pool_dtype", ["native", "int8", "bf16"])
def test_engine_fused_parity_and_free_dedup(climber_setup, pool_dtype):
    """The fused engine matches the full-pass engine at the pool tolerance,
    keeps hit/miss responses bitwise-stable (one shared quantized
    representation), and — because the row gather is folded into the
    kernel — auto-enables KV-row dedup even on the CPU backend."""
    cfg, bundle, params, _ = climber_setup
    rng = np.random.default_rng(3)
    hist = rng.integers(0, 5000, 80).astype(np.int32)
    cand = rng.integers(0, 5000, 32).astype(np.int32)    # 2x bucket-16 chunks
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  pool_dtype=pool_dtype, impl="fused")
    full = _engine(bundle, params)
    try:
        assert eng._kv_dedup, "fused impl must auto-enable kv_dedup on CPU"
        a = eng.serve(hist, cand, user_id=1)             # miss
        b = eng.serve(hist, cand, user_id=1)             # hit
        m = eng.metrics()
        assert m["dso_dedup_rows_saved"] >= 1
        np.testing.assert_array_equal(a, b)
        ref = full.serve(hist, cand)
        tol = QTOL if pool_dtype == "int8" else 2e-3
        np.testing.assert_allclose(a.astype(np.float32),
                                   ref.astype(np.float32),
                                   atol=tol, rtol=tol)
    finally:
        eng.shutdown()
        full.shutdown()


def test_engine_default_extension_ladder(climber_setup):
    """incremental_history without explicit buckets ships the (n, 3n/4,
    n/2) trusted-prefix ladder."""
    cfg, bundle, params, _ = climber_setup
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  incremental_history=True)
    try:
        assert eng.dso.families["extend"] == [64, 48, 32]
    finally:
        eng.shutdown()


def test_engine_extension_crossover_reencodes(climber_setup):
    """A stale hit whose shared prefix only fits a rung below half the
    window re-encodes in full (re-encode-vs-extend crossover) instead of
    extending almost the whole window."""
    cfg, bundle, params, _ = climber_setup
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  incremental_history=True, extend_buckets=(64, 16))
    rng = np.random.default_rng(5)
    h1 = rng.integers(0, 5000, 64).astype(np.int32)
    h2 = h1.copy()
    h2[20:] = rng.integers(0, 5000, 44)                  # shared prefix 20
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    try:
        eng.serve(h1, cand, user_id=2)
        eng.serve(h2, cand, user_id=2)                   # bucket 16 < 32 cap
        m = eng.metrics()
        assert m["pool_extensions"] == 0
        assert m["dso_dispatches_encode"] == 2
    finally:
        eng.shutdown()


def test_engine_refresh_limit_bounds_drift(climber_setup):
    """Long stale-sweep session under an int8 pool: every sweep
    tail-appends, so every request is an extendable stale hit that
    re-quantizes the basis.  With --extend-refresh-limit the engine forces
    a full re-encode every K extensions; drift vs a fresh-encode engine
    stays bounded for the whole session and the forced re-encodes are
    visible in the metrics."""
    cfg, bundle, params, _ = climber_setup
    K = 3
    eng = _engine(bundle, params, history_cache=True, pool_slots=4,
                  pool_dtype="int8", incremental_history=True,
                  extend_refresh_limit=K, impl="fused")
    fresh = _engine(bundle, params, history_cache=True, pool_slots=4)
    rng = np.random.default_rng(7)
    hist = rng.integers(0, 5000, 80).astype(np.int32)
    cand = rng.integers(0, 5000, 8).astype(np.int32)
    n_sweeps = 2 * K + 2
    try:
        eng.serve(hist, cand, user_id=1)                 # cold encode
        drift = []
        for _ in range(n_sweeps):
            hist = np.concatenate(
                [hist, rng.integers(0, 5000, 4).astype(np.int32)])
            out = eng.serve(hist, cand, user_id=1)
            ref = fresh.serve(hist, cand)                # content-hash keyed
            drift.append(float(np.abs(out.astype(np.float32)
                                      - ref.astype(np.float32)).max()))
        m = eng.metrics()
        assert m["pool_refresh_reencodes"] >= 2, m
        assert m["pool_extensions"] >= K, m
        assert max(drift) < QTOL, drift
    finally:
        eng.shutdown()
        fresh.shutdown()
