"""MoE dispatch/combine correctness + router behaviour."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_init, moe_apply, _capacity
from repro.types import ModelConfig, MoEConfig


def make_cfg(e=4, k=2, cf=4.0, shared=0, act="swiglu"):
    return ModelConfig(
        name="t", family="moe", n_layers=2, d_model=64, n_heads=2,
        n_kv_heads=2, d_ff=128, vocab_size=100, activation=act,
        layer_pattern=("attn", "attn"),
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=128,
                      capacity_factor=cf, num_shared_experts=shared))


def _dense_oracle(params, x, cfg):
    """Dense per-token expert mixture (no capacity): ground truth."""
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    xt = x.reshape(t, -1).astype(jnp.float32)
    logits = xt @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    wu = params["w_up"].astype(jnp.float32)
    wg = params.get("w_gate")
    wd = params["w_down"].astype(jnp.float32)
    up = jnp.einsum("td,edf->tef", xt, wu)
    if wg is not None:
        g = jnp.einsum("td,edf->tef", xt, wg.astype(jnp.float32))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    outs = jnp.einsum("tef,efd->ted", h, wd)
    sel = jnp.take_along_axis(outs, idx[..., None], axis=1)
    return (sel * gates[..., None]).sum(1).reshape(x.shape)


def test_moe_matches_dense_oracle_no_drops():
    cfg = make_cfg(cf=8.0)   # capacity high enough: nothing dropped
    params_p, _ = __import__("repro.models.layers", fromlist=["split_params"]) \
        .split_params(moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (2, 32, 64), jnp.float32)
    out, aux = moe_apply(params_p, x, cfg)
    assert float(aux["dropped_fraction"]) == 0.0
    exp = _dense_oracle(params_p, x, cfg)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp),
                               atol=2e-2, rtol=2e-2)


def test_moe_top1():
    cfg = make_cfg(e=4, k=1, cf=8.0)
    from repro.models.layers import split_params
    params, _ = split_params(moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (2, 16, 64), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    exp = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(exp),
                               atol=2e-2, rtol=2e-2)


def test_capacity_drops_counted():
    cfg = make_cfg(e=4, k=2, cf=0.3)   # starve capacity
    from repro.models.layers import split_params
    params, _ = split_params(moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (4, 64, 64), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    assert float(aux["dropped_fraction"]) > 0.0
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_shared_expert_added():
    cfg = make_cfg(shared=1, cf=8.0)
    from repro.models.layers import split_params
    params, _ = split_params(moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (1, 8, 64), jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    # zero the shared expert -> output must change
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    out2, _ = moe_apply(p2, x, cfg)
    assert np.abs(np.asarray(out) - np.asarray(out2)).max() > 1e-4


def test_aux_losses_sane():
    cfg = make_cfg(cf=8.0)
    from repro.models.layers import split_params
    params, _ = split_params(moe_init(jax.random.key(0), cfg))
    x = jax.random.normal(jax.random.key(1), (2, 64, 64), jnp.float32)
    _, aux = moe_apply(params, x, cfg)
    # switch LB loss is ~1*coef when balanced, >= coef*1 in general
    lb = float(aux["load_balance_loss"]) / cfg.moe.load_balance_loss
    assert 0.9 < lb < 4.0
    assert float(aux["router_z_loss"]) >= 0.0


def test_capacity_rounding():
    m = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8, capacity_factor=1.0)
    assert _capacity(64, m) % 8 == 0
    assert _capacity(64, m) >= 64 * 2 // 4
