"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles
(interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.fused_ffn import ops as ffn_ops
from repro.kernels.fused_ffn import ref as ffn_ref
from repro.kernels.rwkv6_scan import ops as rwkv_ops
from repro.kernels.rwkv6_scan import ref as rwkv_ref


FA_CASES = [
    # b, h, hkv, sq, sk, d, mode, window, n_hist
    (2, 4, 2, 256, 256, 64, "causal", 0, 0),
    (1, 2, 2, 200, 200, 64, "full", 0, 0),
    (1, 4, 1, 384, 384, 128, "sliding", 100, 0),
    (2, 2, 2, 130, 130, 32, "sliding", 64, 0),
    (1, 2, 2, 300, 300, 64, "sumi", 0, 200),
    (1, 2, 1, 160, 160, 96, "sumi", 0, 100),   # non-128-aligned d, gqa
    (1, 8, 8, 64, 64, 64, "causal", 0, 0),
]


@pytest.mark.parametrize("case", FA_CASES, ids=[f"{c[6]}-{c[3]}" for c in FA_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(case, dtype):
    b, h, hkv, sq, sk, d, mode, w, nh = case
    ks = jax.random.split(jax.random.key(hash(mode) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = fa_ops.flash_attention_bhsd(q, k, v, mode, window=w, n_history=nh,
                                      bq=64, bk=64)
    exp = fa_ref.reference(q, k, v, mode, window=w, n_history=nh)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_flash_attention_block_shape_sweep():
    """Same problem, several BlockSpec tilings -> identical results."""
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 320, 64))
    k = jax.random.normal(ks[1], (1, 2, 320, 64))
    v = jax.random.normal(ks[2], (1, 2, 320, 64))
    ref = fa_ref.reference(q, k, v, "sumi", n_history=200)
    for b in (32, 64, 128):
        out = fa_ops.flash_attention_bhsd(q, k, v, "sumi", n_history=200,
                                          bq=b, bk=b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_flash_attention_model_layout():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    out = fa_ops.flash_attention(q, k, v, "causal")
    assert out.shape == q.shape


FFN_CASES = [
    (100, 256, 700, "swiglu", True),
    (512, 128, 512, "gelu", True),
    (33, 256, 512, "swiglu", False),
    (256, 512, 1024, "relu", True),
]


@pytest.mark.parametrize("case", FFN_CASES, ids=[f"{c[3]}-{c[0]}" for c in FFN_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ffn_vs_oracle(case, dtype):
    t, d, f, act, norm = case
    ks = jax.random.split(jax.random.key(t), 5)
    x = jax.random.normal(ks[0], (t, d), dtype)
    wu = (jax.random.normal(ks[1], (d, f), dtype) / np.sqrt(d)).astype(dtype)
    wd = (jax.random.normal(ks[2], (f, d), dtype) / np.sqrt(f)).astype(dtype)
    wg = (jax.random.normal(ks[3], (d, f), dtype) / np.sqrt(d)).astype(dtype) \
        if act == "swiglu" else None
    ns = (jax.random.normal(ks[4], (d,), dtype) * 0.1).astype(dtype) if norm else None
    out = ffn_ops.fused_ffn_2d(x, wu, wd, wg, ns, activation=act, bt=64, bf=128)
    exp = ffn_ref.reference(x, wu, wd, w_gate=wg, norm_scale=ns, activation=act)
    scale = max(1e-6, float(np.abs(np.asarray(exp, np.float32)).max()))
    err = np.abs(np.asarray(out, np.float32) - np.asarray(exp, np.float32)).max()
    assert err / scale < (1e-5 if dtype == jnp.float32 else 3e-2)


RWKV_CASES = [(2, 2, 128, 64, 32), (1, 4, 100, 64, 64), (2, 1, 256, 32, 64),
              (1, 2, 64, 64, 64)]


@pytest.mark.parametrize("case", RWKV_CASES, ids=[f"s{c[2]}d{c[3]}" for c in RWKV_CASES])
def test_rwkv6_scan_vs_oracle(case):
    b, h, s, d, chunk = case
    ks = jax.random.split(jax.random.key(s), 5)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    wl = -jnp.exp(jax.random.normal(ks[3], (b, s, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    o, sf = rwkv_ops.rwkv6_scan(r, k, v, wl, u, chunk=chunk)

    def to_bh(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, s, d)

    oref, sref = rwkv_ref.reference(
        to_bh(r), to_bh(k), to_bh(v), to_bh(wl),
        jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d))
    oref = jnp.moveaxis(oref.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sf).reshape(b * h, d, d),
                               np.asarray(sref), atol=2e-3, rtol=2e-3)


def test_rwkv6_scan_state_carry():
    """Two half-sequence scans with carried state == one full scan."""
    b, h, s, d = 1, 2, 128, 64
    ks = jax.random.split(jax.random.key(7), 5)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    wl = -jnp.exp(jax.random.normal(ks[3], (b, s, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.5
    o_full, s_full = rwkv_ops.rwkv6_scan(r, k, v, wl, u, chunk=32)
    o1, st = rwkv_ops.rwkv6_scan(r[:, :64], k[:, :64], v[:, :64], wl[:, :64],
                                 u, chunk=32)
    o2, s2 = rwkv_ops.rwkv6_scan(r[:, 64:], k[:, 64:], v[:, 64:], wl[:, 64:],
                                 u, state=st, chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=2e-3, rtol=2e-3)
