"""Serving layer: FlameEngine end-to-end, TextServingEngine, scheduler."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.pda import RemoteFeatureStore
from repro.data import GRInteractionDataset
from repro.models import build_model
from repro.serving import FlameEngine, TextServingEngine
from repro.serving.scheduler import TrafficConfig, generate_traffic, run_workload
from repro.types import ClimberConfig


@pytest.fixture(scope="module")
def climber_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=10_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def test_flame_engine_serves_and_routes(climber_setup):
    cfg, bundle, params = climber_setup
    eng = FlameEngine(bundle, params, n_history=64, buckets=(64, 32, 16),
                      n_streams=2)
    ds = GRInteractionDataset(n_items=10_000)
    rng = np.random.default_rng(0)
    for m in (16, 40, 100):
        r = ds.sample_request(rng, 64, m)
        scores = eng.serve(r["history"], r["candidates"])
        assert scores.shape == (m, 3)
        assert np.isfinite(scores).all()
        assert (scores >= 0).all() and (scores <= 1).all()
    assert eng.dso.chunk_count >= 3
    eng.shutdown()


def test_flame_engine_dso_matches_single_executor(climber_setup):
    """Routing through multiple buckets == one big-bucket pass (SUMI)."""
    cfg, bundle, params = climber_setup
    eng = FlameEngine(bundle, params, n_history=64, buckets=(128, 32, 16),
                      n_streams=1, feature_mode="off",
                      store=RemoteFeatureStore(latency_s=0.0, feature_dim=12))
    ds = GRInteractionDataset(n_items=10_000)
    rng = np.random.default_rng(1)
    r = ds.sample_request(rng, 64, 48)       # 48 -> 32 + 16 under the router
    split = eng.serve(r["history"], r["candidates"])
    whole = eng.serve(r["history"], r["candidates"][:48].copy())
    np.testing.assert_allclose(split, whole, atol=2e-2, rtol=2e-2)
    eng.shutdown()


def test_flame_engine_cache_reduces_network(climber_setup):
    cfg, bundle, params = climber_setup
    store1 = RemoteFeatureStore(latency_s=0.0, feature_dim=12)
    eng_nc = FlameEngine(bundle, params, n_history=64, buckets=(64,),
                         feature_mode="off", store=store1)
    store2 = RemoteFeatureStore(latency_s=0.0, feature_dim=12)
    eng_c = FlameEngine(bundle, params, n_history=64, buckets=(64,),
                        feature_mode="sync", store=store2)
    ds = GRInteractionDataset(n_items=10_000)
    rng = np.random.default_rng(2)
    reqs = [ds.sample_request(rng, 64, 16) for _ in range(6)]
    for r in reqs + reqs:   # repeat -> second pass should hit cache
        eng_nc.serve(r["history"], r["candidates"])
        eng_c.serve(r["history"], r["candidates"])
    assert store2.bytes_sent < store1.bytes_sent
    eng_nc.shutdown()
    eng_c.shutdown()


def test_text_serving_engine_greedy_matches_manual():
    cfg = reduced_config("h2o-danube-3-4b")
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    eng = TextServingEngine(bundle, params, batch=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
               rng.integers(0, cfg.vocab_size, 10).astype(np.int32)]
    outs = eng.generate(prompts, n_tokens=4)
    assert all(len(o) == 4 for o in outs)
    # manual greedy continuation of prompt 0 via repeated prefill
    seq = list(prompts[0])
    for _ in range(4):
        logits = bundle.prefill(params, {"tokens": jnp.asarray([seq], jnp.int32)},
                                impl="reference")
        seq.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(np.array(seq[-4:]), outs[0])


def test_traffic_generation_and_workload():
    tc = TrafficConfig(n_requests=8, n_history=16,
                       candidate_counts=(8, 16, 32), seed=0)
    reqs = generate_traffic(tc, n_items=1000)
    assert len(reqs) == 8
    assert all(len(r["candidates"]) in (8, 16, 32) for r in reqs)
    res = run_workload(lambda h, c: None, reqs, concurrency=2)
    assert res["requests"] == 8
    assert res["throughput_items_per_s"] > 0
