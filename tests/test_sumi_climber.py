"""SUMI semantics + Climber model properties (the paper's core invariants)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import sumi
from repro.core.climber import climber_forward, climber_init, build_climber
from repro.types import ClimberConfig


def small_cfg(**kw):
    base = dict(vocab_size=3000, d_model=128, d_ff=256, n_heads=4,
                n_kv_heads=4, head_dim=32,
                climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    base.update(kw)
    return dataclasses.replace(get_config("climber"), **base)


def _batch(cfg, b=2, n=64, m=16, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    return {
        "history": jax.random.randint(ks[0], (b, n), 0, cfg.vocab_size),
        "candidates": jax.random.randint(ks[1], (b, m), 0, cfg.vocab_size),
        "side": jax.random.normal(ks[2], (b, 12)),
        "labels": (jax.random.uniform(ks[3], (b, m, 3)) > 0.5).astype(jnp.float32),
    }


def test_candidate_independence():
    """THE SUMI property: a candidate's score must not depend on which other
    candidates share the request (paper: parallel scoring w/ custom mask)."""
    cfg = small_cfg()
    params, _ = climber_init(jax.random.key(0), cfg)
    batch = _batch(cfg, m=16)
    lg_full = climber_forward(params, batch, cfg)
    b2 = dict(batch)
    b2["candidates"] = batch["candidates"][:, :5]
    lg_sub = climber_forward(params, b2, cfg)
    np.testing.assert_allclose(np.asarray(lg_full[:, :5], np.float32),
                               np.asarray(lg_sub, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_candidate_permutation_equivariance():
    cfg = small_cfg()
    params, _ = climber_init(jax.random.key(0), cfg)
    batch = _batch(cfg, m=8)
    perm = jnp.array([3, 1, 7, 0, 5, 2, 6, 4])
    lg = climber_forward(params, batch, cfg)
    b2 = dict(batch)
    b2["candidates"] = batch["candidates"][:, perm]
    lg_p = climber_forward(params, b2, cfg)
    np.testing.assert_allclose(np.asarray(lg[:, perm], np.float32),
                               np.asarray(lg_p, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_history_matters():
    cfg = small_cfg()
    params, _ = climber_init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    lg = climber_forward(params, batch, cfg)
    b2 = dict(batch)
    b2["history"] = jax.random.randint(jax.random.key(99),
                                       batch["history"].shape, 0,
                                       cfg.vocab_size)
    lg2 = climber_forward(params, b2, cfg)
    assert np.abs(np.asarray(lg) - np.asarray(lg2)).max() > 1e-3


def test_bundle_loss_and_scores():
    cfg = small_cfg()
    bundle = build_climber(cfg)
    params, _ = bundle.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = bundle.loss_fn(params, batch)
    assert 0.3 < float(loss) < 1.2    # ~ln2 at init
    scores = bundle.prefill(params, batch)
    assert scores.shape == (2, 16, 3)
    assert float(scores.min()) >= 0.0 and float(scores.max()) <= 1.0


def test_flops_model_matches_paper_order():
    """Paper Table 2: base = 3.72e9, long = 1.64e10 FLOPs per request.
    With our d_model estimate the analytic model must land within ~5x and
    preserve the base:long ratio (~4.4x)."""
    base = sumi.flops_per_request(512, 128, 2, 12, 256, 1024)
    long_ = sumi.flops_per_request(1024, 512, 2, 12, 256, 1024)
    assert 1e9 < base < 2e10
    ratio = long_ / base
    assert 2.5 < ratio < 6.0


def test_adaptive_temperature_changes_scores():
    cfg = small_cfg()
    params, _ = climber_init(jax.random.key(0), cfg)
    batch = _batch(cfg)
    lg = climber_forward(params, batch, cfg)
    p2 = jax.tree.map(lambda x: x, params)
    for b in p2["blocks"].values():
        b["temp"] = b["temp"] + 3.0
    lg2 = climber_forward(p2, batch, cfg)
    assert np.abs(np.asarray(lg) - np.asarray(lg2)).max() > 1e-3


def test_sumi_mask_dense():
    m = np.asarray(sumi.sumi_mask(4, 3))
    assert m.shape == (7, 7)
    assert m[5, 4] == False and m[5, 5] == True and m[5, 0] == True  # noqa: E712
