"""Overload discipline & fault tolerance (ISSUE 9).

Three layers of coverage:

1. `_AdmissionQueue` / `_PipelinedEngine` units via a trivial sleep engine
   (no model): EDF vs FIFO ordering, tier validation, per-tier deadline
   defaults, tiered shedding in both directions, the watchdog backstop,
   degradation-ladder mechanics, and a concurrent-submitter stress run
   whose only assertion that matters is liveness — every future resolves.
2. FlameEngine integration on the reduced climber: a fatal mid-dispatch
   fault fails every rider in the poisoned batch with the ORIGINAL
   traceback, single-flight encode recovery survives a dead leader,
   eviction storms force re-encodes, degradation levels 2/3 reshape
   bulk-tier work, per-family/per-tier deadline-miss breakouts populate.
3. Chaos: a seeded `FaultInjector` replays an identical fault schedule,
   and a mixed-arm chaos run resolves (or errors) every single future —
   zero hung, the gate `bench_serving --profile overload` also enforces.
"""
import dataclasses
import threading
import time
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pda import RemoteFeatureStore
from repro.models import build_model
from repro.serving.api import (DeadlineExceeded, DegradationPolicy,
                               DegradedError, RejectedError, ServeRequest,
                               ShedError, WatchdogTimeout)
from repro.serving.engine import (FlameEngine, _AdmissionQueue,
                                  _AdmissionRecord, _PipelinedEngine)
from repro.serving.faults import FaultInjected, FaultInjector
from repro.serving.scheduler import run_workload_async
from repro.types import ClimberConfig


# ---------------------------------------------------------------------------
# layer 1: admission queue + pipeline scaffolding (no model)
# ---------------------------------------------------------------------------

class _SleepEngine(_PipelinedEngine):
    """Minimal engine: sleeps a fixed service time, returns zeros."""

    def __init__(self, service_s=0.0, **kw):
        self._service_s = service_s
        super().__init__(**kw)

    def _execute(self, req):
        if self._service_s:
            time.sleep(self._service_s)
        return np.zeros((req.m, 3), np.float32), {"execute_s": self._service_s}


def _req(m=4, tier="standard", deadline=None, seed=0):
    rng = np.random.default_rng(seed)
    return ServeRequest(history=rng.integers(0, 100, 8).astype(np.int32),
                        candidates=rng.integers(0, 100, m).astype(np.int32),
                        slo_tier=tier, deadline_s=deadline)


def _rec(q, deadline_abs, tier):
    fut = Future()
    return _AdmissionRecord(q.key_for(deadline_abs, tier), fut,
                            time.perf_counter(), tier, deadline_abs)


def test_edf_pops_by_deadline_then_tier():
    q = _AdmissionQueue(16, mode="edf")
    late = _rec(q, 10.0, "standard")
    early = _rec(q, 1.0, "bulk")         # earliest deadline wins over tier
    none = _rec(q, None, "interactive")  # deadline-less sorts last
    tie_bulk = _rec(q, 5.0, "bulk")
    tie_int = _rec(q, 5.0, "interactive")  # tier breaks deadline ties
    for r in (late, none, tie_bulk, early, tie_int):
        q.put(r)
    order = [q.get() for _ in range(5)]
    assert order == [early, tie_int, tie_bulk, late, none]


def test_fifo_mode_pops_arrival_order():
    q = _AdmissionQueue(16, mode="fifo")
    recs = [_rec(q, 10.0 - i, "interactive" if i % 2 else "bulk")
            for i in range(4)]
    for r in recs:
        q.put(r)
    assert [q.get() for _ in range(4)] == recs


def test_shed_victim_takes_strictly_worse_only():
    q = _AdmissionQueue(16, mode="edf")
    best = _rec(q, 1.0, "interactive")
    mid = _rec(q, 5.0, "standard")
    worst = _rec(q, 50.0, "bulk")
    for r in (best, mid, worst):
        q.put(r)
    probe = _rec(q, 2.0, "interactive")
    assert q.shed_victim(probe.key) is worst
    assert q.qsize() == 2
    # nothing queued ranks below the worst remaining record: no victim
    assert q.shed_victim(mid.key) is None
    # shed records are skipped at the heap root, never served
    assert q.get() is best and q.get() is mid and q.qsize() == 0


def test_unknown_tier_rejected_at_submit():
    eng = _SleepEngine(n_workers=1, name="t")
    try:
        with pytest.raises(ValueError, match="unknown slo_tier"):
            eng.submit(_req(tier="turbo"))
    finally:
        eng.shutdown()


def test_tier_default_deadline_applies():
    """A request with no explicit deadline inherits its tier's default —
    proven by the admission-time shed of an already-blown budget."""
    eng = _SleepEngine(n_workers=1, name="t",
                       slo_tier_defaults={"interactive": 0.001})
    try:
        r = _req(tier="interactive")
        time.sleep(0.01)               # blow the 1 ms budget pre-submit
        with pytest.raises(DeadlineExceeded):
            eng.submit(r)
        assert eng.metrics()["deadline_shed"] == 1
        # standard tier has no default here: same staleness admits fine
        r2 = _req(tier="standard")
        time.sleep(0.01)
        eng.submit(r2).result(timeout=30)
    finally:
        eng.shutdown()


def test_tiered_shed_displaces_bulk_victim():
    """Queue at capacity with bulk work: an interactive arrival sheds the
    worst bulk victim (ShedError into ITS future) and is itself admitted."""
    eng = _SleepEngine(n_workers=0, name="t", max_pending=4,
                       shed_policy="tiered",
                       slo_tier_defaults={"interactive": 5.0, "bulk": 50.0})
    try:
        bulk_futs = [eng.submit(_req(tier="bulk")) for _ in range(4)]
        int_fut = eng.submit(_req(tier="interactive"))
        shed = [f for f in bulk_futs if f.done()]
        assert len(shed) == 1
        with pytest.raises(ShedError, match="displaced"):
            shed[0].result()
        assert not int_fut.done()
        m = eng.metrics()
        assert m["shed_bulk"] == 1 and m["shed_total"] == 1
    finally:
        eng.shutdown()


def test_tiered_shed_rejects_incoming_when_it_is_lowest():
    """Queue full of interactive work: a bulk arrival IS the lowest-value
    work in sight and is shed at admission instead of displacing anyone."""
    eng = _SleepEngine(n_workers=0, name="t", max_pending=4,
                       shed_policy="tiered",
                       slo_tier_defaults={"interactive": 5.0, "bulk": 50.0})
    try:
        int_futs = [eng.submit(_req(tier="interactive")) for _ in range(4)]
        with pytest.raises(ShedError, match="no lower-priority victim"):
            eng.submit(_req(tier="bulk"))
        assert not any(f.done() for f in int_futs)
        assert eng.metrics()["shed_bulk"] == 1
    finally:
        eng.shutdown()


def test_retry_after_hint_on_shed_and_queue_full():
    """Rejections price their own backoff: both shed flavours (displaced
    victim + at-admission) and a plain full queue carry ``retry_after_s``
    derived from the queue-delay EWMA, positive once the engine has
    observed one service time."""
    eng = _SleepEngine(service_s=0.05, n_workers=1, name="t", max_pending=2,
                       shed_policy="tiered",
                       slo_tier_defaults={"interactive": 5.0, "bulk": 50.0})
    try:
        eng.submit(_req(tier="bulk")).result(timeout=30)   # warm the EWMA
        futs = [eng.submit(_req(tier="bulk")) for _ in range(3)]
        with pytest.raises(ShedError) as ei:
            eng.submit(_req(tier="bulk"))                  # incoming is shed
        assert ei.value.retry_after_s and ei.value.retry_after_s > 0
        int_fut = eng.submit(_req(tier="interactive"))     # displaces a bulk
        for f in futs:
            try:
                f.result(timeout=30)                       # serviced, or...
            except ShedError as err:                       # ...displaced
                assert err.retry_after_s and err.retry_after_s > 0
        int_fut.result(timeout=30)
    finally:
        eng.shutdown()
    # shed_policy="none": the raw queue.Full path prices the same hint
    eng = _SleepEngine(service_s=0.05, n_workers=1, name="t", max_pending=1)
    try:
        eng.submit(_req()).result(timeout=30)
        with pytest.raises(RejectedError) as ei:
            for _ in range(16):                            # race the worker
                eng.submit(_req(), timeout=0)
        assert ei.value.retry_after_s is not None
        assert ei.value.retry_after_s > 0
    finally:
        eng.shutdown()


def test_run_workload_async_surfaces_retry_hints():
    """The workload driver aggregates backoff hints: an overloaded engine
    driven with ``tolerate_errors=True`` reports how many rejections were
    priced and their mean, instead of raising."""
    eng = _SleepEngine(service_s=0.05, n_workers=1, name="t", max_pending=2,
                       shed_policy="tiered",
                       slo_tier_defaults={"standard": 30.0})
    try:
        eng.submit(_req()).result(timeout=30)              # warm the EWMA
        reqs = [{"history": np.arange(8, dtype=np.int32),
                 "candidates": np.arange(4, dtype=np.int32)}
                for _ in range(12)]
        res = run_workload_async(eng, reqs, tolerate_errors=True)
        total_rej = res["rejected"] + res["failed"]
        assert total_rej > 0 and res["hung"] == 0
        assert res["retry_after_hinted"] > 0
        assert res["retry_after_mean_ms"] > 0
    finally:
        eng.shutdown()


def test_edf_beats_fifo_on_interactive_goodput():
    """The tentpole ordering claim at unit scale: a burst of bulk work ahead
    of a few interactive requests.  FIFO strands the interactive tail past
    its SLO; EDF serves it first and meets every deadline."""
    slo = {"interactive": 0.1, "bulk": 30.0}

    def goodput(admission):
        eng = _SleepEngine(service_s=0.01, n_workers=1, name=admission,
                           max_pending=64, admission=admission,
                           slo_tier_defaults=slo)
        try:
            futs = [eng.submit(_req(tier="bulk")) for _ in range(16)]
            futs += [eng.submit(_req(tier="interactive")) for _ in range(4)]
            for f in futs:
                f.result(timeout=60)
            return eng.metrics().get("goodput_interactive", 0)
        finally:
            eng.shutdown()

    fifo, edf = goodput("fifo"), goodput("edf")
    # FIFO serves ~16 x 10 ms of bulk first: the 100 ms interactive SLO is
    # unreachable; EDF's worst case is one in-flight bulk + 4 interactive
    assert edf >= 3
    assert edf > fifo


def test_watchdog_fails_stuck_future():
    """No worker ever serves (n_workers=0): the watchdog must fail the
    future grace past its deadline — no request ever hangs."""
    eng = _SleepEngine(n_workers=0, name="t", watchdog_grace_s=0.02,
                       slo_tier_defaults={"standard": 0.02})
    try:
        fut = eng.submit(_req())
        with pytest.raises(WatchdogTimeout, match="unresolved"):
            fut.result(timeout=30)
        assert eng.metrics()["watchdog_timeouts"] == 1
    finally:
        eng.shutdown()


def test_degradation_policy_ladder_reversible():
    pol = DegradationPolicy(threshold_s=0.01, dwell_s=0.0, alpha=1.0)
    assert pol.level == 0
    for want in (1, 2, 3):
        assert pol.observe(1.0) == want
    assert pol.observe(1.0) == 3          # clamped at max_level
    for want in (2, 1, 0):
        assert pol.observe(0.0) == want   # full recovery
    # hysteresis band: between recover (0.005) and threshold (0.01) holds
    pol.observe(1.0)
    assert pol.observe(0.008) == 1


def test_degradation_dwell_rate_limits_steps():
    pol = DegradationPolicy(threshold_s=0.01, dwell_s=10.0, alpha=1.0)
    assert pol.observe(1.0, now=100.0) == 1
    assert pol.observe(1.0, now=100.1) == 1    # inside dwell: no step
    assert pol.observe(1.0, now=111.0) == 2


def test_concurrent_submitters_never_hang():
    """Satellite: N submitter threads push far past queue capacity against
    slow workers + shedding + watchdog.  Every submission must terminate —
    a result, a RejectedError, or a WatchdogTimeout; nothing hangs."""
    eng = _SleepEngine(service_s=0.002, n_workers=2, name="stress",
                       max_pending=8, shed_policy="tiered",
                       watchdog_grace_s=1.0,
                       slo_tier_defaults={"interactive": 0.5,
                                          "standard": 2.0, "bulk": 5.0})
    outcomes = {"ok": 0, "rejected": 0, "failed": 0}
    lock = threading.Lock()
    tiers = ("interactive", "standard", "bulk")

    def submitter(i):
        for j in range(20):
            try:
                fut = eng.submit(_req(tier=tiers[(i + j) % 3]), timeout=10.0)
                fut.result(timeout=30)
                k = "ok"
            except RejectedError:
                k = "rejected"
            except Exception:
                k = "failed"
            with lock:
                outcomes[k] += 1

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(6)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads), \
            f"submitters hung: {outcomes}"
        assert sum(outcomes.values()) == 6 * 20
        assert outcomes["ok"] > 0
    finally:
        eng.shutdown()


def test_shutdown_fails_queued_futures():
    eng = _SleepEngine(n_workers=0, name="t")
    futs = [eng.submit(_req()) for _ in range(3)]
    eng.shutdown()
    for f in futs:
        with pytest.raises(RuntimeError, match="shut down"):
            f.result(timeout=5)


# ---------------------------------------------------------------------------
# layer 2 + 3: FlameEngine integration and chaos
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def climber_setup():
    cfg = dataclasses.replace(
        get_config("climber"), vocab_size=10_000, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=2, head_dim=32,
        climber=ClimberConfig(num_blocks=2, layers_per_block=2))
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.key(0))
    return cfg, bundle, params


def _flame(bundle, params, **kw):
    base = dict(n_history=64, buckets=(32, 16), n_streams=2,
                feature_mode="off",
                store=RemoteFeatureStore(latency_s=0.0, feature_dim=12),
                window_s=0.02, coalesce=True, max_batch=4, n_workers=4)
    base.update(kw)
    return FlameEngine(bundle, params, **base)


def _traffic(n, seed=0, users=None, m=16):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        r = {"history": rng.integers(0, 1000, 64).astype(np.int32),
             "candidates": rng.integers(0, 1000, m).astype(np.int32)}
        if users:
            r["user_id"] = i % users
        out.append(r)
    return out


def test_fatal_dispatch_fault_fails_all_riders_with_traceback(climber_setup):
    """Satellite: one poisoned dispatch must fail every rider coalesced
    into that batch, each seeing the ORIGINAL exception with its traceback
    rooted in the dispatch attempt — not a generic 'batch failed'."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, buckets=(16,), window_s=0.05)
    reqs = _traffic(4, seed=1)
    run_workload_async(eng, reqs)      # warm: executors compiled
    # arm AFTER warmup so the one fatal fault hits a full candidate batch
    inj = FaultInjector(dispatch_p=1.0, dispatch_times=1,
                        dispatch_transient=False, seed=0)
    eng._faults = inj
    eng.dso._fault_hook = inj.dispatch
    futs = [eng.submit(ServeRequest(history=r["history"],
                                    candidates=r["candidates"]))
            for r in reqs]
    errors = []
    for f in futs:
        try:
            f.result(timeout=60)
        except FaultInjected as e:
            errors.append(e)
    assert len(errors) >= 2, "the poisoned batch carried co-riders"
    for e in errors:
        assert "injected dispatch failure" in str(e)
        frames = []
        tb = e.__traceback__
        while tb is not None:
            frames.append(tb.tb_frame.f_code.co_filename)
            tb = tb.tb_next
        assert any(f.endswith("faults.py") for f in frames), \
            "rider lost the original traceback"
    eng.shutdown()


def test_transient_dispatch_fault_retried_to_success(climber_setup):
    cfg, bundle, params = climber_setup
    inj = FaultInjector(dispatch_p=1.0, dispatch_times=2,
                        dispatch_transient=True, seed=0)
    eng = _flame(bundle, params, buckets=(16,), faults=inj,
                 dispatch_retries=3)
    out = run_workload_async(eng, _traffic(4, seed=2))
    assert out["resolved"] == 4
    m = eng.metrics()
    assert m["fault_dispatch_fired"] == 2
    assert m["dso_dispatch_retries"] >= 2
    assert m["dso_dispatch_failures"] == 0
    eng.shutdown()


def test_single_flight_encode_recovery(climber_setup):
    """A follower coalesced behind a dead encode leader recovers: it
    re-enters, becomes the new leader, and serves — counting
    ``encode_recoveries`` — instead of inheriting the leader's failure."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, history_cache=True, pool_slots=8)
    req = ServeRequest(history=np.arange(64).astype(np.int32),
                       candidates=np.arange(16).astype(np.int32), user_id=7)
    key_fp = eng._pool_key(req)
    hist = np.asarray(req.history[None, :eng.n_history], np.int32)
    # play the doomed leader by hand: register an inflight encode future,
    # let a follower block on it, then die (deregister + fail)
    doomed = Future()
    with eng._encode_lock:
        eng._encode_inflight[key_fp] = doomed
    result = {}

    def follower():
        result["kv"], result["path"], _ = eng._lookup_or_encode(
            req, hist, memo=key_fp)

    th = threading.Thread(target=follower)
    th.start()
    time.sleep(0.05)                   # follower reaches fut.result()
    with eng._encode_lock:
        eng._encode_inflight.pop(key_fp, None)
    doomed.set_exception(FaultInjected("injected encode death",
                                       transient=False))
    th.join(timeout=60)
    assert not th.is_alive()
    assert result["path"] == "encode"  # re-entered as the new leader
    assert eng.metrics()["encode_recoveries"] == 1
    # and the recovered entry actually serves
    resp = eng.submit(req).result(timeout=60)
    assert resp.output.shape == (16, 3)
    eng.shutdown()


def test_eviction_storm_forces_reencode_not_failure(climber_setup):
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params, history_cache=True, pool_slots=16)
    reqs = _traffic(6, seed=3, users=3)
    run_workload_async(eng, reqs)      # pool warm: 3 user entries
    inj = FaultInjector(evict_p=1.0, evict_fraction=1.0, seed=0)
    assert inj.pool_storm(eng.history_pool) >= 1
    pool_misses0 = eng.metrics()["pool_misses"]
    out = run_workload_async(eng, reqs)
    assert out["resolved"] == 6        # storms cost re-encodes, never errors
    assert eng.metrics()["pool_misses"] > pool_misses0
    eng.shutdown()


def test_degrade_level3_bulk_cached_hit_or_shed(climber_setup):
    cfg, bundle, params = climber_setup
    # recover_s=0.0: the forced level cannot decay while workers feed tiny
    # real queue delays into the policy mid-test
    pol = DegradationPolicy(threshold_s=0.001, recover_s=0.0, dwell_s=0.0,
                            alpha=1.0)
    eng = _flame(bundle, params, history_cache=True, pool_slots=8,
                 degradation=pol)

    def req(lo, uid, tier):
        return ServeRequest(
            history=np.arange(lo, lo + 64).astype(np.int32),
            candidates=np.arange(16).astype(np.int32),
            user_id=uid, slo_tier=tier)

    eng.submit(req(0, 1, "bulk")).result(timeout=60)   # pool warm
    for _ in range(3):
        pol.observe(1.0)               # force level 3
    assert pol.level == 3
    # warm session: served from cache, no encode dispatch
    resp = eng.submit(req(0, 1, "bulk")).result(timeout=60)
    assert resp.output.shape == (16, 3)
    # cold session: encode suppressed -> DegradedError, counted
    with pytest.raises(DegradedError, match="level-3"):
        eng.submit(req(100, 2, "bulk")).result(timeout=60)
    assert eng.metrics()["degrade_shed"] == 1
    # interactive traffic is untouched at level 3
    resp = eng.submit(req(100, 3, "interactive")).result(timeout=60)
    assert resp.output.shape == (16, 3)
    eng.shutdown()


def test_per_tier_and_per_family_deadline_miss_breakout(climber_setup):
    """Satellite: a guaranteed miss lands in both breakout ledgers —
    per-tier on the engine, per-executor-family on the DSO."""
    cfg, bundle, params = climber_setup
    eng = _flame(bundle, params)
    run_workload_async(eng, _traffic(2, seed=4))   # warm (no deadlines)
    r = _traffic(1, seed=5)[0]
    # the budget must die on EXECUTION, not queueing — the deadline-aware
    # DSO flushes early to save a near-deadline chunk, so a mere window-
    # sized budget is met.  2 ms is admissible (creation->submit is µs)
    # but unmeetable: the warm full pass alone runs ~3-4 ms on this model
    fut = eng.submit(ServeRequest(history=r["history"],
                                  candidates=r["candidates"],
                                  slo_tier="interactive",
                                  deadline_s=0.002))
    fut.result(timeout=60)             # a miss still serves (soft SLO)
    m = eng.metrics()
    assert m["deadline_misses"] >= 1
    assert m["deadline_misses_interactive"] >= 1
    assert m["dso_deadline_miss_chunks"] >= 1
    assert any(k.startswith("dso_deadline_miss_chunks_") and v > 0
               for k, v in m.items())
    eng.shutdown()


def test_fault_injector_is_deterministic():
    spec = "dispatch:0.4,stall:0.3:0.001,evict:0.2"

    def schedule(seed):
        inj = FaultInjector.parse(spec, seed=seed)
        fired = []
        for _ in range(32):
            try:
                inj.dispatch("full", 16)
                fired.append(0)
            except FaultInjected:
                fired.append(1)
        return fired, inj.stats()

    a, sa = schedule(seed=9)
    b, sb = schedule(seed=9)
    assert a == b and sa == sb and sum(a) > 0
    c, _ = schedule(seed=10)
    assert a != c                      # the seed is the schedule


def test_chaos_mixed_arms_zero_hung_futures(climber_setup):
    """The liveness gate at test scale: dispatch faults + stalls + eviction
    storms + shedding + degradation + watchdog, every future resolves."""
    cfg, bundle, params = climber_setup
    inj = FaultInjector.parse("dispatch:0.2,stall:0.15:0.002,evict:0.15",
                              seed=5)
    eng = _flame(bundle, params, history_cache=True, pool_slots=16,
                 max_pending=8, shed_policy="tiered", faults=inj,
                 degradation=DegradationPolicy(threshold_s=0.05),
                 watchdog_grace_s=2.0,
                 slo_tier_defaults={"interactive": 0.5, "standard": 2.0,
                                    "bulk": 10.0})
    reqs = _traffic(12, seed=6, users=4)
    tiers = ("interactive", "standard", "bulk")
    for i, r in enumerate(reqs):
        r["slo_tier"] = tiers[i % 3]
    total = {"resolved": 0, "rejected": 0, "failed": 0, "hung": 0}
    for _ in range(2):
        out = run_workload_async(eng, reqs, tolerate_errors=True,
                                 result_timeout_s=60.0)
        for k in total:
            total[k] += out[k]
    assert total["hung"] == 0, f"liveness violated: {total}"
    assert total["resolved"] + total["rejected"] + total["failed"] \
        == 2 * len(reqs)
    assert total["resolved"] > 0
    m = eng.metrics()
    assert m["fault_dispatch_fired"] + m["fault_stall_fired"] \
        + m["fault_evict_fired"] > 0
    eng.shutdown()
