"""Threaded stress regression for HistoryKVPool (device + spill tiers).

Runtime counterpart of flamecheck's lock-discipline pass: hammer the pool
with concurrent put/lookup/extend traffic and assert the invariants the
static pass can only prove are *guarded*, not *correct* —

- byte accounting: ``bytes_used`` / ``spill_bytes_used`` equal the sum of
  resident entry sizes and never exceed their budgets;
- slot accounting: never more than ``slots`` primary entries;
- counter conservation: every counted lookup lands in exactly one of
  hits/misses (stale folds into misses by contract);
- no lost updates: with capacity for every writer, each writer's final
  put is the state a later reader sees.
"""
import threading

import numpy as np
import pytest

from repro.serving.kv_cache import HistoryKVPool, payload_bytes

N_THREADS = 8
N_OPS = 120


def _kv(seed: int, rows: int = 4):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((rows, 8)).astype(np.float32),
            rng.standard_normal((rows, 8)).astype(np.float32))


def _run_threads(fn):
    errs = []

    def wrap(tid):
        try:
            fn(tid)
        except BaseException as e:  # noqa: BLE001 — surface in main thread
            errs.append(e)

    threads = [threading.Thread(target=wrap, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]


def _assert_accounting(pool: HistoryKVPool):
    """Quiescent-state accounting invariants (threads joined)."""
    primary = sum(e.nbytes for e in pool._entries.values())
    spilled = sum(e.nbytes for e in pool._spill.values())
    assert pool.bytes_used == primary, \
        f"bytes_used={pool.bytes_used} but entries sum to {primary}"
    assert pool.spill_bytes_used == spilled, \
        f"spill_bytes_used={pool.spill_bytes_used} vs {spilled}"
    if pool.budget_bytes is not None:
        assert pool.bytes_used <= pool.budget_bytes
    assert pool.spill_bytes_used <= pool.spill_budget
    if pool.slots is not None:
        assert len(pool) <= pool.slots


def test_concurrent_churn_budget_and_counter_invariants():
    """Shared hot keyspace sized to force eviction + spill demotion."""
    one = payload_bytes(_kv(0))
    pool = HistoryKVPool(slots=6, budget_bytes=4 * one + 1,
                         placement="host", spill_bytes=3 * one + 1)
    lookups = [0] * N_THREADS
    puts = [0] * N_THREADS

    def worker(tid):
        rng = np.random.default_rng(tid)
        for i in range(N_OPS):
            key = ("u", int(rng.integers(10)))
            # two rotating fingerprints per key force stale transitions
            fp = f"fp{(i // 7) % 2}"
            kv, status, basis = pool.lookup(key, fp, want_basis=True)
            lookups[tid] += 1
            if status == "hit":
                assert kv is not None and len(kv) == 2
            else:
                assert kv is None
                if status == "stale" and basis is not None:
                    pool.count_extension()
                pool.put(key, fp, _kv(hash(key) & 0xffff),
                         hist_window=np.arange(16, dtype=np.int32),
                         refreshes=0)
                puts[tid] += 1

    _run_threads(worker)
    _assert_accounting(pool)
    st = pool.stats()
    assert st["hits"] + st["misses"] == sum(lookups), \
        "every counted lookup must land in exactly one of hits/misses"
    assert pool.extensions <= pool.stale
    # churn actually happened — otherwise this test proves nothing
    assert st["misses"] > 0 and pool.evictions > 0


def test_concurrent_disjoint_writers_no_lost_updates():
    """With room for every entry, each writer's final put must survive."""
    keys_per_thread = 4
    n_keys = N_THREADS * keys_per_thread
    one = payload_bytes(_kv(0))
    pool = HistoryKVPool(slots=n_keys, budget_bytes=n_keys * one + 1,
                         placement="host")
    final_fp = {}

    def worker(tid):
        for i in range(N_OPS):
            key = ("t", tid, i % keys_per_thread)
            fp = f"{tid}-{i}"
            pool.put(key, fp, _kv(tid * 1000 + i % keys_per_thread),
                     hist_window=np.arange(8, dtype=np.int32))
            final_fp[key] = fp     # per-key writes are single-threaded
            # re-read our own write: single writer per key + ample
            # capacity means it must still be resident and fresh
            kv, status, _ = pool.lookup(key, fp)
            assert status == "hit", f"own write lost: {key} -> {status}"
            # peek (uncounted, non-destructive) at another thread's key to
            # stress concurrent reads without tripping the stale-drop
            # contract (a mismatched *lookup* fingerprint evicts on purpose)
            other = ("t", (tid + 1) % N_THREADS, i % keys_per_thread)
            pool.peek(other, "whatever")

    _run_threads(worker)
    _assert_accounting(pool)
    assert len(pool) == n_keys
    for key, fp in final_fp.items():
        kv, status, _ = pool.lookup(key, fp)
        assert status == "hit", f"lost update: {key} fp={fp} -> {status}"
        tid = key[1]
        i = key[2]
        expect = _kv(tid * 1000 + i)
        np.testing.assert_allclose(np.asarray(kv[0]), expect[0], rtol=1e-6)


def test_concurrent_extend_refresh_counters():
    """count_extension / count_refresh_reencode from many threads."""
    pool = HistoryKVPool(slots=4, placement="host")
    per_thread = 50

    def worker(tid):
        for i in range(per_thread):
            pool.count_extension()
            if i % 5 == 0:
                pool.count_refresh_reencode()

    _run_threads(worker)
    assert pool.extensions == N_THREADS * per_thread
    assert pool.refresh_reencodes == N_THREADS * (per_thread // 5)


@pytest.mark.parametrize("dtype", ["native", "int8"])
def test_concurrent_quantized_churn(dtype):
    """Quantized entries keep exact byte accounting under churn."""
    one = payload_bytes(_kv(0))
    pool = HistoryKVPool(slots=5, budget_bytes=6 * one, dtype=dtype,
                         placement="host", spill_bytes=2 * one)

    def worker(tid):
        for i in range(60):
            key = int((tid + i) % 8)
            if pool.get(key, "fp") is None:
                pool.put(key, "fp", _kv(key))

    _run_threads(worker)
    _assert_accounting(pool)
