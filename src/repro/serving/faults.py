"""Chaos-injection layer for the serving stack (ISSUE 9).

A :class:`FaultInjector` is a deterministic, seedable source of the faults
a production replica actually sees, wired into the engine's hook points:

  ``dispatch(kind, bucket)``   raised *inside* the DSO's executor-run retry
                               loop — a transient :class:`FaultInjected`
                               exercises bounded retry-with-backoff; a
                               fatal one must propagate into every rider's
                               ResponseFuture (never strand a batch).
  ``worker_stall()``           sleeps a pipeline worker mid-request — the
                               watchdog (deadline + grace) is the backstop.
  ``pool_storm(pool)``         eviction storm: drops a fraction of the
                               HistoryKVPool's entries, forcing re-encodes
                               (a cold-restart / pressure-spike stand-in).

Every arm is an independent Bernoulli roll from one seeded PRNG, so a
given (spec, seed) pair replays the identical fault schedule — chaos tests
are regular deterministic tests.  All hooks are thread-safe.

Spec grammar (CLI ``--fault-spec``), comma-separated arms:

  ``dispatch:P[:TIMES]``        transient dispatch failure with prob P,
                                at most TIMES fires (default unlimited)
  ``dispatch_fatal:P[:TIMES]``  same, but non-transient (no retry)
  ``stall:P[:SECONDS]``         worker stall of SECONDS (default 0.01)
  ``evict:P[:FRACTION]``        pool eviction storm dropping FRACTION of
                                entries (default 0.5)

e.g. ``--fault-spec dispatch:0.2,stall:0.1:0.02,evict:0.1``.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, Optional


class FaultInjected(RuntimeError):
    """An injected fault.  ``transient=True`` marks it retryable: the DSO's
    dispatch loop retries it with backoff; a non-transient instance (or an
    exhausted retry budget) propagates into the affected futures."""

    def __init__(self, message: str, *, transient: bool = True):
        super().__init__(message)
        self.transient = transient


class _Arm:
    """One fault arm: Bernoulli(p), optionally capped at ``times`` fires."""

    def __init__(self, p: float, times: Optional[int] = None,
                 arg: float = 0.0):
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"fault probability must be in [0, 1], got {p}")
        self.p = float(p)
        self.times = times
        self.arg = float(arg)
        self.fired = 0

    def roll(self, rng: random.Random) -> bool:
        """Caller holds the injector lock."""
        if self.p <= 0.0 or (self.times is not None
                             and self.fired >= self.times):
            return False
        if rng.random() >= self.p:
            return False
        self.fired += 1
        return True


class FaultInjector:
    """Deterministic fault source; see the module docstring for semantics.

    Construct programmatically (tests) or via :meth:`parse` (CLI).  A zero
    probability disables an arm, so the default injector is inert."""

    def __init__(self, *, dispatch_p: float = 0.0,
                 dispatch_times: Optional[int] = None,
                 dispatch_transient: bool = True,
                 stall_p: float = 0.0, stall_s: float = 0.01,
                 evict_p: float = 0.0, evict_fraction: float = 0.5,
                 seed: int = 0):
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._dispatch = _Arm(dispatch_p, dispatch_times)
        self._dispatch_transient = bool(dispatch_transient)
        self._stall = _Arm(stall_p, arg=stall_s)
        self._evict = _Arm(evict_p, arg=evict_fraction)
        self.spec = (f"dispatch:{dispatch_p},stall:{stall_p}:{stall_s},"
                     f"evict:{evict_p}:{evict_fraction}")

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultInjector":
        """Build an injector from the CLI spec grammar (module docstring)."""
        kw: Dict[str, object] = {"seed": seed}
        for arm in filter(None, (a.strip() for a in spec.split(","))):
            parts = arm.split(":")
            name, p = parts[0], float(parts[1]) if len(parts) > 1 else 0.0
            arg = float(parts[2]) if len(parts) > 2 else None
            if name == "dispatch" or name == "dispatch_fatal":
                kw["dispatch_p"] = p
                kw["dispatch_transient"] = name == "dispatch"
                if arg is not None:
                    kw["dispatch_times"] = int(arg)
            elif name == "stall":
                kw["stall_p"] = p
                if arg is not None:
                    kw["stall_s"] = arg
            elif name == "evict":
                kw["evict_p"] = p
                if arg is not None:
                    kw["evict_fraction"] = arg
            else:
                raise ValueError(f"unknown fault arm {name!r} in {spec!r}")
        inj = cls(**kw)          # type: ignore[arg-type]
        inj.spec = spec
        return inj

    # ---- hook points (called from engine/DSO threads) ----
    def dispatch(self, kind: str, bucket: int) -> None:
        """DSO pre-executor hook: maybe raise a dispatch failure."""
        with self._lock:
            fire = self._dispatch.roll(self._rng)
            transient = self._dispatch_transient
        if fire:
            raise FaultInjected(
                f"injected dispatch failure ({kind}, b{bucket})",
                transient=transient)

    def worker_stall(self) -> None:
        """Pipeline-worker hook: maybe stall this worker."""
        with self._lock:
            fire = self._stall.roll(self._rng)
            dur = self._stall.arg
        if fire:
            time.sleep(dur)

    def pool_storm(self, pool) -> int:
        """Maybe drop a fraction of ``pool``'s primary-tier entries (via
        ``HistoryKVPool.drop``); returns the number evicted."""
        with self._lock:
            fire = self._evict.roll(self._rng)
            frac = self._evict.arg
            if fire:
                # draw victims under the same lock so the schedule stays
                # a pure function of (spec, seed, call order)
                keys = pool.keys()
                n = max(1, int(len(keys) * frac)) if keys else 0
                victims = self._rng.sample(keys, n) if n else []
        if not fire:
            return 0
        dropped = 0
        for k in victims:
            dropped += int(pool.drop(k))
        return dropped

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "fault_dispatch_fired": self._dispatch.fired,
                "fault_stall_fired": self._stall.fired,
                "fault_evict_fired": self._evict.fired,
            }
