from repro.serving.engine import FlameEngine, TextServingEngine  # noqa: F401
