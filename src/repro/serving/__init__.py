from repro.serving.api import (AdmissionQueueFull, ResponseFuture,  # noqa: F401
                               ServeMetrics, ServeRequest, ServeResponse,
                               ServingEngine, available_engines,
                               create_engine, register_engine)
# importing engine registers "flame" / "implicit" / "text" in the registry
from repro.serving.engine import (FlameEngine,  # noqa: F401
                                  ImplicitShapeServingEngine,
                                  TextServingEngine)
from repro.serving.kv_cache import HistoryKVPool, KVCacheManager  # noqa: F401
