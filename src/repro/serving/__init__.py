from repro.serving.api import (AdmissionQueueFull,  # noqa: F401
                               DeadlineExceeded, ResponseFuture,
                               ServeMetrics, ServeRequest, ServeResponse,
                               ServingEngine, available_engines,
                               create_engine, register_engine)
# importing engine registers "flame" / "implicit" / "text" in the registry
from repro.serving.engine import (FlameEngine,  # noqa: F401
                                  ImplicitShapeServingEngine,
                                  TextServingEngine)
from repro.serving.kv_cache import HistoryKVPool, KVCacheManager  # noqa: F401
