"""Host-side beam/top-k bookkeeping for generative candidate decode.

The accelerator work of a decode step (vocab scoring + KV append) lives in
``core/climber.py`` / ``core/dso.py``; everything about *which* hypotheses
survive is plain numpy here so the search logic is independently testable
(propcheck invariants in ``tests/test_decode_serving.py``) and shared by
the engine and the tests.

Score convention: a hypothesis's score is the sum of per-step
log-probabilities (log-softmax over the step's token universe), so scores
are monotonically non-increasing as hypotheses grow — the invariant the
propcheck suite pins down.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np


def log_softmax(x: np.ndarray, axis: int = -1) -> np.ndarray:
    """Numerically-stable fp64 log-softmax (host-side ranking only)."""
    x = np.asarray(x, np.float64)
    m = np.max(x, axis=axis, keepdims=True)
    z = x - m
    return z - np.log(np.sum(np.exp(z), axis=axis, keepdims=True))


def beam_step(cum: np.ndarray, seqs: List[Tuple[int, ...]],
              finished: np.ndarray, step_logprobs: np.ndarray,
              width: int, eos: Optional[int],
              universe: Sequence[int]):
    """One beam-search transition over ``width`` live hypotheses.

    ``cum`` [W] cumulative logprobs; ``seqs`` the W token tuples so far;
    ``finished`` [W] bool; ``step_logprobs`` [W, V] this step's
    log-softmax over the token ``universe`` (ignored for finished rows).
    Returns ``(cum', seqs', finished', parents)`` where ``parents`` [W]
    maps each surviving hypothesis to the beam slot it extends (its own
    slot for finished pass-throughs) — the engine uses it to route KV
    appends.

    Invariants (propcheck-asserted): a finished hypothesis contributes
    exactly one candidate — itself, unextended, at its frozen score — so
    finished beams are never re-expanded; live extensions add a
    log-probability (``<= 0``) so ``max(cum')`` never exceeds
    ``max(cum)``; and because a (parent, token) pair is unique and the
    universe carries no duplicate ids, no two live hypotheses are ever
    identical."""
    w = len(cum)
    universe = np.asarray(universe)
    cand_scores: List[float] = []
    cand_src: List[Tuple[int, int]] = []      # (parent slot, token or -1)
    for i in range(w):
        if finished[i]:
            cand_scores.append(float(cum[i]))
            cand_src.append((i, -1))
        else:
            for j, tok in enumerate(universe):
                cand_scores.append(float(cum[i] + step_logprobs[i, j]))
                cand_src.append((i, int(tok)))
    order = np.argsort(-np.asarray(cand_scores), kind="stable")[:width]
    new_cum = np.asarray([cand_scores[o] for o in order], np.float64)
    new_seqs: List[Tuple[int, ...]] = []
    new_fin = np.zeros(len(order), bool)
    parents = np.zeros(len(order), np.int64)
    for slot, o in enumerate(order):
        parent, tok = cand_src[o]
        parents[slot] = parent
        if tok < 0:
            new_seqs.append(seqs[parent])
            new_fin[slot] = True
        else:
            new_seqs.append(seqs[parent] + (tok,))
            new_fin[slot] = (eos is not None and tok == eos)
    return new_cum, new_seqs, new_fin, parents
