"""FLAME Serving API v2 — the request/response surface every engine speaks.

The serving system is addressed through four pieces (see DESIGN.md for the
full request lifecycle diagram):

  ServeRequest / ServeResponse   frozen value types crossing the API boundary
  ResponseFuture                 handle returned by ``submit``; resolves to a
                                 ServeResponse once the pipeline finishes
  ServingEngine                  the protocol all engines implement:
                                 ``submit`` (async), ``serve`` (blocking
                                 sugar), ``metrics``, ``shutdown``
  engine registry                name -> factory, so launchers/benchmarks
                                 select engines with ``--engine flame``

Engines register themselves with :func:`register_engine`; callers construct
them with :func:`create_engine` and never import concrete classes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import (Any, Callable, Dict, Optional, Protocol, Sequence,
                    runtime_checkable)

import numpy as np

_REQUEST_IDS = itertools.count()


# ---------------------------------------------------------------------------
# SLO tiers
# ---------------------------------------------------------------------------

#: Service tiers, best-first.  ``interactive`` is user-facing traffic with a
#: tight budget, ``standard`` is the default, ``bulk`` is background re-rank
#: work that tolerates queueing.  Under overload the engine sheds/degrades
#: bulk first and interactive last (see ``engine._AdmissionQueue``).
SLO_TIERS = ("interactive", "standard", "bulk")

#: Tier -> shed/EDF priority rank (lower = more protected).
TIER_RANK = {t: i for i, t in enumerate(SLO_TIERS)}

#: Tier -> default ``deadline_s`` applied by tier-aware engines when a
#: request carries no explicit deadline (engine-overridable via the
#: ``slo_tier_defaults`` knob / ``--slo-tier-defaults`` CLI flag).
DEFAULT_TIER_DEADLINES = {
    "interactive": 0.05,
    "standard": 0.25,
    "bulk": 2.0,
}


# ---------------------------------------------------------------------------
# value types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopKConfig:
    """Generative decode: grow ``k`` sequences greedily for ``steps`` steps
    (each step keeps the top-k single-token continuations of each sequence's
    own greedy path — k independent greedy beams seeded by the top-k first
    tokens).  ``eos`` (an item id) finishes a sequence early — a finished
    sequence stops decoding and, once every sequence has finished, the
    remaining steps are skipped (counted in ``gen_early_exits``)."""

    k: int = 4
    steps: int = 8
    eos: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class BeamConfig:
    """Generative decode: beam search of ``width`` hypotheses for ``steps``
    steps, ranked by cumulative log-probability; ``eos`` (an item id)
    finishes a hypothesis early — finished beams keep their score and are
    never re-expanded."""

    width: int = 4
    steps: int = 8
    eos: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One upstream request.

    Recommendation engines read ``history`` (item ids) and ``candidates``
    (item ids to score); text engines read ``history`` as prompt token ids
    and generate ``n_tokens``.  ``user_id`` is an optional stable upstream
    identity: cache-aware engines key their history-KV pool by it (falling
    back to a content hash of the history when absent), so repeat-user and
    session-re-rank traffic reuses the cached history encode.

    ``deadline_s`` is an optional per-request latency budget (seconds,
    relative to ``arrival_t``).  Deadline-aware engines order their flush
    queues earliest-deadline-first against it and count overruns in the
    ``deadline_misses`` metric; ``None`` defers to the engine's default
    budget (which may be "no deadline").

    ``slo_tier`` (one of :data:`SLO_TIERS`) places the request on a service
    tier: tier-aware engines derive a default deadline from it (when
    ``deadline_s`` is None), order EDF admission ties by tier, shed
    lowest-tier work first under overload, and degrade bulk-tier service
    first under sustained pressure.
    """

    history: np.ndarray
    candidates: Optional[np.ndarray] = None
    n_tokens: int = 16
    # generative decode (ISSUE 8): a TopKConfig/BeamConfig here asks the
    # engine to GENERATE candidate sequences over the item vocabulary
    # instead of scoring a provided list; ``candidates``, when also given,
    # restricts the per-step token universe to those ids.  The response
    # ``output`` is then ``[width, steps]`` generated item ids, best-first.
    generate: Optional[object] = None
    user_id: Optional[int] = None
    deadline_s: Optional[float] = None
    slo_tier: str = "standard"
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQUEST_IDS))
    arrival_t: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def m(self) -> int:
        """Number of candidates (0 for text requests)."""
        return 0 if self.candidates is None else int(self.candidates.shape[0])


@dataclasses.dataclass(frozen=True)
class ServeResponse:
    """Pipeline output for one request.

    ``output`` is ``[M, num_tasks]`` scores for recommendation engines, or a
    ``[n_tokens]`` generated-id array for text engines.  ``timings`` breaks
    the latency into pipeline stages (queue / features / execute).
    """

    request_id: int
    output: np.ndarray
    latency_s: float
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)


class ResponseFuture:
    """Handle for an in-flight request; resolves to a :class:`ServeResponse`."""

    def __init__(self, request: ServeRequest):
        self.request = request
        self._f: "Future[ServeResponse]" = Future()

    # ---- consumer side ----
    def done(self) -> bool:
        return self._f.done()

    def result(self, timeout: Optional[float] = None) -> ServeResponse:
        return self._f.result(timeout)

    def scores(self, timeout: Optional[float] = None) -> np.ndarray:
        """Convenience: block and return just the output array."""
        return self.result(timeout).output

    def add_done_callback(self, fn: Callable[["ResponseFuture"], None]):
        self._f.add_done_callback(lambda _: fn(self))

    # ---- engine side ----
    def set_result(self, response: ServeResponse):
        self._f.set_result(response)

    def set_exception(self, exc: BaseException):
        self._f.set_exception(exc)


class RejectedError(RuntimeError):
    """Base of every admission-side rejection (overload discipline): the
    engine refused to spend compute on the request.  Callers that tolerate
    shedding catch this one type; the concrete subclasses say why.

    Shedding rejections may carry a ``retry_after_s`` attribute — the
    engine's queue-delay-EWMA estimate of how long the current backlog
    takes to drain — so a well-behaved caller backs off for about one
    drain interval instead of hammering an overloaded engine."""

    retry_after_s: Optional[float] = None


class AdmissionQueueFull(RejectedError):
    """Raised by ``submit`` when the bounded admission queue stays full past
    the caller's timeout (the backpressure signal)."""


class DeadlineExceeded(RejectedError):
    """Raised by ``submit`` when the request's deadline budget has already
    passed at admission time (counted in the ``deadline_shed`` metric):
    executing it would burn an executor slot on a guaranteed miss, so
    deadline-aware engines shed it instead."""


class ShedError(RejectedError):
    """The overloaded engine dropped this request to protect higher-tier /
    earlier-deadline work (counted per tier in ``shed_{tier}``).  Raised
    from ``submit`` when the incoming request itself is the lowest-priority
    work in sight, or delivered through a queued victim's
    :class:`ResponseFuture` when a higher-priority arrival displaced it."""


class DegradedError(RejectedError):
    """A degraded engine (level >= 3) refused the expensive path for a
    bulk-tier request — pool re-encode fell back to cached-hit-or-shed and
    the pool had no fresh entry.  Delivered through the request's future."""


class WatchdogTimeout(RuntimeError):
    """The engine watchdog failed this future ``grace`` seconds past its
    deadline without a response — the no-request-ever-hangs backstop for
    wedged workers / lost dispatches.  Not a :class:`RejectedError`: the
    request was admitted, then lost to a fault."""


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class ServeMetrics:
    """Thread-safe request/latency accounting shared by all engines.

    ``record`` is called from pipeline worker threads concurrently; every
    mutation happens under one lock (the unguarded ``requests += 1`` and
    first/last-timestamp updates used to race under ``run_workload``'s
    thread pool)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.requests = 0
        self.items = 0
        self.first_t = 0.0
        self.last_t = 0.0
        self.latencies: list = []
        self.gauges: Dict[str, float] = {}
        self.counters: Dict[str, int] = {}

    def record(self, n_items: int, latency_s: float):
        now = time.perf_counter()
        with self._lock:
            if self.requests == 0:
                self.first_t = now - latency_s
            self.last_t = now
            self.requests += 1
            self.items += n_items
            self.latencies.append(latency_s)

    def set_gauge(self, name: str, value: float):
        """Point-in-time engine gauge surfaced in ``summary()`` — e.g. the
        history-KV pool's byte accounting (``pool_bytes_used`` vs its
        configured budget), the DSO's cumulative ``padded_fraction``
        (candidate-slot padding dispatched vs reclaimed by segment
        packing) and ``queue_delay_ms`` (mean chunk enqueue-to-dispatch
        delay), updated by the engine as requests flow."""
        with self._lock:
            self.gauges[name] = float(value)

    def incr(self, name: str, by: int = 1):
        """Monotonic engine counter surfaced in ``summary()`` — e.g.
        ``deadline_misses`` (requests that resolved after their
        ``ServeRequest.deadline_s`` budget)."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + by

    def summary(self) -> Dict[str, float]:
        with self._lock:
            lat = np.array(self.latencies) if self.latencies else np.zeros(1)
            wall = max(self.last_t - self.first_t, 1e-9)
            return {
                "requests": self.requests,
                "throughput_items_per_s": self.items / wall,
                "mean_latency_ms": float(lat.mean() * 1e3),
                "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
                **self.gauges,
                **self.counters,
            }


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------

class DegradationPolicy:
    """Steps service down under sustained pressure instead of failing.

    Pipeline workers feed every request's queue delay into ``observe``; the
    policy keeps an EWMA and walks a ladder of degradation levels with
    hysteresis (a dwell time between steps, and a lower recovery threshold
    so the level is reversible without flapping):

      level 0  full service
      level 1  flush immediately — coalescing/tail-packing windows collapse
               to zero, trading batch fill for latency
      level 2  + bulk-tier generation shrinks (beam width and gen steps
               halve), bounding worst-case work per bulk request
      level 3  + bulk-tier history encode falls back to cached-hit-or-shed
               (pool miss => DegradedError instead of an encode dispatch)

    Engines surface the current level as the ``degrade_level`` gauge and
    count transitions in ``degrade_steps``.  Thread-safe; ``observe`` is
    called from every worker."""

    MAX_LEVEL = 3

    def __init__(self, threshold_s: float = 0.05, *,
                 recover_s: Optional[float] = None, alpha: float = 0.3,
                 max_level: int = MAX_LEVEL, dwell_s: float = 0.25):
        if threshold_s <= 0:
            raise ValueError(f"threshold_s must be > 0, got {threshold_s}")
        self.threshold_s = float(threshold_s)
        self.recover_s = float(recover_s if recover_s is not None
                               else threshold_s * 0.5)
        self.alpha = float(alpha)
        self.max_level = int(max_level)
        self.dwell_s = float(dwell_s)
        self._lock = threading.Lock()
        self._ewma: Optional[float] = None
        self._level = 0
        self._last_step_t: Optional[float] = None

    @property
    def level(self) -> int:
        with self._lock:
            return self._level

    @property
    def ewma_s(self) -> float:
        with self._lock:
            return self._ewma or 0.0

    def observe(self, delay_s: float, now: Optional[float] = None) -> int:
        """Fold one queue-delay sample in; returns the (possibly stepped)
        level.  Steps are rate-limited to one per ``dwell_s`` so a single
        burst doesn't slam the ladder to the floor."""
        if now is None:
            now = time.perf_counter()
        with self._lock:
            self._ewma = delay_s if self._ewma is None else \
                self.alpha * delay_s + (1.0 - self.alpha) * self._ewma
            dwelled = (self._last_step_t is None
                       or now - self._last_step_t >= self.dwell_s)
            if dwelled and self._ewma > self.threshold_s \
                    and self._level < self.max_level:
                self._level += 1
                self._last_step_t = now
            elif dwelled and self._ewma < self.recover_s and self._level > 0:
                self._level -= 1
                self._last_step_t = now
            return self._level


# ---------------------------------------------------------------------------
# the engine protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class ServingEngine(Protocol):
    """What every serving engine exposes, regardless of model family."""

    def submit(self, request: ServeRequest, *,
               timeout: Optional[float] = None) -> ResponseFuture:
        """Admit a request into the pipeline; returns immediately with a
        future.  Blocks (up to ``timeout``) when the admission queue is
        full; raises :class:`AdmissionQueueFull` on timeout."""
        ...

    def serve(self, history: np.ndarray,
              candidates: Optional[np.ndarray] = None, **kw) -> np.ndarray:
        """Blocking sugar: submit one request and wait for its output."""
        ...

    def metrics(self) -> Dict[str, Any]:
        """Unified metrics snapshot (request stats + engine internals)."""
        ...

    def shutdown(self) -> None:
        ...


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

_ENGINES: Dict[str, Callable[..., ServingEngine]] = {}


def register_engine(name: str):
    """Class/factory decorator: ``@register_engine("flame")``."""
    def deco(factory):
        _ENGINES[name] = factory
        return factory
    return deco


def available_engines() -> Sequence[str]:
    return sorted(_ENGINES)


def create_engine(name: str, *args, **kwargs) -> ServingEngine:
    try:
        factory = _ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; "
                       f"available: {list(available_engines())}") from None
    return factory(*args, **kwargs)
