"""FLAME Serving API v2 — the request/response surface every engine speaks.

The serving system is addressed through four pieces (see DESIGN.md for the
full request lifecycle diagram):

  ServeRequest / ServeResponse   frozen value types crossing the API boundary
  ResponseFuture                 handle returned by ``submit``; resolves to a
                                 ServeResponse once the pipeline finishes
  ServingEngine                  the protocol all engines implement:
                                 ``submit`` (async), ``serve`` (blocking
                                 sugar), ``metrics``, ``shutdown``
  engine registry                name -> factory, so launchers/benchmarks
                                 select engines with ``--engine flame``

Engines register themselves with :func:`register_engine`; callers construct
them with :func:`create_engine` and never import concrete classes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import (Any, Callable, Dict, Optional, Protocol, Sequence,
                    runtime_checkable)

import numpy as np

_REQUEST_IDS = itertools.count()


# ---------------------------------------------------------------------------
# value types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopKConfig:
    """Generative decode: grow ``k`` sequences greedily for ``steps`` steps
    (each step keeps the top-k single-token continuations of each sequence's
    own greedy path — k independent greedy beams seeded by the top-k first
    tokens)."""

    k: int = 4
    steps: int = 8


@dataclasses.dataclass(frozen=True)
class BeamConfig:
    """Generative decode: beam search of ``width`` hypotheses for ``steps``
    steps, ranked by cumulative log-probability; ``eos`` (an item id)
    finishes a hypothesis early — finished beams keep their score and are
    never re-expanded."""

    width: int = 4
    steps: int = 8
    eos: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One upstream request.

    Recommendation engines read ``history`` (item ids) and ``candidates``
    (item ids to score); text engines read ``history`` as prompt token ids
    and generate ``n_tokens``.  ``user_id`` is an optional stable upstream
    identity: cache-aware engines key their history-KV pool by it (falling
    back to a content hash of the history when absent), so repeat-user and
    session-re-rank traffic reuses the cached history encode.

    ``deadline_s`` is an optional per-request latency budget (seconds,
    relative to ``arrival_t``).  Deadline-aware engines order their flush
    queues earliest-deadline-first against it and count overruns in the
    ``deadline_misses`` metric; ``None`` defers to the engine's default
    budget (which may be "no deadline").
    """

    history: np.ndarray
    candidates: Optional[np.ndarray] = None
    n_tokens: int = 16
    # generative decode (ISSUE 8): a TopKConfig/BeamConfig here asks the
    # engine to GENERATE candidate sequences over the item vocabulary
    # instead of scoring a provided list; ``candidates``, when also given,
    # restricts the per-step token universe to those ids.  The response
    # ``output`` is then ``[width, steps]`` generated item ids, best-first.
    generate: Optional[object] = None
    user_id: Optional[int] = None
    deadline_s: Optional[float] = None
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQUEST_IDS))
    arrival_t: float = dataclasses.field(default_factory=time.perf_counter)

    @property
    def m(self) -> int:
        """Number of candidates (0 for text requests)."""
        return 0 if self.candidates is None else int(self.candidates.shape[0])


@dataclasses.dataclass(frozen=True)
class ServeResponse:
    """Pipeline output for one request.

    ``output`` is ``[M, num_tasks]`` scores for recommendation engines, or a
    ``[n_tokens]`` generated-id array for text engines.  ``timings`` breaks
    the latency into pipeline stages (queue / features / execute).
    """

    request_id: int
    output: np.ndarray
    latency_s: float
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)


class ResponseFuture:
    """Handle for an in-flight request; resolves to a :class:`ServeResponse`."""

    def __init__(self, request: ServeRequest):
        self.request = request
        self._f: "Future[ServeResponse]" = Future()

    # ---- consumer side ----
    def done(self) -> bool:
        return self._f.done()

    def result(self, timeout: Optional[float] = None) -> ServeResponse:
        return self._f.result(timeout)

    def scores(self, timeout: Optional[float] = None) -> np.ndarray:
        """Convenience: block and return just the output array."""
        return self.result(timeout).output

    def add_done_callback(self, fn: Callable[["ResponseFuture"], None]):
        self._f.add_done_callback(lambda _: fn(self))

    # ---- engine side ----
    def set_result(self, response: ServeResponse):
        self._f.set_result(response)

    def set_exception(self, exc: BaseException):
        self._f.set_exception(exc)


class AdmissionQueueFull(RuntimeError):
    """Raised by ``submit`` when the bounded admission queue stays full past
    the caller's timeout (the backpressure signal)."""


class DeadlineExceeded(RuntimeError):
    """Raised by ``submit`` when the request's deadline budget has already
    passed at admission time (counted in the ``deadline_shed`` metric):
    executing it would burn an executor slot on a guaranteed miss, so
    deadline-aware engines shed it instead."""


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class ServeMetrics:
    """Thread-safe request/latency accounting shared by all engines.

    ``record`` is called from pipeline worker threads concurrently; every
    mutation happens under one lock (the unguarded ``requests += 1`` and
    first/last-timestamp updates used to race under ``run_workload``'s
    thread pool)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.requests = 0
        self.items = 0
        self.first_t = 0.0
        self.last_t = 0.0
        self.latencies: list = []
        self.gauges: Dict[str, float] = {}
        self.counters: Dict[str, int] = {}

    def record(self, n_items: int, latency_s: float):
        now = time.perf_counter()
        with self._lock:
            if self.requests == 0:
                self.first_t = now - latency_s
            self.last_t = now
            self.requests += 1
            self.items += n_items
            self.latencies.append(latency_s)

    def set_gauge(self, name: str, value: float):
        """Point-in-time engine gauge surfaced in ``summary()`` — e.g. the
        history-KV pool's byte accounting (``pool_bytes_used`` vs its
        configured budget), the DSO's cumulative ``padded_fraction``
        (candidate-slot padding dispatched vs reclaimed by segment
        packing) and ``queue_delay_ms`` (mean chunk enqueue-to-dispatch
        delay), updated by the engine as requests flow."""
        with self._lock:
            self.gauges[name] = float(value)

    def incr(self, name: str, by: int = 1):
        """Monotonic engine counter surfaced in ``summary()`` — e.g.
        ``deadline_misses`` (requests that resolved after their
        ``ServeRequest.deadline_s`` budget)."""
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + by

    def summary(self) -> Dict[str, float]:
        with self._lock:
            lat = np.array(self.latencies) if self.latencies else np.zeros(1)
            wall = max(self.last_t - self.first_t, 1e-9)
            return {
                "requests": self.requests,
                "throughput_items_per_s": self.items / wall,
                "mean_latency_ms": float(lat.mean() * 1e3),
                "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
                **self.gauges,
                **self.counters,
            }


# ---------------------------------------------------------------------------
# the engine protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class ServingEngine(Protocol):
    """What every serving engine exposes, regardless of model family."""

    def submit(self, request: ServeRequest, *,
               timeout: Optional[float] = None) -> ResponseFuture:
        """Admit a request into the pipeline; returns immediately with a
        future.  Blocks (up to ``timeout``) when the admission queue is
        full; raises :class:`AdmissionQueueFull` on timeout."""
        ...

    def serve(self, history: np.ndarray,
              candidates: Optional[np.ndarray] = None, **kw) -> np.ndarray:
        """Blocking sugar: submit one request and wait for its output."""
        ...

    def metrics(self) -> Dict[str, Any]:
        """Unified metrics snapshot (request stats + engine internals)."""
        ...

    def shutdown(self) -> None:
        ...


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

_ENGINES: Dict[str, Callable[..., ServingEngine]] = {}


def register_engine(name: str):
    """Class/factory decorator: ``@register_engine("flame")``."""
    def deco(factory):
        _ENGINES[name] = factory
        return factory
    return deco


def available_engines() -> Sequence[str]:
    return sorted(_ENGINES)


def create_engine(name: str, *args, **kwargs) -> ServingEngine:
    try:
        factory = _ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; "
                       f"available: {list(available_engines())}") from None
    return factory(*args, **kwargs)
