"""Mixed-traffic workload driver (paper §4.2.3 simulation).

Generates requests whose candidate counts follow the paper's non-uniform
upstream distribution (uniform over {128,256,512,1024} in Table 5, plus
zipf-skewed and heavy-tailed lognormal variants) and drives them through an
engine, concurrently, collecting the throughput / latency / P99 metrics of
Table 5.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.serving.api import ServeRequest, ServingEngine


@dataclasses.dataclass
class TrafficConfig:
    candidate_counts: Sequence[int] = (128, 256, 512, 1024)
    # uniform | zipf | jittered | lognormal — ``zipf`` skews over the fixed
    # counts (most requests draw the smallest); ``lognormal`` is the
    # heavy-tailed continuous variant (median at the middle count, clipped
    # to [1, max]): almost every M is tiny and non-bucket-aligned, the
    # regime where tail-chunk padding dominates dispatch cost
    distribution: str = "uniform"
    n_requests: int = 64
    n_history: int = 1024
    concurrency: int = 4
    seed: int = 0
    # repeat-user / session-re-rank profile: > 0 draws each request's user
    # from a fixed population whose histories are stable across requests, so
    # the same user re-ranks fresh candidate slates against one history —
    # the regime where a history-KV pool converts full passes into
    # candidate-only passes.  0 keeps the legacy one-user-per-request shape.
    n_users: int = 0


def generate_traffic(tc: TrafficConfig, n_items: int = 100_000
                     ) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(tc.seed)
    user_hist = {}
    reqs = []
    for _ in range(tc.n_requests):
        if tc.distribution == "uniform":
            m = int(rng.choice(tc.candidate_counts))
        elif tc.distribution == "zipf":
            idx = min(len(tc.candidate_counts) - 1, rng.zipf(2.0) - 1)
            m = int(sorted(tc.candidate_counts)[idx])
        elif tc.distribution == "lognormal":
            counts = sorted(tc.candidate_counts)
            med = counts[len(counts) // 2]
            m = int(np.clip(rng.lognormal(np.log(med), 1.0), 1, counts[-1]))
        else:  # jittered: non-bucket-aligned counts (the hard case)
            base = int(rng.choice(tc.candidate_counts))
            m = max(1, base - int(rng.integers(0, base // 3)))
        req = {"candidates": rng.integers(0, n_items, m).astype(np.int32)}
        if tc.n_users > 0:
            uid = int(rng.integers(tc.n_users))
            if uid not in user_hist:
                user_hist[uid] = rng.integers(
                    0, n_items, tc.n_history).astype(np.int32)
            req["history"] = user_hist[uid]
            req["user_id"] = uid
        else:
            req["history"] = rng.integers(
                0, n_items, tc.n_history).astype(np.int32)
        reqs.append(req)
    return reqs


def run_workload(serve_fn: Callable, requests: List[Dict], concurrency: int = 4
                 ) -> Dict[str, float]:
    """serve_fn(history, candidates) -> scores.  Returns workload metrics."""
    lat: List[float] = []
    items = 0
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=concurrency) as tp:
        def one(r):
            t = time.perf_counter()
            serve_fn(r["history"], r["candidates"])
            return time.perf_counter() - t, len(r["candidates"])

        for dt, m in tp.map(one, requests):
            lat.append(dt)
            items += m
    total = time.perf_counter() - t0
    la = np.array(lat)
    return {
        "requests": len(requests),
        "total_s": total,
        "throughput_items_per_s": items / total,
        "mean_latency_ms": float(la.mean() * 1e3),
        "p50_latency_ms": float(np.percentile(la, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(la, 99) * 1e3),
    }


def run_workload_async(engine: "ServingEngine", requests: List[Dict], *,
                       arrival_gap_s: float = 0.0, seed: int = 0
                       ) -> Dict[str, object]:
    """Drive an API v2 engine through ``submit`` — all requests in flight
    together, which is the condition under which the coalescing DSO can
    merge same-bucket chunks from different requests into one dispatch.

    ``arrival_gap_s`` > 0 sleeps a uniform random gap in [0, arrival_gap_s)
    between submits (open-loop jittered arrivals).  Returns the run_workload
    metric keys plus ``outputs`` (per-request score arrays, request order)
    so callers can compare result correctness across engine configs."""
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    futs = []
    for r in requests:
        if arrival_gap_s > 0:
            time.sleep(float(rng.uniform(0, arrival_gap_s)))
        futs.append(engine.submit(ServeRequest(
            history=r["history"], candidates=r.get("candidates"),
            user_id=r.get("user_id"), deadline_s=r.get("deadline_s"),
            generate=r.get("generate"))))
    resps = [f.result() for f in futs]
    total = time.perf_counter() - t0
    la = np.array([r.latency_s for r in resps])
    # generative requests count generated tokens; scoring requests count
    # scored candidates
    items = sum(int((r.output >= 0).sum())
                if requests[i].get("generate") is not None
                else len(requests[i]["candidates"])
                for i, r in enumerate(resps))
    return {
        "requests": len(requests),
        "total_s": total,
        "throughput_items_per_s": items / total,
        "mean_latency_ms": float(la.mean() * 1e3),
        "p50_latency_ms": float(np.percentile(la, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(la, 99) * 1e3),
        "outputs": [r.output for r in resps],
    }
