"""Mixed-traffic workload driver (paper §4.2.3 simulation).

Generates requests whose candidate counts follow the paper's non-uniform
upstream distribution (uniform over {128,256,512,1024} in Table 5, plus
zipf-skewed and heavy-tailed lognormal variants) and drives them through an
engine, concurrently, collecting the throughput / latency / P99 metrics of
Table 5.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.api import (SLO_TIERS, RejectedError, ServeRequest,
                               ServingEngine)


@dataclasses.dataclass
class TrafficConfig:
    candidate_counts: Sequence[int] = (128, 256, 512, 1024)
    # uniform | zipf | jittered | lognormal — ``zipf`` skews over the fixed
    # counts (most requests draw the smallest); ``lognormal`` is the
    # heavy-tailed continuous variant (median at the middle count, clipped
    # to [1, max]): almost every M is tiny and non-bucket-aligned, the
    # regime where tail-chunk padding dominates dispatch cost
    distribution: str = "uniform"
    n_requests: int = 64
    n_history: int = 1024
    concurrency: int = 4
    seed: int = 0
    # repeat-user / session-re-rank profile: > 0 draws each request's user
    # from a fixed population whose histories are stable across requests, so
    # the same user re-ranks fresh candidate slates against one history —
    # the regime where a history-KV pool converts full passes into
    # candidate-only passes.  0 keeps the legacy one-user-per-request shape.
    n_users: int = 0
    # SLO tier mix: weights over {interactive, standard, bulk} — each
    # request draws its ``slo_tier`` from this distribution (the overload
    # bench's tiered traffic).  None keeps every request tier-less
    # ("standard"), the pre-overload-discipline shape.
    tier_mix: Optional[Dict[str, float]] = None


def generate_traffic(tc: TrafficConfig, n_items: int = 100_000
                     ) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(tc.seed)
    user_hist = {}
    tiers, tier_p = None, None
    if tc.tier_mix:
        bad = set(tc.tier_mix) - set(SLO_TIERS)
        if bad:
            raise ValueError(f"unknown SLO tiers in tier_mix: {bad}")
        tiers = sorted(tc.tier_mix)
        w = np.array([tc.tier_mix[t] for t in tiers], float)
        tier_p = w / w.sum()
    reqs = []
    for _ in range(tc.n_requests):
        if tc.distribution == "uniform":
            m = int(rng.choice(tc.candidate_counts))
        elif tc.distribution == "zipf":
            idx = min(len(tc.candidate_counts) - 1, rng.zipf(2.0) - 1)
            m = int(sorted(tc.candidate_counts)[idx])
        elif tc.distribution == "lognormal":
            counts = sorted(tc.candidate_counts)
            med = counts[len(counts) // 2]
            m = int(np.clip(rng.lognormal(np.log(med), 1.0), 1, counts[-1]))
        else:  # jittered: non-bucket-aligned counts (the hard case)
            base = int(rng.choice(tc.candidate_counts))
            m = max(1, base - int(rng.integers(0, base // 3)))
        req = {"candidates": rng.integers(0, n_items, m).astype(np.int32)}
        if tiers is not None:
            req["slo_tier"] = tiers[int(rng.choice(len(tiers), p=tier_p))]
        if tc.n_users > 0:
            uid = int(rng.integers(tc.n_users))
            if uid not in user_hist:
                user_hist[uid] = rng.integers(
                    0, n_items, tc.n_history).astype(np.int32)
            req["history"] = user_hist[uid]
            req["user_id"] = uid
        else:
            req["history"] = rng.integers(
                0, n_items, tc.n_history).astype(np.int32)
        reqs.append(req)
    return reqs


def run_workload(serve_fn: Callable, requests: List[Dict], concurrency: int = 4
                 ) -> Dict[str, float]:
    """serve_fn(history, candidates) -> scores.  Returns workload metrics."""
    lat: List[float] = []
    items = 0
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=concurrency) as tp:
        def one(r):
            t = time.perf_counter()
            serve_fn(r["history"], r["candidates"])
            return time.perf_counter() - t, len(r["candidates"])

        for dt, m in tp.map(one, requests):
            lat.append(dt)
            items += m
    total = time.perf_counter() - t0
    la = np.array(lat)
    return {
        "requests": len(requests),
        "total_s": total,
        "throughput_items_per_s": items / total,
        "mean_latency_ms": float(la.mean() * 1e3),
        "p50_latency_ms": float(np.percentile(la, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(la, 99) * 1e3),
    }


def run_workload_async(engine: "ServingEngine", requests: List[Dict], *,
                       arrival_gap_s: float = 0.0, seed: int = 0,
                       tolerate_errors: bool = False,
                       result_timeout_s: float = 120.0
                       ) -> Dict[str, object]:
    """Drive an API v2 engine through ``submit`` — all requests in flight
    together, which is the condition under which the coalescing DSO can
    merge same-bucket chunks from different requests into one dispatch.

    ``arrival_gap_s`` > 0 sleeps a uniform random gap in [0, arrival_gap_s)
    between submits (open-loop jittered arrivals).  Returns the run_workload
    metric keys plus ``outputs`` (per-request score arrays, request order)
    so callers can compare result correctness across engine configs.

    ``tolerate_errors=True`` is the overload/chaos mode: admission-side
    :class:`RejectedError`\\ s and failed futures are COUNTED instead of
    raised (``rejected`` / ``failed`` in the result; latency metrics cover
    the ``resolved`` survivors), and any future still unresolved after
    ``result_timeout_s`` counts as ``hung`` — the liveness number the
    chaos gate asserts is zero.  Rejections carrying a ``retry_after_s``
    backoff hint aggregate into ``retry_after_hinted`` /
    ``retry_after_mean_ms``.  The default (False) keeps the strict v1
    contract: any rejection or failure raises."""
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    futs = []
    rejected = 0
    retry_hints = []       # retry_after_s backoff hints on rejections
    for r in requests:
        if arrival_gap_s > 0:
            time.sleep(float(rng.uniform(0, arrival_gap_s)))
        try:
            futs.append(engine.submit(ServeRequest(
                history=r["history"], candidates=r.get("candidates"),
                user_id=r.get("user_id"), deadline_s=r.get("deadline_s"),
                generate=r.get("generate"),
                slo_tier=r.get("slo_tier", "standard"))))
        except RejectedError as e:
            if not tolerate_errors:
                raise
            rejected += 1
            if getattr(e, "retry_after_s", None) is not None:
                retry_hints.append(float(e.retry_after_s))
            futs.append(None)
    resps, out_reqs, failed, hung = [], [], 0, 0
    for i, f in enumerate(futs):
        if f is None:
            continue
        try:
            resps.append(f.result(result_timeout_s if tolerate_errors
                                  else None))
            out_reqs.append(requests[i])
        except FuturesTimeout:
            if not tolerate_errors:
                raise
            hung += 1
        except RejectedError as e:
            # a queued victim displaced under overload: the ShedError is
            # delivered through its future and prices the same backoff
            if not tolerate_errors:
                raise
            failed += 1
            if getattr(e, "retry_after_s", None) is not None:
                retry_hints.append(float(e.retry_after_s))
        except BaseException:
            if not tolerate_errors:
                raise
            failed += 1
    total = time.perf_counter() - t0
    la = np.array([r.latency_s for r in resps]) if resps else np.zeros(1)
    # generative requests count generated tokens; scoring requests count
    # scored candidates
    items = sum(int((r.output >= 0).sum())
                if out_reqs[i].get("generate") is not None
                else len(out_reqs[i]["candidates"])
                for i, r in enumerate(resps))
    return {
        "requests": len(requests),
        "resolved": len(resps),
        "rejected": rejected,
        "failed": failed,
        "hung": hung,
        "retry_after_hinted": len(retry_hints),
        "retry_after_mean_ms": float(np.mean(retry_hints) * 1e3)
        if retry_hints else 0.0,
        "total_s": total,
        "throughput_items_per_s": items / total,
        "mean_latency_ms": float(la.mean() * 1e3),
        "p50_latency_ms": float(np.percentile(la, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(la, 99) * 1e3),
        "outputs": [r.output for r in resps],
    }
