"""Serving engines behind the API v2 surface (repro.serving.api).

Every engine shares the same staged pipeline scaffolding:

  submit() --> bounded admission queue (backpressure)
           --> PDA feature prefetch (fire-and-forget cache warm)
           --> worker threads: feature query -> execute -> ResponseFuture

and differs only in the execute stage:

  FlameEngine                the paper's system end to end — PDA feature
                             query, coalescing DSO over batch-axis AOT
                             executors (chunks from *different* in-flight
                             requests share one dispatch), SUMI-masked
                             Climber forward, per-candidate task scores;
  ImplicitShapeServingEngine Table 5 "Default" — plain jit over the full
                             model, retrace+recompile per novel M, wrapped
                             in the same pipeline for A/B comparison;
  TextServingEngine          prefill+decode serving for the decode-based
                             assigned architectures.

Engines self-register ("flame" / "implicit" / "text"); construct them via
``repro.serving.api.create_engine``.  See DESIGN.md for the request
lifecycle diagram and docs/ARCHITECTURE.md for the end-to-end narrative.

Executor-family contract (FlameEngine <-> CoalescingOrchestrator)
-----------------------------------------------------------------
Executors are AOT-compiled per ``(kind, bucket)``:

  ("full",   M-bucket)   monolithic SUMI pass (pool off)
  ("cached", M-bucket)   candidate-only scoring against pooled history K/V;
                         with ``kv_dedup`` the signature carries unique KV
                         rows + a [B] gather index; under ``impl="fused"``
                         the rows are the pool's RAW (quantized) leaves and
                         both dequant and gather happen in-kernel
                         (kernels/fused_score)
  ("encode", n_history)  history encode repopulating the pool on a miss
  ("extend", prefix_len) PDA v2 incremental path: re-encode only the window
                         suffix + side token against a stale entry's cached
                         prefix K/V (bucket = trusted prefix length)

``_pad_slice(request, chunk, kind)`` produces one chunk's host/device args
(leading axis 1); ``_gather(rows, chunks, m, kind)`` reassembles per-request
outputs.  Pool fingerprint/staleness semantics live in
``serving/kv_cache.py``; the history window is fingerprinted over the FULL
upstream array (side features average all of it), and stale entries become
extension bases instead of pure losses when ``incremental_history`` is on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import math
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.core import dso as DSO
from repro.core import pda as PDA
from repro.core.climber import N_SIDE_FEATURES
from repro.models.model import ModelBundle
from repro.serving.api import (SLO_TIERS, TIER_RANK, AdmissionQueueFull,
                               DeadlineExceeded, DegradedError,
                               ResponseFuture, ServeMetrics, ServeRequest,
                               ServeResponse, ShedError, WatchdogTimeout,
                               register_engine)
from repro.kernels.fused_score.ops import (packed_reroute_count,
                                           set_packed_alignment)
from repro.serving.kv_cache import (HistoryKVPool, KVCacheManager,
                                    quantize_kv_graph, raw_kv_specs)

_STOP = object()

#: per-tier flush-window multipliers handed to ``CoalescePolicy``: an
#: interactive chunk flushes almost immediately, bulk may wait past the
#: default window for better packing.  Tier-less chunks (and "standard")
#: keep scale 1.0, so tier-agnostic callers see the v1 window exactly.
_TIER_WINDOW_SCALE = {"interactive": 0.25, "standard": 1.0, "bulk": 2.0}

#: service-time EWMA smoothing for admission-time wait prediction
_SERVICE_EWMA = 0.3


def _try_fail(fut: ResponseFuture, exc: BaseException) -> bool:
    """Best-effort set_exception: the future may have been resolved by a
    worker in the same race window.  Returns True when the exception was
    actually delivered (callers count sheds/timeouts only on delivery)."""
    try:
        fut.set_exception(exc)
        return True
    except Exception:  # InvalidStateError — already resolved, fine
        return False


class _AdmissionRecord:
    """One queued submission: the priority key, the request's future, its
    submit timestamp, and the SLO/deadline facts shedding decisions read."""

    __slots__ = ("key", "fut", "t_submit", "tier", "deadline_abs", "shed")

    def __init__(self, key: tuple, fut: ResponseFuture, t_submit: float,
                 tier: str, deadline_abs: Optional[float]):
        self.key = key
        self.fut = fut
        self.t_submit = t_submit
        self.tier = tier
        self.deadline_abs = deadline_abs
        self.shed = False              # lazy-deletion marker (see shed_victim)


class _AdmissionQueue:
    """Bounded deadline-ordered (EDF) admission queue with tiered shedding.

    Replaces the FIFO ``queue.Queue`` of PR 1: records pop in priority-key
    order — ``(absolute deadline | inf, tier rank, seq)`` under ``edf``
    (deadline-less work sorts last, ties break best-tier-first then FIFO),
    or pure arrival order under ``fifo`` (the A/B baseline the overload
    bench gates against).

    One mutex guards the heap, with two condition variables over it
    (``not_empty`` for workers, ``not_full`` for blocked submitters) so a
    completed get wakes exactly a submitter and a put wakes exactly a
    worker.  Shedding removes a queued victim *lazily*: ``shed_victim``
    marks the worst strictly-lower-priority record and frees its capacity
    slot; ``get`` skips marked records when they surface at the heap root.

    ``close()`` is the stop signal: getters return ``None`` immediately
    (they do NOT drain — shutdown must not wait out a deep queue) and
    blocked putters raise; ``drain()`` then hands shutdown the leftovers
    to fail."""

    def __init__(self, maxsize: int, mode: str = "edf"):
        if mode not in ("edf", "fifo"):
            raise ValueError(f"admission mode must be edf|fifo, got {mode!r}")
        self.maxsize = maxsize
        self.mode = mode
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._heap: List[Tuple[tuple, _AdmissionRecord]] = []
        self._seq = itertools.count()
        self._live = 0                 # unshed records (capacity accounting)
        self._closed = False

    def key_for(self, deadline_abs: Optional[float], tier: str) -> tuple:
        """Priority key for one submission (smaller = served sooner)."""
        if self.mode == "fifo":
            return (next(self._seq),)
        return (deadline_abs if deadline_abs is not None else math.inf,
                TIER_RANK.get(tier, 1), next(self._seq))

    def put(self, rec: _AdmissionRecord, timeout: Optional[float] = None):
        """Enqueue; blocks while at capacity (``timeout=0`` = non-blocking).
        Raises ``queue.Full`` past the timeout and ``RuntimeError`` when
        closed."""
        with self._not_full:
            if timeout == 0:
                if self._live >= self.maxsize and not self._closed:
                    raise queue.Full
            else:
                end = None if timeout is None \
                    else time.perf_counter() + timeout
                while self._live >= self.maxsize and not self._closed:
                    left = None if end is None else end - time.perf_counter()
                    if left is not None and left <= 0:
                        raise queue.Full
                    self._not_full.wait(timeout=left)
            if self._closed:
                raise RuntimeError("admission queue closed")
            heapq.heappush(self._heap, (rec.key, rec))
            self._live += 1
            self._not_empty.notify()

    def get(self) -> Optional[_AdmissionRecord]:
        """Pop the best live record (blocking); ``None`` once closed — the
        worker stop signal (leftovers are failed by ``drain``, not served)."""
        with self._not_empty:
            while True:
                while self._heap and self._heap[0][1].shed:
                    heapq.heappop(self._heap)      # lazy-deleted victims
                if self._closed:
                    return None
                if self._heap:
                    _, rec = heapq.heappop(self._heap)
                    self._live -= 1
                    self._not_full.notify()
                    return rec
                self._not_empty.wait()

    def shed_victim(self, key: tuple
                    ) -> Optional[_AdmissionRecord]:
        """Remove and return the WORST queued record strictly lower-priority
        than ``key`` (latest deadline, lowest tier), or ``None`` when
        everything queued outranks the caller.  O(n) scan — the queue is
        admission-bounded, and shedding only runs under overload."""
        with self._lock:
            worst: Optional[_AdmissionRecord] = None
            for _, rec in self._heap:
                if not rec.shed and rec.key > key \
                        and (worst is None or rec.key > worst.key):
                    worst = rec
            if worst is None:
                return None
            worst.shed = True
            self._live -= 1
            self._not_full.notify()
            return worst

    def qsize(self) -> int:
        with self._lock:
            return self._live

    def close(self):
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    def drain(self) -> List[_AdmissionRecord]:
        """Pop every remaining live record (shutdown fails them)."""
        with self._lock:
            out = [rec for _, rec in self._heap if not rec.shed]
            self._heap.clear()
            self._live = 0
            return out


class _PipelinedEngine:
    """API v2 pipeline scaffolding shared by all engines.

    ``submit`` admits into a bounded deadline-ordered queue (blocking when
    full is the backpressure signal; a timeout raises
    :class:`AdmissionQueueFull`); ``n_workers`` threads drain it in EDF
    order and run the engine-specific ``_execute``.  Subclasses must finish
    their own setup *before* calling ``__init__`` here — workers start
    immediately.

    Overload discipline (all off by default — v1 semantics preserved):

    * ``admission="fifo"`` reverts to arrival-order service (A/B baseline).
    * ``slo_tier_defaults`` maps tier → default deadline seconds, used when
      a request carries no explicit ``deadline_s`` (falls back to the
      engine-wide default for unlisted tiers).
    * ``shed_policy="tiered"`` enables admission-time load shedding: when
      the queue is at depth or the EWMA-predicted wait blows the incoming
      request's budget, the worst strictly-lower-priority queued victim is
      failed with :class:`ShedError` (or the incoming request itself when
      nothing queued ranks below it).
    * ``watchdog_grace_s > 0`` starts a watchdog thread that fails any
      future still unresolved ``grace`` past its deadline with
      :class:`WatchdogTimeout` — under fault injection no request ever
      hangs.
    * ``degradation`` (a :class:`DegradationPolicy`) observes queue delay
      from the workers; level transitions invoke the ``_on_degrade`` hook.
    * ``faults`` (a :class:`FaultInjector`) arms the worker-stall hook here
      (subclasses wire its dispatch/pool arms)."""

    def __init__(self, *, max_pending: int = 64, n_workers: int = 4,
                 name: str = "engine", admission: str = "edf",
                 shed_policy: str = "none",
                 slo_tier_defaults: Optional[Dict[str, float]] = None,
                 watchdog_grace_s: float = 0.0,
                 degradation=None, faults=None):
        # engine-default deadline budget (seconds; 0 = none): subclasses
        # that support deadlines set it BEFORE calling __init__ here
        self._deadline_s = getattr(self, "_deadline_s", 0.0)
        if shed_policy not in ("none", "tiered"):
            raise ValueError(
                f"shed_policy must be none|tiered, got {shed_policy!r}")
        if slo_tier_defaults is not None:
            bad = set(slo_tier_defaults) - set(SLO_TIERS)
            if bad:
                raise ValueError(f"unknown SLO tiers in defaults: {bad}")
        self._metrics = ServeMetrics()
        self._admission = _AdmissionQueue(max_pending, mode=admission)
        self._shed = shed_policy == "tiered"
        self._tier_defaults = dict(slo_tier_defaults) \
            if slo_tier_defaults else None
        self._degradation = degradation
        self._degrade_applied = 0
        self._faults = faults
        self._ewma_lock = threading.Lock()
        self._service_ewma_s: Optional[float] = None
        self._n_workers = max(int(n_workers), 1)
        self._open = True
        self._workers: List[threading.Thread] = []
        for i in range(n_workers):
            th = threading.Thread(target=self._worker_loop,
                                  name=f"{name}-worker-{i}", daemon=True)
            th.start()
            self._workers.append(th)
        self._watchdog_grace_s = float(watchdog_grace_s)
        self._watchdog_stop = threading.Event()
        self._watchdog_lock = threading.Lock()
        self._watchdog_futs: Dict[int, Tuple[ResponseFuture, float]] = {}
        self._watchdog_th: Optional[threading.Thread] = None
        if self._watchdog_grace_s > 0:
            th = threading.Thread(target=self._watchdog_loop,
                                  name=f"{name}-watchdog", daemon=True)
            th.start()
            self._watchdog_th = th

    # ---- engine-specific hooks ----
    def _execute(self, request: ServeRequest
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Run one request; returns (output, stage timings)."""
        raise NotImplementedError

    def _admit_hook(self, request: ServeRequest):
        """Called on the caller's thread at submit time (e.g. PDA prefetch)."""

    def _extra_metrics(self) -> Dict[str, float]:
        return {}

    def _close(self):
        """Engine-specific teardown after the workers have drained."""

    # ---- ServingEngine protocol ----
    def _effective_deadline(self, req: ServeRequest) -> float:
        """Deadline budget (seconds, 0 = none): explicit ``deadline_s``
        wins, then the engine's per-tier default, then the global one."""
        if req.deadline_s is not None:
            return req.deadline_s
        tier = getattr(req, "slo_tier", "standard")
        if self._tier_defaults is not None and tier in self._tier_defaults:
            return self._tier_defaults[tier]
        return self._deadline_s

    def _predicted_wait_s(self, depth: int) -> float:
        """EWMA service-time estimate of queue wait at the given depth."""
        with self._ewma_lock:
            s = self._service_ewma_s
        return 0.0 if s is None else depth * s / self._n_workers

    def _shed_for(self, rec: _AdmissionRecord):
        """Tiered admission-time shedding: under overload (queue at depth,
        or predicted wait past the incoming budget) drop the lowest-value
        work in sight — a strictly worse queued victim if one exists, else
        the incoming request itself (raises :class:`ShedError`)."""
        depth = self._admission.qsize()
        overloaded = depth >= self._admission.maxsize
        if not overloaded and rec.deadline_abs is not None:
            wait = self._predicted_wait_s(depth)
            overloaded = time.perf_counter() + wait > rec.deadline_abs
        if not overloaded:
            return
        # admission-control feedback: a shed caller should back off for
        # about one queue-drain interval instead of hammering — the same
        # queue-delay EWMA that detected the overload prices the hint
        retry_after_s = self._predicted_wait_s(depth)
        victim = self._admission.shed_victim(rec.key)
        if victim is not None:
            err = ShedError(
                f"request {victim.fut.request.request_id} "
                f"({victim.tier}) shed: displaced by a higher-priority "
                f"arrival under overload")
            err.retry_after_s = retry_after_s
            if _try_fail(victim.fut, err):
                self._metrics.incr(f"shed_{victim.tier}")
                self._metrics.incr("shed_total")
            return
        # nothing queued ranks below the incoming request: it IS the
        # lowest-value work — shed it before it burns a queue slot
        self._metrics.incr(f"shed_{rec.tier}")
        self._metrics.incr("shed_total")
        err = ShedError(
            f"request {rec.fut.request.request_id} ({rec.tier}) shed at "
            f"admission: queue overloaded and no lower-priority victim")
        err.retry_after_s = retry_after_s
        raise err

    def submit(self, request: ServeRequest, *,
               timeout: Optional[float] = None) -> ResponseFuture:
        if not self._open:
            raise RuntimeError("engine is shut down")
        tier = getattr(request, "slo_tier", "standard")
        if tier not in TIER_RANK:
            raise ValueError(
                f"request {request.request_id}: unknown slo_tier {tier!r}; "
                f"expected one of {SLO_TIERS}")
        dl = self._effective_deadline(request)
        if dl and time.perf_counter() > request.arrival_t + dl:
            # admission-time shedding: the latency budget is already blown,
            # so executing would burn an executor slot on a guaranteed miss
            # and delay co-pending requests that can still make theirs —
            # reject here, before the prefetch hook or a queue slot
            self._metrics.incr("deadline_shed")
            raise DeadlineExceeded(
                f"request {request.request_id}: deadline budget "
                f"{dl * 1e3:.3g} ms already exhausted at admission")
        deadline_abs = (request.arrival_t + dl) if dl else None
        fut = ResponseFuture(request)
        self._admit_hook(request)
        t_submit = time.perf_counter()
        rec = _AdmissionRecord(self._admission.key_for(deadline_abs, tier),
                               fut, t_submit, tier, deadline_abs)
        if self._shed:
            self._shed_for(rec)        # may raise ShedError for `rec` itself
        try:
            self._admission.put(rec, timeout=timeout)
        except queue.Full:
            err = AdmissionQueueFull(
                f"admission queue full ({self._admission.maxsize} pending)")
            err.retry_after_s = self._predicted_wait_s(
                self._admission.qsize())
            raise err from None
        except RuntimeError:
            # queue closed mid-put: shutdown raced us
            _try_fail(fut, RuntimeError("engine shut down during submit"))
            return fut
        self._watchdog_register(fut, deadline_abs)
        if not self._open:
            # lost the race with shutdown(): the workers may already have
            # observed the close signal, so nobody will resolve this
            # future — fail it rather than hang the caller
            _try_fail(fut, RuntimeError("engine shut down during submit"))
        return fut

    def serve(self, history: np.ndarray,
              candidates: Optional[np.ndarray] = None, **kw) -> np.ndarray:
        """Blocking sugar around submit()."""
        req = ServeRequest(
            history=np.asarray(history),
            candidates=None if candidates is None else np.asarray(candidates),
            **kw)
        return self.submit(req).result().output

    def metrics(self) -> Dict[str, float]:
        # engine internals first: _extra_metrics may refresh ServeMetrics
        # gauges (padded_fraction / queue_delay_ms) that summary() reports
        extra = self._extra_metrics()
        out = self._metrics.summary()
        out["pending"] = self._admission.qsize()
        out.update(extra)
        return out

    def shutdown(self):
        if not self._open:
            return
        self._open = False
        self._admission.close()        # workers see None and exit
        for th in self._workers:
            th.join(timeout=10.0)
        # fail any request that raced past the close signal
        for rec in self._admission.drain():
            _try_fail(rec.fut, RuntimeError("engine shut down"))
        self._watchdog_stop.set()
        if self._watchdog_th is not None:
            self._watchdog_th.join(timeout=5.0)
        self._close()

    # ---- watchdog (liveness backstop under fault injection) ----
    def _watchdog_register(self, fut: ResponseFuture,
                           deadline_abs: Optional[float]):
        if self._watchdog_th is None or deadline_abs is None:
            return
        fail_at = deadline_abs + self._watchdog_grace_s
        with self._watchdog_lock:
            self._watchdog_futs[id(fut)] = (fut, fail_at)
        fut.add_done_callback(self._watchdog_forget)

    def _watchdog_forget(self, fut):
        with self._watchdog_lock:
            self._watchdog_futs.pop(id(fut), None)

    def _watchdog_loop(self):
        interval = min(max(self._watchdog_grace_s / 2, 0.01), 0.25)
        grace_ms = self._watchdog_grace_s * 1e3
        while not self._watchdog_stop.wait(interval):
            now = time.perf_counter()
            with self._watchdog_lock:
                due = [fut for fut, t in self._watchdog_futs.values()
                       if now > t]
            for fut in due:
                # a worker may resolve it in this window — count only wins
                if _try_fail(fut, WatchdogTimeout(
                        f"request {fut.request.request_id} unresolved "
                        f"{grace_ms:.3g} ms past its deadline")):
                    self._metrics.incr("watchdog_timeouts")

    # ---- graceful degradation plumbing ----
    def _observe_pressure(self, queue_delay_s: float):
        level = self._degradation.observe(queue_delay_s)
        if level != self._degrade_applied:
            # benign race: concurrent workers converge on the same level
            self._degrade_applied = level
            self._metrics.set_gauge("degrade_level", float(level))
            self._metrics.incr("degrade_steps")
            self._on_degrade(level)

    def _on_degrade(self, level: int):
        """Engine-specific degradation effects (subclass hook); called on a
        worker thread whenever the applied level changes."""

    # ---- worker side ----
    def _worker_loop(self):
        while True:
            rec = self._admission.get()
            if rec is None:            # queue closed: stop signal
                return
            fut, t_submit = rec.fut, rec.t_submit
            t_deq = time.perf_counter()
            req = fut.request
            try:
                if self._faults is not None:
                    self._faults.worker_stall()
                output, timings = self._execute(req)
                t_done = time.perf_counter()
                latency = t_done - t_submit
                timings = {"queue_s": t_deq - t_submit, **timings}
                n_items = req.m if req.candidates is not None \
                    and getattr(req, "generate", None) is None \
                    else len(output)
                self._metrics.record(n_items, latency)
                dl = self._effective_deadline(req)
                if dl:
                    if t_done > req.arrival_t + dl:
                        self._metrics.incr("deadline_misses")
                        self._metrics.incr(f"deadline_misses_{rec.tier}")
                    else:
                        self._metrics.incr("deadline_met")
                        self._metrics.incr(f"goodput_{rec.tier}")
                fut.set_result(ServeResponse(req.request_id, output,
                                             latency, timings))
            except BaseException as e:  # noqa: BLE001 — surface via future
                _try_fail(fut, e)
            finally:
                dt = time.perf_counter() - t_deq
                with self._ewma_lock:
                    s = self._service_ewma_s
                    self._service_ewma_s = dt if s is None \
                        else _SERVICE_EWMA * dt + (1 - _SERVICE_EWMA) * s
                if self._degradation is not None:
                    self._observe_pressure(t_deq - t_submit)


def _make_features(feature_mode: str, store, cache_capacity: int,
                   cache_ttl_s: float):
    store = store or PDA.RemoteFeatureStore(feature_dim=N_SIDE_FEATURES)
    cache = None if feature_mode == "off" else PDA.BucketedLRUCache(
        cache_capacity, cache_ttl_s)
    return store, PDA.FeatureQueryEngine(store, cache, mode=feature_mode)


class _SideFeatureMixin:
    """PDA in action: fetch item features for the history, aggregate into
    the request's side-feature vector (user-profile style)."""

    def _check_request(self, req: ServeRequest):
        """Reject malformed requests before their chunks reach the shared
        coalescing queue — a bad shape there would fail every co-rider
        batched into the same dispatch, not just this request."""
        generative = getattr(req, "generate", None) is not None
        if not generative and (req.candidates is None
                               or req.candidates.ndim != 1 or req.m < 1):
            raise ValueError(
                f"request {req.request_id}: candidates must be a non-empty "
                f"1-D id array, got "
                f"{None if req.candidates is None else req.candidates.shape}")
        if generative and req.candidates is not None \
                and (req.candidates.ndim != 1 or req.m < 1):
            raise ValueError(
                f"request {req.request_id}: a generative request's "
                f"candidates (its token universe) must be a non-empty 1-D "
                f"id array, got {req.candidates.shape}")
        if req.candidates is not None and req.m and int(np.min(
                req.candidates)) < 0:  # flamecheck: host-sync-ok(admission validation over the caller's host id array)
            raise ValueError(
                f"request {req.request_id}: candidate ids must be >= 0 "
                f"(negative ids are reserved for chunk-padding sentinels)")
        if req.history.ndim != 1 or \
                req.history.shape[0] < self.n_history:  # flamecheck: recompile-ok(admission validation that raises; selects no executor)
            raise ValueError(
                f"request {req.request_id}: history must be a 1-D id array "
                f"with >= n_history={self.n_history} entries, got "
                f"{req.history.shape}")

    def _side_features(self, history: np.ndarray) -> np.ndarray:
        feats = self.features.query([int(i) for i in history])
        got = [v for v in feats.values() if v is not None]
        if not got:
            return np.zeros((1, N_SIDE_FEATURES), np.float32)
        return np.mean(got, axis=0, keepdims=True).astype(np.float32)

    def _admit_hook(self, request: ServeRequest):
        self.features.prefetch([int(i) for i in request.history])


class _Beam:
    """Host-side state of one in-flight hypothesis (ISSUE 8).

    ``leaves`` holds the beam's padded KV cache locally ONLY while the
    pool has rejected (or not yet accepted) it — the steady state is
    ``leaves is None`` with the cache living in the :class:`HistoryKVPool`
    under ``pool_key``/``pool_fp``, where it is subject to the same LRU /
    byte-budget discipline as every history entry.  An evicted beam is
    recovered by replaying its appends from a re-encoded base (counted in
    ``gen_replays``)."""

    __slots__ = ("tokens", "cum", "finished", "leaves", "pool_key",
                 "pool_fp")

    def __init__(self, tokens, cum, finished=False, leaves=None,
                 pool_key=None, pool_fp=None):
        self.tokens = tokens            # tuple of generated item ids
        self.cum = cum                  # cumulative log-probability
        self.finished = finished
        self.leaves = leaves
        self.pool_key = pool_key
        self.pool_fp = pool_fp


@register_engine("flame")
class FlameEngine(_SideFeatureMixin, _PipelinedEngine):
    """PDA -> coalescing DSO -> Climber, per the paper's Fig 1/Fig 4.

    Executors are AOT-compiled with a real batch axis ``(max_batch,
    bucket)``; the DSO dispatcher merges same-bucket chunks from different
    in-flight requests into one executor call (time-window + fill-target
    policy) and scatters rows back to per-request futures.  Batch rows are
    independent, so coalesced scores are bitwise-identical to sequential
    per-request serving (tests assert this).

    With ``history_cache=True`` the engine splits the SUMI forward
    (MTServe-style hierarchical caching): the per-request history encode is
    keyed into a :class:`HistoryKVPool` (by ``request.user_id``, else a
    content hash of the history prefix) and scoring always runs the cheap
    candidate-only executor family against the pooled K/V.  A pool hit
    skips the history encode entirely; a miss routes one batched
    ``encode`` dispatch first and parks the result for the next request
    from that user.  Scores are numerically identical to the full pass
    (bitwise under the reference/chunked impls).

    PDA v2 pool knobs (all riding on ``history_cache=True``):

    ``pool_budget_bytes`` / ``pool_slots``
        byte and/or entry bound on the pool (LRU-evicted; bytes are the
        real HBM constraint — entries scale with ``n_history``).
    ``pool_dtype``
        stored precision: ``native`` | ``bf16`` | ``int8`` (per-head
        scales; ~4x users-per-budget vs f32 at a bounded score drift).
    ``pool_placement`` / ``pool_spill_bytes``
        ``device`` keeps entries as JAX device arrays that flow
        dispatcher -> pool -> dispatch without host round-trips (``host``
        reproduces the PR 2 behavior for A/B); a nonzero spill budget adds
        a host-RAM second tier that absorbs evictions.
    ``incremental_history`` / ``extend_buckets``
        stale hits whose cached entry encoded a window sharing a prefix
        with the new history re-encode ONLY the changed suffix + side
        token against the cached prefix K/V (``extend`` executor family;
        buckets are trusted-prefix lengths, default the full window — the
        tail-append case that re-encodes one token per block).  Note:
        under a lossy ``pool_dtype`` each extension re-quantizes the
        dequantized prefix, so drift can accumulate over a long-lived
        user's repeated extensions (bounded per step by the dtype's error;
        periodic forced re-encode is a ROADMAP follow-up).
    ``kv_dedup``
        identity-dedup of KV rows in the cached-scoring dispatcher: a
        multi-chunk request (or co-batched requests hitting one pool
        entry) stacks each user's KV rows once per dispatch, not once per
        chunk.  Default ``None`` = auto: ON for accelerator backends
        (the saved cost is the per-chunk host->HBM transfer; the
        executor-side row gather is an HBM-local copy, ~30x cheaper) and
        OFF for the CPU backend (stacking is a plain memcpy there, so the
        gather would be pure overhead — measured ~15% on 2 cores) —
        EXCEPT under ``impl="fused"``, where it is ON everywhere: the FKE
        folds the gather into the kernel's KV block reads, so dedup is
        free on every backend.
    ``extend_buckets`` / ``extend_refresh_limit``
        trusted-prefix lengths for the extend executor family (default:
        the (n, 3n/4, n/2) ladder) and the extension-drift cap — after
        this many incremental extensions of one entry (each of which
        re-quantizes under a lossy ``pool_dtype``) the next stale hit
        re-encodes in full (``pool_refresh_reencodes`` metric; 0 = off).
        Prefixes below half the window always re-encode (the
        re-encode-vs-extend crossover: the extension would redo most of
        the window while layering another requantization).

    DSO v2 (``pack_tails`` / ``deadline_s``):

    ``pack_tails``
        segment-packed ragged dispatch (needs ``history_cache``): partial
        tail chunks from DIFFERENT requests pack into shared ``(1,
        bucket)`` rows as independent segments, each steered to its own
        user's pooled history KV through a per-candidate ``[B, bucket]``
        KV slot index (candidates never attend to each other under SUMI,
        so packing is bitwise-clean — asserted in tests/test_dso_v2.py).
        Reclaims the 20-40% ``padded_fraction`` the greedy bucket split
        dispatches on non-uniform candidate traffic; subsumes KV-row
        dedup (same-user segments share one stacked KV slot).
        ``pack_rows`` (default ``max_batch / 4``) sizes the packed
        executors' row axis: packed rows are dense, so fewer rows carry
        the unpacked fill target's candidate throughput at a fraction of
        the per-dispatch executor cost, while ``max_batch`` still sizes
        the unique-KV axis (distinct users per dispatch).
    ``deadline_s``
        default per-request latency budget (seconds; a request's own
        ``ServeRequest.deadline_s`` overrides).  Pending chunks flush
        earliest-deadline-first with a shortest-remaining-work tie-break,
        and the DSO stops collecting co-riders as soon as its per-bucket
        cost model says waiting longer would miss the earliest collected
        deadline.  Overruns count into the ``deadline_misses`` metric.

    Mesh-sharded serving (``mesh=...``): executors AOT-compile with
    ``NamedSharding`` in/out specs resolved from
    ``sharding.serving_rules`` — the request-batch axis rides the mesh's
    ``data`` axis, attention heads ride ``model`` (tensor-parallel; when
    the KV heads don't divide the model ways, the history length takes
    the model axis instead, the context-parallel fallback shared with
    ``impl="cp"``), and the pooled-user row axis of stacked history KV is
    REPLICATED so the dedup/packed row gathers never cross shards.  The
    pool commits its entries to the same layout (``shard_spec``) and
    splits its byte budget per model shard; the DSO rounds batch/row
    capacities up to multiples of the data ways so one coalesced flush
    feeds every device without resharding on the hot path.

    FKE (``impl="fused"``): the ``cached`` executor family is compiled
    against the pool's RAW stored representation (int8/bf16 values + per-
    (layer, head) scales, ``serving/kv_cache.py::raw_kv_specs``) plus the
    dedup row index, and ``kernels/fused_score`` dequantizes tiles and
    resolves the row gather in-kernel — a pool hit dispatches without the
    host-side dequantize or the ``kv[idx]`` materialization the framework
    impls pay.  Hit and miss paths share the stored representation, so
    repeat scores are bitwise-stable."""

    def __init__(self, bundle: ModelBundle, params, *, n_history: int,
                 buckets: Sequence[int] = (512, 256, 128),
                 n_streams: int = 2,
                 feature_mode: str = "sync",
                 cache_capacity: int = 50_000, cache_ttl_s: float = 30.0,
                 store: Optional[PDA.RemoteFeatureStore] = None,
                 coalesce: bool = True, max_batch: int = 4,
                 window_s: float = 0.002,
                 max_pending: int = 64, n_workers: int = 4,
                 impl: str = "chunked",
                 history_cache: bool = False, pool_slots: int = 256,
                 pool_budget_bytes: Optional[int] = None,
                 pool_dtype: str = "native",
                 pool_placement: str = "device",
                 pool_spill_bytes: int = 0,
                 incremental_history: bool = False,
                 extend_buckets: Optional[Sequence[int]] = None,
                 extend_refresh_limit: int = 0,
                 extend_crossover: float = 0.5,
                 kv_dedup: Optional[bool] = None,
                 pack_tails: bool = False,
                 pack_rows: Optional[int] = None,
                 pack_align: Optional[int] = None,
                 deadline_s: float = 0.0,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 generate: int = 0,
                 gen_vocab: int = 256,
                 admission: str = "edf",
                 shed_policy: str = "none",
                 slo_tier_defaults: Optional[Dict[str, float]] = None,
                 watchdog_grace_s: float = 0.0,
                 degradation=None,
                 faults=None,
                 dispatch_retries: int = 2):
        self.bundle = bundle
        self.params = params
        self.cfg = bundle.cfg
        self.n_history = n_history
        self.impl = impl
        self._fused = impl == "fused"
        # mesh-sharded serving: executors compile with NamedSharding in/out
        # specs (batch over "data", attention heads over "model", pooled
        # user rows replicated) so one coalesced flush feeds every device
        self.mesh = mesh
        self._shard_rules: Optional[dict] = None
        self._data_ways = 1
        self._model_ways = 1
        if mesh is not None:
            self._shard_rules = shd.serving_rules(
                mesh, kv_heads=bundle.cfg.n_kv_heads)
            self._data_ways = int(mesh.shape.get("data", 1))
            self._model_ways = int(mesh.shape.get("model", 1))
        self._pack_tails = bool(pack_tails)
        if pack_rows is None and pack_tails:
            # packed rows are dense where unpacked rows are mostly padding:
            # a quarter of the row capacity carries a comparable candidate
            # throughput on the heavy-tailed traffic packing targets, at a
            # quarter of the per-dispatch executor cost.  max_batch still
            # sizes the unique-KV axis (distinct users per dispatch).
            pack_rows = max(1, max_batch // 4)
        self._pack_rows = pack_rows
        # bq-aligned packed dispatch (FKE v2): when the packer starts every
        # candidate segment on a multiple of the kernel's q-block size, 2-D
        # seg indices are constant per block and the packed fused families
        # keep the kernel formulation instead of silently rerouting to jnp.
        # Default: align to the Pallas sublane quantum under fused packing,
        # plain first-fit (align 1, bitwise-identical layouts) elsewhere.
        if pack_align is None:
            pack_align = 8 if (self._fused and pack_tails) else 1
        pack_align = int(pack_align)
        if pack_align > 1 and pack_align % 8:
            raise ValueError(
                f"pack_align must be 1 (unaligned) or a multiple of 8 "
                f"(Pallas sublane quantum), got {pack_align}")
        self._pack_align = pack_align
        # value for the fused-ops module knob at TRACE time: 0 declares
        # "no alignment contract" (2-D kernel dispatch reroutes to jnp)
        self._ops_pack_align = pack_align if pack_align > 1 else 0
        self._deadline_s = float(deadline_s)
        if pack_tails and not history_cache:
            raise ValueError(
                "pack_tails=True needs history_cache=True: segment packing "
                "steers each candidate segment to its own user's POOLED "
                "history KV — the monolithic full-pass family has no "
                "per-user KV rows to steer to")
        self.store, self.features = _make_features(
            feature_mode, store, cache_capacity, cache_ttl_s)

        self.history_pool: Optional[HistoryKVPool] = None
        self._extend_buckets: tuple = ()
        self._extend_refresh_limit = int(extend_refresh_limit)
        if history_cache:
            if bundle.encode_history is None or bundle.score_candidates is None:
                raise ValueError(
                    "history_cache=True needs a bundle with the split "
                    "encode_history/score_candidates serving surface")
            if incremental_history:
                if bundle.extend_history is None:
                    raise ValueError(
                        "incremental_history=True needs a bundle with the "
                        "extend_history serving surface")
                explicit_buckets = extend_buckets is not None
                if extend_buckets is None:
                    # default trusted-prefix ladder (n, 3n/4, n/2): the
                    # dominant tail-append case extends from the full
                    # window, mid-window edits from the nearest rung
                    extend_buckets = (n_history, 3 * n_history // 4,
                                      n_history // 2)
                # re-encode-vs-extend crossover: an extension re-encodes
                # the (window - bucket) suffix, so once the trusted prefix
                # drops below ``extend_crossover`` of the window the
                # extension does most of a full re-encode's work anyway
                # (while layering another requantization).  Buckets below
                # the threshold are dropped HERE so no AOT executor is
                # ever compiled for a rung the dispatch policy would never
                # route to (executor builds dominate engine startup).
                min_prefix = int(extend_crossover * n_history)
                self._extend_buckets = tuple(sorted(
                    {b for b in extend_buckets if b >= max(min_prefix, 1)},
                    reverse=True))
                if explicit_buckets and not self._extend_buckets:
                    # every user-supplied rung fell below the crossover:
                    # silently serving full re-encodes would contradict
                    # the explicit incremental request — fail loudly
                    raise ValueError(
                        f"extend_buckets {tuple(extend_buckets)} all fall "
                        f"below the re-encode-vs-extend crossover "
                        f"({min_prefix} = {extend_crossover:g} * "
                        f"n_history); raise the buckets or lower "
                        f"extend_crossover")
            self.history_pool = HistoryKVPool(
                pool_slots, budget_bytes=pool_budget_bytes, dtype=pool_dtype,
                placement=pool_placement, spill_bytes=pool_spill_bytes,
                mesh=mesh, shard_spec=self._kv_leaf_sharding)
            kv_specs = bundle.history_kv_specs(params, n_history, batch=1)
            # the FKE ("fused") executors consume the pool's RAW
            # representation — stored-precision values + per-(layer, head)
            # scales, dequantized in-kernel (cached scoring) or in-graph
            # (extend basis) — so their compiled signature quantizes the
            # row specs instead of the engine dequantizing every hit (or
            # every stale basis) on the host
            cached_specs = raw_kv_specs(kv_specs, pool_dtype) \
                if self._fused else kv_specs
            cleaves, self._cached_treedef = jax.tree.flatten(cached_specs)
            self._cached_row_specs = cleaves
            # compute dtype the stored representation dequantizes back to
            # (prequantized puts must record it so later dequantizing
            # lookups round-trip to the executors' compiled input dtype)
            self._kv_compute_dtype = jax.tree.leaves(kv_specs)[0].dtype
            if kv_dedup is None:
                # auto: ON for accelerator backends (each deduped row is a
                # skipped H2D transfer) and, under the fused impl, on EVERY
                # backend — the row gather is folded into the kernel's KV
                # block reads, so dedup costs nothing even on CPU
                kv_dedup = jax.default_backend() != "cpu" or self._fused
            self._kv_dedup = kv_dedup
            self._encode_inflight: Dict[tuple, Future] = {}
            self._encode_lock = threading.Lock()
            self._key_memo: Dict[int, tuple] = {}   # request_id -> (key, fp)

        # generative candidate decode (ISSUE 8): ``generate`` is the
        # engine's per-request generation CAPACITY in steps — beam caches
        # are padded by this many extra sequence slots up front so every
        # append is a fixed-shape in-place write (one compiled executor,
        # no recompiles as beams grow)
        self._generate = int(generate)
        self._gen_vocab = int(gen_vocab)
        self._gen_lock = threading.Lock()
        self._gen_t0: Optional[float] = None
        self._gen_last = 0.0
        self._gen_tokens = 0
        self._beams_in_flight = 0
        if self._generate:
            if not history_cache:
                raise ValueError(
                    "generate>0 needs history_cache=True: in-flight beams "
                    "live in the HistoryKVPool as growing entries and the "
                    "decode step reads pooled history KV as its prompt")
            if mesh is not None:
                raise ValueError(
                    "generate>0 under a mesh is not supported yet: beam "
                    "caches are per-request host-orchestrated state and "
                    "would reshard on every append")
            if bundle.decode_logits is None or bundle.append_token is None:
                raise ValueError(
                    "generate>0 needs a bundle with the decode_logits/"
                    "append_token generative serving surface")
            # decode/append executors speak PADDED beam caches: the cached
            # row specs with ``generate`` extra slots on the sequence axis,
            # filled one per appended token (valid prefix = lengths).
            # Under the fused impl the raw specs interleave per-(layer,
            # head) scale leaves (trailing singleton) with the value
            # leaves; a beam keeps its ROOT scales for the whole
            # generation (appended tokens quantize against them in the
            # epilogue), so scale leaves don't grow with the beam
            self._decode_row_specs = tuple(
                s if s.shape[-1] == 1 else jax.ShapeDtypeStruct(
                    s.shape[:2] + (s.shape[2] + self._generate,)
                    + s.shape[3:], s.dtype)
                for s in self._cached_row_specs)
            self._s0 = int(self._cached_row_specs[0].shape[2])

        # baseline for the packed_kernel_reroutes delta counter: the ops
        # module count is process-wide and may predate this engine
        self._reroutes_seen = packed_reroute_count()

        hist_spec = lambda batch: jax.ShapeDtypeStruct(  # noqa: E731
            (batch, n_history), jnp.int32)
        side_spec = lambda batch: jax.ShapeDtypeStruct(  # noqa: E731
            (batch, N_SIDE_FEATURES), jnp.float32)
        _batched = lambda specs, batch: tuple(  # noqa: E731
            jax.ShapeDtypeStruct((batch,) + s.shape[1:], s.dtype)
            for s in specs)
        cached_row_shapes = lambda batch: _batched(  # noqa: E731
            self._cached_row_specs, batch)
        decode_row_shapes = lambda batch: _batched(  # noqa: E731
            getattr(self, "_decode_row_specs", ()), batch)

        def build_fn(kind: str, bucket: int, batch: int):
            if kind == "full":
                def fn(history, candidates, side):
                    b = {"history": history,
                         # -1 chunk-padding sentinel -> a real (ignored) row
                         "candidates": jnp.maximum(candidates, 0),
                         "side": side}
                    return bundle.prefill(self.params, b, impl=self.impl)
                shapes = (hist_spec(batch),
                          jax.ShapeDtypeStruct((batch, bucket), jnp.int32),
                          side_spec(batch))
            elif kind == "encode":
                # Under the fused impl the executor quantizes IN-EPILOGUE
                # (FKE v2): its output is the pool's stored representation
                # — (values, scale) leaves from quantize_kv_graph — so a
                # miss pools what it just computed via put(prequantized=
                # True) and scores from the same leaves, with no separate
                # quantize pass and no raw read-back
                def fn(history, side):
                    kv = bundle.encode_history(
                        self.params, {"history": history, "side": side},
                        impl=self.impl)
                    if self._fused:
                        kv = quantize_kv_graph(kv, self.history_pool.dtype)
                    return kv
                shapes = (hist_spec(batch), side_spec(batch))
            elif kind == "extend":
                # bucket = trusted prefix length: re-encode window positions
                # >= bucket (plus the side token) against the cached prefix.
                # Under the fused impl the basis arrives RAW (the pool's
                # stored int8/bf16 leaves + scales, 4x fewer dispatch bytes
                # for int8) and dequantizes in-graph inside extend_history
                def fn(*args):
                    *kv_leaves, history, side = args
                    kv = jax.tree.unflatten(self._cached_treedef,
                                            list(kv_leaves))
                    out = bundle.extend_history(
                        self.params, kv, {"history": history, "side": side},
                        prefix_len=bucket, impl=self.impl)
                    if self._fused:
                        # in-epilogue re-quantize: same contract as encode
                        out = quantize_kv_graph(out, self.history_pool.dtype)
                    return out
                shapes = cached_row_shapes(batch) + (hist_spec(batch),
                                                     side_spec(batch))
            elif kind == "cached":
                if self._pack_tails:
                    # DSO v2 segment-packed signature: one row may carry
                    # candidate segments of several users; seg_idx [B,
                    # bucket] steers every candidate to its own user's
                    # stacked KV row (per-candidate generalization of the
                    # dedup row index — consumed in-kernel under fused,
                    # via the reference-structured segment attention
                    # elsewhere)
                    def fn(*args):
                        *kv_leaves, seg_idx, candidates = args
                        kv = jax.tree.unflatten(self._cached_treedef,
                                                list(kv_leaves))
                        return bundle.score_candidates(
                            self.params, kv, jnp.maximum(candidates, 0),
                            impl=self.impl, row_index=seg_idx)
                    # policy.rows (late-bound: build_fn runs inside the
                    # orchestrator's executor build) carries the mesh
                    # rounding, so compiled rows match the packer's capacity
                    rows = policy.rows
                    shapes = cached_row_shapes(batch) + (
                        jax.ShapeDtypeStruct((rows, bucket), jnp.int32),
                        jax.ShapeDtypeStruct((rows, bucket), jnp.int32))
                elif self._kv_dedup:
                    # deduped signature: unique KV rows + per-row gather idx
                    def fn(*args):
                        *kv_leaves, idx, candidates = args
                        if self._fused:
                            # FKE: the raw (stored-precision) rows and the
                            # gather index flow straight into the kernel —
                            # no host dequant, no kv[idx] materialization
                            kv = jax.tree.unflatten(self._cached_treedef,
                                                    list(kv_leaves))
                            return bundle.score_candidates(
                                self.params, kv, jnp.maximum(candidates, 0),
                                impl=self.impl, row_index=idx)
                        kv = jax.tree.unflatten(
                            self._cached_treedef,
                            [jnp.take(a, idx, axis=0) for a in kv_leaves])
                        return bundle.score_candidates(
                            self.params, kv, jnp.maximum(candidates, 0),
                            impl=self.impl)
                    shapes = cached_row_shapes(batch) + (
                        jax.ShapeDtypeStruct((batch,), jnp.int32),
                        jax.ShapeDtypeStruct((batch, bucket), jnp.int32))
                else:
                    def fn(*args):
                        *kv_leaves, candidates = args
                        kv = jax.tree.unflatten(self._cached_treedef,
                                                list(kv_leaves))
                        return bundle.score_candidates(
                            self.params, kv, jnp.maximum(candidates, 0),
                            impl=self.impl)
                    shapes = cached_row_shapes(batch) + (
                        jax.ShapeDtypeStruct((batch, bucket), jnp.int32),)
            elif kind == "decode":
                # one generative-decode step: score ``bucket`` next-token
                # candidates per row against padded beam caches with valid
                # prefix ``lengths``.  Under pack_tails the family is
                # SEGMENT-PACKED exactly like "cached" — in-flight beams
                # from different requests (at different lengths) bin-pack
                # into shared rows, each candidate steered to its own
                # beam's stacked cache row AND its own valid length by the
                # per-candidate seg index; ``lengths`` rides as an extra
                # packable lead arg alongside the KV leaves.
                if self._pack_tails:
                    def fn(*args):
                        *kv_leaves, lengths, seg_idx, candidates = args
                        kv = jax.tree.unflatten(self._cached_treedef,
                                                list(kv_leaves))
                        return bundle.decode_logits(
                            self.params, kv, jnp.maximum(candidates, 0),
                            lengths, impl=self.impl, row_index=seg_idx)
                    rows = policy.rows
                    shapes = decode_row_shapes(batch) + (
                        jax.ShapeDtypeStruct((batch,), jnp.int32),
                        jax.ShapeDtypeStruct((rows, bucket), jnp.int32),
                        jax.ShapeDtypeStruct((rows, bucket), jnp.int32))
                else:
                    def fn(*args):
                        *kv_leaves, lengths, candidates = args
                        kv = jax.tree.unflatten(self._cached_treedef,
                                                list(kv_leaves))
                        return bundle.decode_logits(
                            self.params, kv, jnp.maximum(candidates, 0),
                            lengths, impl=self.impl)
                    shapes = decode_row_shapes(batch) + (
                        jax.ShapeDtypeStruct((batch,), jnp.int32),
                        jax.ShapeDtypeStruct((batch, bucket), jnp.int32))
            elif kind == "append":
                # grow a beam cache by its chosen token's K/V at position
                # ``lengths`` — a fixed-shape scatter into the padded cache,
                # so every step of every beam reuses this one executor
                def fn(*args):
                    *kv_leaves, lengths, tokens = args
                    kv = jax.tree.unflatten(self._cached_treedef,
                                            list(kv_leaves))
                    return bundle.append_token(
                        self.params, kv, jnp.maximum(tokens, 0), lengths,
                        impl=self.impl)
                shapes = decode_row_shapes(batch) + (
                    jax.ShapeDtypeStruct((batch,), jnp.int32),
                    jax.ShapeDtypeStruct((batch, 1), jnp.int32))
            else:
                raise ValueError(kind)
            # declare the packer's bq-alignment contract for the duration
            # of THIS trace: the fused ops module consults it when a 2-D
            # seg index reaches _fused_attention, and the knob is process-
            # wide — scoping it to the compile keeps engines with
            # different pack_align settings from leaking into each other
            prev_align = set_packed_alignment(self._ops_pack_align)
            try:
                if self.mesh is not None:
                    # attach the resolved NamedSharding specs to the AOT
                    # signature: the executor consumes its operands in
                    # exactly the layout the dispatcher stacks / the pool
                    # stores them, so the steady-state hot path never
                    # reshards.  Tracing under mesh_rules() binds the
                    # model's constrain_ctx annotations (and the impl="cp"
                    # shard_map route) to the same rule table.
                    shapes = tuple(
                        jax.ShapeDtypeStruct(
                            s.shape, s.dtype,
                            sharding=self._arg_sharding(s.shape))
                        for s in shapes)
                    out_sh = jax.tree.map(
                        lambda s: self._arg_sharding(s.shape),
                        jax.eval_shape(fn, *shapes))
                    with shd.mesh_rules(self.mesh, self._shard_rules):
                        return jax.jit(fn, out_shardings=out_sh) \
                            .lower(*shapes).compile()
                return jax.jit(fn).lower(*shapes).compile()
            finally:
                set_packed_alignment(prev_align)

        # the bucket key gains a hit/miss dimension: candidate-only
        # ("cached") executors serve pool traffic, "encode" repopulates the
        # pool on miss, "extend" refreshes a stale entry from its cached
        # prefix, "full" is the monolithic path when the pool is off
        dedup_kinds = None
        packed_kinds = None
        device_output_kinds: tuple = ()
        if history_cache:
            families = {"cached": tuple(buckets), "encode": (n_history,)}
            if self._extend_buckets:
                families["extend"] = self._extend_buckets
            if self._pack_tails:
                # packing subsumes KV-row dedup: same-user segments share
                # one stacked KV slot inside the packer
                packed_kinds = {"cached": len(self._cached_row_specs)}
            elif kv_dedup:
                dedup_kinds = {"cached": len(self._cached_row_specs)}
            if self._generate:
                families["decode"] = tuple(buckets)
                families["append"] = (1,)
                if self._pack_tails:
                    # the beam's valid length packs alongside its KV leaves
                    # (one lead-arg tuple per unique beam -> one stacked
                    # slot), so a packed row mixes beams at different
                    # lengths without padding any of them
                    packed_kinds["decode"] = len(self._cached_row_specs) + 1
            if pool_placement == "device" and jax.default_backend() != "cpu":
                # encode/extend outputs feed the pool: keep them on device.
                # On the CPU backend host and device memory coincide, so the
                # numpy scatter path is the same placement without the
                # per-row device-slice dispatch overhead.
                device_output_kinds = ("encode", "extend")
                if self._generate:
                    device_output_kinds += ("append",)
        else:
            families = {"full": tuple(buckets)}
        policy = DSO.CoalescePolicy(enabled=coalesce, max_batch=max_batch,
                                    window_s=window_s,
                                    pack_rows=self._pack_rows,
                                    pack_align=self._pack_align,
                                    data_ways=self._data_ways,
                                    tier_windows=dict(_TIER_WINDOW_SCALE))
        self.dso = DSO.CoalescingOrchestrator(
            build_fn, pad_slice_fn=self._pad_slice, gather_fn=self._gather,
            policy=policy, n_streams=n_streams, families=families,
            dedup_kinds=dedup_kinds, packed_kinds=packed_kinds,
            device_output_kinds=device_output_kinds,
            # multi-device executables must not overlap their collectives
            # (XLA rendezvous has no cross-computation ordering — see
            # CoalescingOrchestrator); a 1x1 mesh stays fully concurrent
            serialize_dispatch=mesh is not None and mesh.size > 1,
            fault_hook=None if faults is None else faults.dispatch,
            dispatch_retries=dispatch_retries)
        super().__init__(max_pending=max_pending, n_workers=n_workers,
                         name="flame", admission=admission,
                         shed_policy=shed_policy,
                         slo_tier_defaults=slo_tier_defaults,
                         watchdog_grace_s=watchdog_grace_s,
                         degradation=degradation, faults=faults)

    # back-compat alias: callers used to read eng.pool.build_time_s
    @property
    def pool(self):
        return self.dso

    # ---- mesh sharding (logical layouts -> NamedSharding) ----
    def _kv_leaf_sharding(self, shape):
        """Sharding for one stored/stacked history-KV leaf (5-d: [rows, L,
        S, Hkv, D] values or [rows, L, 1, Hkv, 1] scales): heads ride the
        model axis, the pooled-user row axis stays replicated.  Doubles as
        the pool's placement callback so pooled KV lives where its heads
        live; returns None for non-KV shapes or mesh-less engines."""
        if self.mesh is None or len(shape) != 5:
            return None
        return shd.logical_to_sharding(shd.SERVING_KV_LEAF, shape,
                                       self.mesh, self._shard_rules)

    def _arg_sharding(self, shape):
        """NamedSharding for one executor operand/result: 5-d arrays are
        history-KV leaves; everything else (history / side / candidates /
        seg_idx / scores) leads with the request-batch axis, which rides
        the data axis."""
        kv = self._kv_leaf_sharding(shape)
        if kv is not None:
            return kv
        logical = ("batch",) + (None,) * (len(shape) - 1)
        return shd.logical_to_sharding(logical, shape, self.mesh,
                                       self._shard_rules)

    def _pool_key(self, request: ServeRequest
                  ):  # flamecheck: host-sync-ok(admission-time canonicalization: histories arrive as host numpy and the content hash must read host bytes)
        fp = self._fingerprint(np.asarray(request.history, np.int32))
        key = ("u", int(request.user_id)) \
            if request.user_id is not None else ("h", fp)
        return key, fp

    def _admit_hook(self, request: ServeRequest):
        if self.history_pool is not None and (
                request.candidates is not None
                or request.generate is not None):
            key, fp = self._pool_key(request)
            # stash for _execute so the O(n_history) hash runs once; the
            # memo is written on the submitter thread and consumed on a
            # pipeline worker, so it shares the encode lock
            with self._encode_lock:
                self._key_memo[request.request_id] = (key, fp)
            if self.history_pool.contains(key, fp):
                return      # pool hit ahead: side features never consumed
        super()._admit_hook(request)

    # ---- chunk plumbing (host-side; the dispatcher stacks + transfers) ----
    @staticmethod
    def _slice_candidates(candidates, chunk: DSO.Chunk):
        sl = candidates[:, chunk.start:chunk.start + chunk.valid]
        if chunk.valid < chunk.bucket:
            # -1 sentinel: padding is never a real item id (0 is)
            sl = np.pad(sl, ((0, 0), (0, chunk.bucket - chunk.valid)),
                        constant_values=-1)
        return sl

    def _pad_slice(self, request, chunk: DSO.Chunk, kind: str):
        if kind == "encode":
            history, side = request
            return history, side
        if kind == "extend":
            kv_leaves, history, side = request
            return tuple(kv_leaves) + (history, side)
        if kind == "full":
            history, candidates, side = request
            return history, self._slice_candidates(candidates, chunk), side
        if kind == "append":
            kv_leaves, lengths, tokens = request
            return tuple(kv_leaves) + (lengths, tokens)
        if kind == "decode":
            kv_leaves, lengths, candidates = request
            if self._pack_tails:
                sl = candidates[:, chunk.start:chunk.start + chunk.valid]
                return tuple(kv_leaves) + (lengths, sl)
            return tuple(kv_leaves) + (
                lengths, self._slice_candidates(candidates, chunk))
        kv_leaves, candidates = request          # cached
        if self._pack_tails:
            # packed family: hand the dispatcher the UNPADDED segment —
            # the packer places it at an arbitrary row offset and pads the
            # assembled row once
            sl = candidates[:, chunk.start:chunk.start + chunk.valid]
            return tuple(kv_leaves) + (sl,)
        return tuple(kv_leaves) + (self._slice_candidates(candidates, chunk),)

    def _gather(self, rows, chunks: List[DSO.Chunk], m: int,
                kind: str = "full"):
        if kind in ("encode", "extend", "append"):
            return rows[0]                      # one chunk: the KV pytree
        parts = [r[:, :c.valid] for r, c in zip(rows, chunks)]
        return np.concatenate(parts, axis=1)

    # ---- history-KV pool ----
    @staticmethod
    def _fingerprint(history: np.ndarray) -> str:
        """Content hash of the FULL history array — the model truncates to
        n_history, but side features average over every entry, so a
        tail-only change must read as stale too (full-pass parity)."""
        return hashlib.blake2b(np.ascontiguousarray(history).tobytes(),
                               digest_size=16).hexdigest()

    @staticmethod
    def _shared_prefix(cached: Optional[np.ndarray], new: np.ndarray
                       ) -> int:  # flamecheck: host-sync-ok(prefix diff of two host-resident id windows; no device arrays involved)
        """Length of the common leading run of two history windows (-1 when
        no basis window is available)."""
        if cached is None or cached.shape != new.shape:
            return -1
        neq = np.nonzero(np.asarray(cached) != np.asarray(new))[0]
        return int(neq[0]) if neq.size else int(new.shape[0])

    def _cached_rows(self, kv) -> tuple:
        """Flatten a pool lookup result into the cached-executor arg order.
        Under the fused impl the result is a raw view — (values, scale)
        tuples over the stored arrays — whose flatten order matches the
        compiled raw-spec signature; otherwise it is the dequantized leaf
        tuple unchanged."""
        return tuple(jax.tree.leaves(kv))

    def _lookup_or_encode(self, req: ServeRequest, hist: np.ndarray,
                          memo: Optional[tuple] = None,
                          deadline: Optional[float] = None,
                          _retry: bool = True
                          ) -> Tuple[tuple, str, float]:
        """Returns (kv_leaves, path, features_s) with path one of ``hit`` /
        ``encode`` / ``extend`` / ``wait``; encodes (or, on an extendable
        stale hit, suffix-extends the dropped entry) and repopulates the
        pool on miss.  Concurrent misses for one (key, fingerprint) are
        single-flighted: the first worker encodes, co-arriving session
        requests wait on its future instead of dispatching duplicate
        O(n_history) encodes.  Under the fused impl the stale basis is
        read back RAW (``raw_basis``): the extend executors are compiled
        against the pool's stored representation and dequantize in-graph,
        so the host-side dequant of the dropped entry is gone."""
        key, fp = memo if memo is not None else self._pool_key(req)
        kv, status, basis = self.history_pool.lookup(
            key, fp, want_basis=bool(self._extend_buckets),
            raw=self._fused, raw_basis=self._fused)
        if status == "hit":
            return self._cached_rows(kv), "hit", 0.0
        with self._encode_lock:
            fut = self._encode_inflight.get((key, fp))
            leader = fut is None
            if leader:
                # a racing leader may have put + deregistered between our
                # counted miss and taking this lock — re-check (uncounted)
                # before electing ourselves and re-encoding
                kv = self.history_pool.peek(key, fp, raw=self._fused)
                if kv is not None:
                    return self._cached_rows(kv), "wait", 0.0
                fut = Future()
                self._encode_inflight[(key, fp)] = fut
        if not leader:
            try:
                return fut.result(), "wait", 0.0
            except BaseException:
                # single-flight recovery: the leader we coalesced behind
                # died (e.g. a poisoned request or an injected fault) — its
                # failure is ITS OWN, not ours.  Re-enter once: the dead
                # leader has deregistered, so we either become the new
                # leader or join a healthy one.  One retry only, so a
                # deterministically-failing encode still fails everyone.
                if not _retry:
                    raise
                self._metrics.incr("encode_recoveries")
                return self._lookup_or_encode(req, hist, memo, deadline,
                                              _retry=False)
        try:
            t0 = time.perf_counter()
            side = self._side_features(req.history)
            t1 = time.perf_counter()
            kv_tree, path, refreshes = None, "encode", 0
            if basis is not None and self._extend_buckets:
                # stale hit sharing a window prefix with the dropped entry:
                # re-encode only the suffix + side token against its K/V
                shared = self._shared_prefix(basis.hist_window, hist[0])
                bucket = max((b for b in self._extend_buckets if b <= shared),
                             default=None)
                if bucket is not None and self._extend_refresh_limit and \
                        basis.refreshes >= self._extend_refresh_limit:
                    # extension-drift cap: this entry has been extended
                    # (re-quantized) K times since its last full encode
                    bucket = None
                    self.history_pool.count_refresh_reencode()
                if bucket is not None:
                    basis_leaves = tuple(jax.tree.leaves(basis.kv))
                    kv_tree = self.dso.score((basis_leaves, hist, side),
                                             bucket, kind="extend",
                                             deadline=deadline,
                                             tier=req.slo_tier)
                    path = "extend"
                    refreshes = basis.refreshes + 1
                    self.history_pool.count_extension()
            if kv_tree is None:
                kv_tree = self.dso.score((hist, side), self.n_history,
                                         kind="encode", deadline=deadline,
                                         tier=req.slo_tier)
            # device-resident rows arrive as fresh device buffers (XLA
            # slices of the stacked dispatch output); host rows are numpy
            # VIEWS into the (max_batch, ...) stacked parent — copy those so
            # pooling them doesn't pin the padded parent or make pool_bytes
            # under-report
            kv = tuple(np.array(a) if isinstance(a, np.ndarray) else a
                       for a in jax.tree.leaves(
                           kv_tree))  # flamecheck: host-sync-ok(copies host VIEWS out of the padded stacked parent so pooling them cannot pin it)
            if self._fused:
                # in-epilogue quantize (FKE v2): the encode/extend
                # executors already emitted the pool's stored
                # representation, so pool it as-is (no second quantize
                # pass) and score from the very same leaves — hit, wait,
                # encode and extend paths all share one representation
                # without the raw read-back the un-fused flow needs
                self.history_pool.put(
                    key, fp, jax.tree.unflatten(self._cached_treedef,
                                                list(kv)),
                    hist_window=hist[0], refreshes=refreshes,
                    prequantized=True,
                    compute_dtype=self._kv_compute_dtype)
            else:
                self.history_pool.put(key, fp, kv, hist_window=hist[0],
                                      refreshes=refreshes)
            self._metrics.set_gauge("pool_bytes_used",
                                    self.history_pool.bytes_used)
            for i, b in enumerate(self.history_pool.shard_bytes()):
                self._metrics.set_gauge(f"pool_bytes_used_shard{i}", b)
            fut.set_result(kv)
        except BaseException as e:
            fut.set_exception(e)
            raise
        finally:
            with self._encode_lock:
                self._encode_inflight.pop((key, fp), None)
        return kv, path, t1 - t0

    def _degrade_level(self) -> int:
        return 0 if self._degradation is None else self._degradation.level

    def _execute(self, req: ServeRequest):
        memo = None
        if self.history_pool is not None:
            with self._encode_lock:
                memo = self._key_memo.pop(req.request_id, None)
            if self._faults is not None:
                # eviction-storm arm: pressure-spike / cold-restart stand-in
                dropped = self._faults.pool_storm(self.history_pool)
                if dropped:
                    self._metrics.incr("fault_pool_evictions", dropped)
        self._check_request(req)
        if req.generate is not None:
            return self._execute_generate(req, memo)
        t0 = time.perf_counter()
        dl = self._effective_deadline(req)
        deadline = (req.arrival_t + dl) if dl else None
        hist = np.asarray(req.history[None, :self.n_history],
                          np.int32)  # flamecheck: host-sync-ok(request arrays arrive as host numpy; dtype canonicalized once at admission)
        cand = np.asarray(req.candidates[None],
                          np.int32)  # flamecheck: host-sync-ok(request arrays arrive as host numpy; dtype canonicalized once at admission)
        if self.history_pool is None:
            side = self._side_features(req.history)
            t1 = time.perf_counter()
            out = self.dso.score((hist, cand, side), req.m, kind="full",
                                 deadline=deadline, tier=req.slo_tier)
            t2 = time.perf_counter()
            return out[0], {"features_s": t1 - t0, "execute_s": t2 - t1}
        key_fp = memo if memo is not None else self._pool_key(req)
        if req.slo_tier == "bulk" and self._degrade_level() >= 3:
            # level-3 degradation: bulk-tier encodes are suppressed — serve
            # only from cache, shed the rest (cached-hit-or-shed)
            kv_raw = self.history_pool.peek(key_fp[0], key_fp[1],
                                            raw=self._fused)
            if kv_raw is None:
                self._metrics.incr("degrade_shed")
                raise DegradedError(
                    f"request {req.request_id} (bulk) shed: level-3 "
                    f"degradation suppresses encodes and the pool has no "
                    f"entry for this session")
            kv, path, features_s = self._cached_rows(kv_raw), "hit", 0.0
        else:
            kv, path, features_s = self._lookup_or_encode(req, hist, key_fp,
                                                          deadline)
        t1 = time.perf_counter()
        # On a HIT the (key, fingerprint) pair is a stable content identity
        # for the loaded rows (every hit dequantizes the same payload), so
        # co-batched requests for one user dedup even when a quantized pool
        # dequantizes to fresh arrays per lookup.  Under the framework
        # impls, miss paths carry the leader's PRE-quantization KV — under
        # a lossy pool dtype that is a different representation than a
        # hit's, so they fall back to object identity (which still dedups
        # one request's own chunks and single-flight followers sharing the
        # leader's tuple).  Under the FUSED impl every path reads the
        # stored (quantized) representation — the miss leader reads the
        # entry back raw after put — so hit, wait, encode and extend rows
        # all share one content identity and dedup across co-batched
        # requests unconditionally.
        token = None
        if (self._kv_dedup or self._pack_tails) \
                and (self._fused or path == "hit"):
            token = ("kv",) + key_fp[0] + (key_fp[1],)
        out = self.dso.score((kv, cand), req.m, kind="cached",
                             dedup_token=token, deadline=deadline,
                             tier=req.slo_tier)
        t2 = time.perf_counter()
        build_s = (t1 - t0) - features_s
        return out[0], {"features_s": features_s,
                        "encode_s": build_s if path == "encode" else 0.0,
                        "extend_s": build_s if path == "extend" else 0.0,
                        "pool_hit": 1.0 if path == "hit" else 0.0,
                        "execute_s": t2 - t1}

    # ---- generative candidate decode (ISSUE 8) ----
    def _pad_beam_leaves(self, kv_leaves) -> tuple:
        """Pad base (s0-row) cache leaves to the decode executors' S_pad =
        s0 + generate slots — once per request root, on the host; every
        subsequent append is a fixed-shape in-place write.  Raw (fused)
        leaf tuples interleave per-(layer, head) scale leaves — trailing
        singleton — which stay at their root shape: appended tokens
        quantize against the root scales (see ``_decode_row_specs``)."""
        pad = ((0, 0), (0, 0), (0, self._generate), (0, 0), (0, 0))
        return tuple(
            np.asarray(a) if a.shape[-1] == 1 else np.pad(np.asarray(a), pad)
            for a in
            kv_leaves)  # flamecheck: host-sync-ok(one-time root-cache padding; beam orchestration is host-side by design)

    def _copy_kv_rows(self, kv_tree) -> tuple:
        """Flatten an executor KV result and copy host VIEWS out of the
        padded stacked dispatch parent (same rule as the encode path)."""
        return tuple(
            np.array(a) if isinstance(a, np.ndarray) else a
            for a in jax.tree.leaves(
                kv_tree))  # flamecheck: host-sync-ok(copies host VIEWS out of the padded stacked parent so holding them cannot pin it)

    def _note_gen_tokens(self, n: int):
        now = time.perf_counter()
        with self._gen_lock:
            if self._gen_t0 is None:
                self._gen_t0 = now
            self._gen_last = now
            self._gen_tokens += n
        self._metrics.incr("gen_tokens", n)

    def _shift_beams_in_flight(self, delta: int):
        with self._gen_lock:
            self._beams_in_flight += delta
            n = self._beams_in_flight
        self._metrics.set_gauge("beams_in_flight", n)

    def _beam_leaves(self, req, hist, memo, beam: _Beam, deadline) -> tuple:
        """The beam's padded KV cache: local copy if the pool rejected it,
        else a pool lookup — and, when the entry was LRU-evicted
        mid-generation, a replay (re-encode the history base, re-append
        every generated token; ``gen_replays`` counts these)."""
        if beam.leaves is not None:
            return beam.leaves
        kv, status, _ = self.history_pool.lookup(beam.pool_key, beam.pool_fp,
                                                 raw=self._fused)
        if status == "hit":
            return tuple(jax.tree.leaves(kv))
        self._metrics.incr("gen_replays")
        base, _, _ = self._lookup_or_encode(req, hist, memo, deadline)
        leaves = self._pad_beam_leaves(base)
        for i, tok in enumerate(beam.tokens):
            kv_tree = self.dso.score(
                (leaves, np.full((1,), self._s0 + i, np.int32),
                 np.asarray(
                     [[tok]],
                     np.int32)),  # flamecheck: host-sync-ok(replayed tokens are host python ints; beam orchestration is host-side by design)
                1, kind="append", deadline=deadline, tier=req.slo_tier)
            leaves = self._copy_kv_rows(kv_tree)
        return leaves

    def _park_beam(self, req, slot: int, beam: _Beam, leaves: tuple,
                   hist_fp) -> None:
        """Hand a beam's cache to the pool (key = (\"g\", request id, beam
        slot); fingerprint = the token path, so a slot overwritten by a
        different hypothesis next step reads as a miss, not a wrong hit).
        On accept the local copy is dropped — the pool's LRU/byte budget
        governs the beam like any user entry; on reject it stays local."""
        key = ("g", req.request_id, slot)
        fp = (hist_fp,) + beam.tokens
        if self._fused:
            # the appended cache is already the stored representation
            # (climber's append epilogue quantizes the new token against
            # the root scales in-graph) — park it without re-quantizing
            accepted = self.history_pool.put(
                key, fp, jax.tree.unflatten(self._cached_treedef,
                                            list(leaves)),
                prequantized=True, compute_dtype=self._kv_compute_dtype)
        else:
            accepted = self.history_pool.put(key, fp, leaves)
        if accepted:
            beam.pool_key, beam.pool_fp, beam.leaves = key, fp, None
        else:
            beam.leaves = leaves

    def _execute_generate(self, req: ServeRequest, memo: Optional[tuple]):
        from repro.serving import generate as G
        from repro.serving.api import BeamConfig, TopKConfig
        gen = req.generate
        if isinstance(gen, TopKConfig):
            width, steps, eos, beam_mode = int(gen.k), int(gen.steps), \
                gen.eos, False
        elif isinstance(gen, BeamConfig):
            width, steps, eos, beam_mode = int(gen.width), int(gen.steps), \
                gen.eos, True
        else:
            raise ValueError(
                f"request {req.request_id}: generate must be a TopKConfig "
                f"or BeamConfig, got {type(gen).__name__}")
        if not self._generate:
            raise ValueError(
                "this engine was built without generative capacity; "
                "construct it with generate=<max steps>")
        if not 1 <= steps <= self._generate:
            raise ValueError(
                f"request {req.request_id}: steps={steps} outside the "
                f"engine's generate capacity [1, {self._generate}]")
        if req.candidates is not None:
            # np.unique sorts AND dedups: duplicate ids would make two
            # "distinct" hypotheses identical, breaking beam uniqueness
            universe = np.unique(np.asarray(
                req.candidates,
                np.int32))  # flamecheck: host-sync-ok(admission-time canonicalization of the caller's host id array)
        else:
            universe = np.arange(self._gen_vocab, dtype=np.int32)
        # top-k seeds k INDEPENDENT greedy beams from the k best first
        # tokens, so k is capped by the universe; beam search may run wider
        # than the universe (hypotheses multiply V-fold per step — step 0
        # seeds min(width, V) beams and beam_step grows toward width)
        if width < 1 or (not beam_mode and width > len(universe)):
            raise ValueError(
                f"request {req.request_id}: width={width} must be in "
                f"[1, |universe|={len(universe)}] for top-k decode")
        if req.slo_tier == "bulk" and self._degrade_level() >= 2:
            # level-2 degradation: bulk-tier generation runs at half beam
            # width and half the steps — a cheaper, shorter answer beats a
            # shed one, and the freed decode slots drain the backlog
            width = max(1, width // 2)
            steps = max(1, steps // 2)
            self._metrics.incr("degrade_gen_shrunk")
        t0 = time.perf_counter()
        dl = self._effective_deadline(req)
        deadline = (req.arrival_t + dl) if dl else None
        hist = np.asarray(
            req.history[None, :self.n_history],
            np.int32)  # flamecheck: host-sync-ok(request arrays arrive as host numpy; dtype canonicalized once at admission)
        key_fp = memo if memo is not None else self._pool_key(req)
        hist_fp = key_fp[1]
        base, path, features_s = self._lookup_or_encode(req, hist, key_fp,
                                                        deadline)
        root_leaves = self._pad_beam_leaves(base)
        t1 = time.perf_counter()
        self._shift_beams_in_flight(width)
        try:
            beams = self._generate_loop(
                req, hist, key_fp, root_leaves, universe, width, steps,
                eos, beam_mode, deadline, G)
        finally:
            self._shift_beams_in_flight(-width)
        # best-first [width, steps] id matrix; -1 pads rows finished early
        order = np.argsort(
            -np.asarray([b.cum for b in beams]),
            kind="stable")  # flamecheck: host-sync-ok(final ranking over host python floats; beam orchestration is host-side by design)
        out = np.full((width, steps), -1, np.int32)
        for r, o in enumerate(order):
            toks = beams[o].tokens
            out[r, :len(toks)] = toks
        t2 = time.perf_counter()
        build_s = (t1 - t0) - features_s
        return out, {"features_s": features_s,
                     "encode_s": build_s if path == "encode" else 0.0,
                     "extend_s": build_s if path == "extend" else 0.0,
                     "pool_hit": 1.0 if path == "hit" else 0.0,
                     "execute_s": t2 - t1}

    def _generate_loop(self, req, hist, memo, root_leaves, universe,
                       width, steps, eos, beam_mode, deadline, G):
        """Run ``steps`` decode rounds; returns the final beam list.

        Each round: fetch every live beam's cache (local / pool / replay),
        submit ALL their vocab-scoring chunks to the ``decode`` family at
        once (under ``pack_tails`` beams from this and other in-flight
        requests bin-pack into shared ragged rows), rank continuations
        host-side (greedy per-beam for top-k, global beam_step for beam
        search), then submit the surviving children's KV appends as one
        coalesced ``append`` round and park the grown caches in the pool."""
        rid = req.request_id
        v = len(universe)
        # ---- step 0: one decode from the shared history root ----
        fut = self.dso.submit((root_leaves,
                               np.full((1,), self._s0, np.int32),
                               universe[None]),
                              v, kind="decode",
                              dedup_token=("g", rid, "root"),
                              deadline=deadline, tier=req.slo_tier)
        probs = np.asarray(
            fut.result(),
            np.float32)[0]  # flamecheck: host-sync-ok(beam ranking is host-side search logic by design)
        self._metrics.incr("decode_steps")
        lp = G.log_softmax(probs.sum(-1))
        order = np.argsort(-lp, kind="stable")[:width]
        beams = [
            _Beam(tokens=(int(universe[o]),), cum=float(lp[o]),
                  finished=(eos is not None and int(universe[o]) == eos))
            for o in order]
        self._note_gen_tokens(len(beams))
        parent_leaves = {i: root_leaves for i in range(len(beams))}
        parent_of = {i: i for i in range(len(beams))}
        for step in range(1, steps + 1):
            # ---- append round: grow every unfinished child's cache ----
            if step < steps:     # the final round's tokens are never scored
                afuts = []
                for i, b in enumerate(beams):
                    if b.finished:
                        continue
                    plv = parent_leaves[parent_of[i]]
                    afuts.append((i, self.dso.submit(
                        (plv,
                         np.full((1,), self._s0 + len(b.tokens) - 1,
                                 np.int32),
                         np.asarray(
                             [[b.tokens[-1]]],
                             np.int32)),  # flamecheck: host-sync-ok(chosen tokens are host python ints; beam orchestration is host-side by design)
                        1, kind="append", deadline=deadline,
                        tier=req.slo_tier)))
                for i, f in afuts:
                    leaves = self._copy_kv_rows(f.result())
                    self._park_beam(req, i, beams[i], leaves, memo[1])
            if step == steps:
                break
            if self._faults is not None:
                # mid-generation eviction pressure: a storm HERE lands in
                # the window between a beam's park and its next-round
                # lookup — the only place an eviction can force a replay
                # (request-start storms almost never catch it)
                dropped = self._faults.pool_storm(self.history_pool)
                if dropped:
                    self._metrics.incr("fault_pool_evictions", dropped)
            # ---- decode round over the live hypotheses ----
            live = [i for i, b in enumerate(beams) if not b.finished]
            if not live:
                # EOS early exit: every hypothesis terminated with decode
                # budget left — the remaining rounds' decode/append
                # dispatches are skipped entirely (step < steps holds
                # here: the final round breaks before this check)
                self._metrics.incr("gen_early_exits")
                break
            leaves_of = {}
            dfuts = []
            for i in live:
                leaves_of[i] = self._beam_leaves(req, hist, memo, beams[i],
                                                 deadline)
                dfuts.append((i, self.dso.submit(
                    (leaves_of[i],
                     np.full((1,), self._s0 + len(beams[i].tokens),
                             np.int32),
                     universe[None]),
                    v, kind="decode",
                    dedup_token=("g", rid, i, len(beams[i].tokens)),
                    deadline=deadline, tier=req.slo_tier)))
            self._metrics.incr("decode_steps")
            step_lp = np.zeros((len(beams), v))
            for i, f in dfuts:
                probs = np.asarray(
                    f.result(),
                    np.float32)[0]  # flamecheck: host-sync-ok(beam ranking is host-side search logic by design)
                step_lp[i] = G.log_softmax(probs.sum(-1))
            if beam_mode:
                cum = np.asarray(
                    [b.cum for b in beams])  # flamecheck: host-sync-ok(beam scores are host python floats; ranking is host-side by design)
                seqs = [b.tokens for b in beams]
                fin = np.asarray(
                    [b.finished for b in beams])  # flamecheck: host-sync-ok(beam flags are host python bools; ranking is host-side by design)
                new_cum, new_seqs, new_fin, parents = G.beam_step(
                    cum, seqs, fin, step_lp, width, eos, universe)
                new_beams = []
                parent_of = {}
                grew_n = 0
                for slot in range(len(new_cum)):
                    p = int(parents[slot])
                    grew_n += len(new_seqs[slot]) > len(seqs[p])
                    parent_of[slot] = p
                    new_beams.append(
                        _Beam(tokens=new_seqs[slot],
                              cum=float(new_cum[slot]),
                              finished=bool(new_fin[slot])))
                self._note_gen_tokens(grew_n)
                # the next append round reads each UNFINISHED child's
                # parent cache: keep those addressable host-side (decode
                # already fetched live parents; a pool-parked one rides
                # its pooled entry via _beam_leaves)
                parent_leaves = {}
                for slot, nb in enumerate(new_beams):
                    p = parent_of[slot]
                    if nb.finished or p in parent_leaves:
                        continue
                    plv = leaves_of.get(p)
                    if plv is None:
                        plv = beams[p].leaves
                    if plv is None:
                        plv = self._beam_leaves(req, hist, memo, beams[p],
                                                deadline)
                    parent_leaves[p] = plv
                beams = new_beams
            else:
                # top-k: each hypothesis follows its own greedy path
                parent_of = {i: i for i in range(len(beams))}
                parent_leaves = leaves_of
                appended = 0
                for i in live:
                    j = int(np.argmax(
                        step_lp[i]))  # flamecheck: host-sync-ok(argmax over a host fp64 ranking buffer; greedy selection is host-side by design)
                    tok = int(universe[j])
                    beams[i] = _Beam(
                        tokens=beams[i].tokens + (tok,),
                        cum=beams[i].cum + float(step_lp[i][j]),
                        finished=(eos is not None and tok == eos))
                    appended += 1
                self._note_gen_tokens(appended)
        return beams

    def _extra_metrics(self):
        st = self.dso.stats()
        # surface the DSO v2 dispatch-economics gauges through ServeMetrics
        # so summary() carries them alongside the request stats.  The
        # padded-fraction gauge covers the CANDIDATE-SCORING kinds only:
        # encode/extend dispatches always run full rows, so folding them
        # in (as the all-kind dso_padded_fraction does) would read near
        # zero on miss-heavy traffic even while cached dispatches are
        # mostly padding — the exact regime the gauge exists to expose
        slots = sum(st.get(f"cand_slots_{k}", 0) for k in ("cached", "full"))
        valid = sum(st.get(f"cand_valid_{k}", 0) for k in ("cached", "full"))
        self._metrics.set_gauge(
            "padded_fraction", 1.0 - valid / slots if slots else 0.0)
        self._metrics.set_gauge("queue_delay_ms", st["queue_delay_ms"])
        if self._generate:
            with self._gen_lock:
                toks = self._gen_tokens
                dt = self._gen_last - self._gen_t0 \
                    if self._gen_t0 is not None else 0.0
            # first-to-last appended-token wall clock; one lone step
            # reports 0 rather than a meaningless infinite rate
            self._metrics.set_gauge(
                "gen_tokens_per_s", toks / dt if dt > 0 else 0.0)
        # satellite observability for the packed-seg kernel->jnp reroute:
        # the ops-module count is process-wide, so fold in deltas only
        reroutes = packed_reroute_count()
        delta = reroutes - self._reroutes_seen
        if delta > 0:
            self._metrics.incr("packed_kernel_reroutes", delta)
        self._reroutes_seen = reroutes
        out = {f"dso_{k}": v for k, v in st.items()}
        out["dso_build_s"] = self.dso.build_time_s
        out.update({f"pda_{k}": v for k, v in
                    dataclasses.asdict(self.features.stats).items()})
        if self.history_pool is not None:
            out.update({f"pool_{k}": v
                        for k, v in self.history_pool.stats().items()})
        if self._faults is not None:
            out.update(self._faults.stats())
        return out

    def _on_degrade(self, level: int):
        # level >= 1: stop waiting for co-riders — flush every coalescing
        # window immediately (tail-packing windows add latency the backlog
        # can no longer afford); reversible when pressure recedes
        self.dso.set_window_override(0.0 if level >= 1 else None)

    def _close(self):
        self.features.shutdown()
        self.dso.shutdown()
        if self.history_pool is not None:
            self.history_pool.release()


@register_engine("implicit")
class ImplicitShapeServingEngine(_SideFeatureMixin, _PipelinedEngine):
    """Table 5 "Default" — plain jit over the full model: every novel
    candidate count M retraces + recompiles in-band (the XLA analogue of
    TensorRT implicit-shape dynamic (re)allocation).  Same pipeline and
    protocol as FlameEngine so the two are A/B-comparable."""

    def __init__(self, bundle: ModelBundle, params, *, n_history: int,
                 feature_mode: str = "off",
                 cache_capacity: int = 50_000, cache_ttl_s: float = 30.0,
                 store: Optional[PDA.RemoteFeatureStore] = None,
                 max_pending: int = 64, n_workers: int = 4,
                 impl: str = "chunked"):
        self.bundle = bundle
        self.params = params
        self.n_history = n_history
        self.impl = impl
        self.store, self.features = _make_features(
            feature_mode, store, cache_capacity, cache_ttl_s)
        self._fn = jax.jit(lambda h, c, s: bundle.prefill(
            params, {"history": h, "candidates": c, "side": s}, impl=impl))
        self.compiles = 0
        self._seen: set = set()
        self._seen_lock = threading.Lock()
        super().__init__(max_pending=max_pending, n_workers=n_workers,
                         name="implicit")

    def _execute(self, req: ServeRequest
                 ):  # flamecheck: host-sync-ok(Table-5 Default baseline: per-request jit + sync is the comparison point, not a defect)
        self._check_request(req)
        t0 = time.perf_counter()
        side = self._side_features(req.history)
        t1 = time.perf_counter()
        with self._seen_lock:
            if req.m not in self._seen:
                self._seen.add(req.m)
                self.compiles += 1
        hist = jnp.asarray(req.history[None, :self.n_history], jnp.int32)
        cand = jnp.asarray(req.candidates[None], jnp.int32)
        out = self._fn(hist, cand, jnp.asarray(side))
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        return np.asarray(out)[0], {"features_s": t1 - t0,
                                    "execute_s": t2 - t1}

    def _extra_metrics(self):
        with self._seen_lock:
            out = {"jit_compiles": self.compiles}
        out.update({f"pda_{k}": v for k, v in
                    dataclasses.asdict(self.features.stats).items()})
        return out

    def _close(self):
        self.features.shutdown()


@register_engine("text")
class TextServingEngine(_PipelinedEngine):
    """Continuous-batching-lite decode serving for text architectures.

    Through the API v2 surface, ``request.history`` is the prompt token-id
    array and ``request.n_tokens`` the generation budget; the batched
    ``generate`` entry point remains for direct callers."""

    def __init__(self, bundle: ModelBundle, params, *, batch: int = 4,
                 max_len: int = 256, max_pending: int = 64, **cache_kw):
        self.bundle = bundle
        self.params = params
        self.kv = KVCacheManager(bundle, batch, max_len, **cache_kw)
        self._decode = jax.jit(
            lambda p, c, b: bundle.decode_step(p, c, b))
        self._gen_lock = threading.Lock()
        # decode state is single-stream: exactly one pipeline worker
        super().__init__(max_pending=max_pending, n_workers=1, name="text")

    def _execute(self, req: ServeRequest
                 ):  # flamecheck: host-sync-ok(decode engine: prompts are host token arrays by contract)
        t0 = time.perf_counter()
        out = self.generate([np.asarray(req.history)],
                            n_tokens=req.n_tokens)[0]
        return out, {"execute_s": time.perf_counter() - t0}

    def generate(self, prompts: List[np.ndarray], n_tokens: int = 16,
                 greedy: bool = True) -> List[np.ndarray]:
        """Serve a batch of prompts (token id arrays) for n_tokens each."""
        assert len(prompts) <= self.kv.batch
        with self._gen_lock:
            plen = max(len(p) for p in prompts)
            padded = np.stack([np.pad(p, (0, plen - len(p)))
                               for p in prompts])
            batch = {"tokens": jnp.asarray(padded, jnp.int32)}
            # prefill all at once (batch-padded)
            caches, _ = self.bundle.cache_init(len(prompts), self.kv.max_len)
            logits, caches = self.bundle.prefill(self.params, batch,
                                                 caches=caches)
            last = jnp.argmax(logits[:, -1], axis=-1)
            outs = [[int(t)] for t in last]
            cur = plen
            for _ in range(n_tokens - 1):
                step = {"tokens": last[:, None].astype(jnp.int32),
                        "cur_index": jnp.int32(cur)}
                logits, caches = self._decode(self.params, caches, step)
                last = jnp.argmax(logits[:, -1], axis=-1)
                for i, t in enumerate(last):
                    outs[i].append(int(t))
                cur += 1
            return [np.array(o) for o in
                    outs]  # flamecheck: host-sync-ok(autoregressive decode emits host token ids per step by design)
