"""Serving engines: the full FLAME pipeline and a text-decoder engine.

FlameEngine — the paper's system end to end:

  request --> PDA (feature query w/ cache; packed transfer)
          --> DSO (descending-bucket split onto AOT executors)
          --> FKE/model (SUMI-masked Climber forward)
          --> per-candidate multi-task scores

TextServingEngine — prefill+decode serving for the decode-based assigned
architectures (used by examples/ and tests; the pod-scale path is exercised
by the dry-run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dso as DSO
from repro.core import pda as PDA
from repro.core.climber import N_SIDE_FEATURES, climber_forward
from repro.models.model import ModelBundle
from repro.serving.kv_cache import KVCacheManager


@dataclasses.dataclass
class ServeMetrics:
    requests: int = 0
    items: int = 0
    first_t: float = 0.0
    last_t: float = 0.0
    latencies: list = dataclasses.field(default_factory=list)

    def record(self, n_items: int, latency_s: float):
        now = time.perf_counter()
        if self.requests == 0:
            self.first_t = now - latency_s
        self.last_t = now
        self.requests += 1
        self.items += n_items
        self.latencies.append(latency_s)

    def summary(self) -> Dict[str, float]:
        lat = np.array(self.latencies) if self.latencies else np.zeros(1)
        wall = max(self.last_t - self.first_t, 1e-9)
        return {
            "requests": self.requests,
            "throughput_items_per_s": self.items / wall,
            "mean_latency_ms": float(lat.mean() * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
        }


class FlameEngine:
    """PDA -> DSO -> Climber, per the paper's Fig 1/Fig 4."""

    def __init__(self, bundle: ModelBundle, params, *, n_history: int,
                 buckets: Sequence[int] = (512, 256, 128),
                 n_streams: int = 2,
                 feature_mode: str = "sync",
                 cache_capacity: int = 50_000, cache_ttl_s: float = 30.0,
                 store: Optional[PDA.RemoteFeatureStore] = None,
                 packed: bool = True):
        self.bundle = bundle
        self.params = params
        self.cfg = bundle.cfg
        self.n_history = n_history
        self.packed = packed

        # ---- PDA ----
        self.store = store or PDA.RemoteFeatureStore(
            feature_dim=N_SIDE_FEATURES)
        cache = None if feature_mode == "off" else PDA.BucketedLRUCache(
            cache_capacity, cache_ttl_s)
        self.features = PDA.FeatureQueryEngine(self.store, cache,
                                               mode=feature_mode)

        # ---- DSO over AOT executors (FKE inside) ----
        def build_fn(bucket: int):
            def fn(history, candidates, side):
                batch = {"history": history, "candidates": candidates,
                         "side": side}
                return bundle.prefill(self.params, batch)
            shapes = (
                jax.ShapeDtypeStruct((1, n_history), jnp.int32),
                jax.ShapeDtypeStruct((1, bucket), jnp.int32),
                jax.ShapeDtypeStruct((1, N_SIDE_FEATURES), jnp.float32),
            )
            return jax.jit(fn).lower(*shapes).compile()

        self.pool = DSO.ExecutorPool(build_fn, buckets, n_streams=n_streams)
        self.dso = DSO.DynamicStreamOrchestrator(
            self.pool, self._pad_slice, self._gather)
        self.metrics = ServeMetrics()

    # ---- request plumbing ----
    def _side_features(self, history: np.ndarray) -> np.ndarray:
        """PDA in action: fetch item features for the history, aggregate into
        the request's side-feature vector (user-profile style)."""
        feats = self.features.query([int(i) for i in history])
        got = [v for v in feats.values() if v is not None]
        if not got:
            return np.zeros((1, N_SIDE_FEATURES), np.float32)
        return np.mean(got, axis=0, keepdims=True).astype(np.float32)

    def _pad_slice(self, request, chunk: DSO.Chunk):
        history, candidates, side = request
        sl = candidates[:, chunk.start:chunk.start + chunk.valid]
        if chunk.valid < chunk.bucket:
            sl = jnp.pad(sl, ((0, 0), (0, chunk.bucket - chunk.valid)))
        return history, sl, side

    def _gather(self, results, chunks: List[DSO.Chunk], m: int):
        parts = [np.asarray(r[:, :c.valid]) for r, c in zip(results, chunks)]
        return np.concatenate(parts, axis=1)

    def serve(self, history: np.ndarray, candidates: np.ndarray):
        """One SUMI request: history [n], candidates [M] -> scores [M, tasks]."""
        t0 = time.perf_counter()
        side = self._side_features(history)
        if self.packed:
            side_dev, = PDA.packed_transfer([side])
        else:
            side_dev, = PDA.unpacked_transfer([side])
        hist = jnp.asarray(history[None, :self.n_history], jnp.int32)
        cand = jnp.asarray(candidates[None], jnp.int32)
        out = self.dso.score((hist, cand, side_dev), candidates.shape[0])
        dt = time.perf_counter() - t0
        self.metrics.record(candidates.shape[0], dt)
        return out[0]

    def shutdown(self):
        self.features.shutdown()
        self.dso.shutdown()


class TextServingEngine:
    """Continuous-batching-lite decode serving for text architectures."""

    def __init__(self, bundle: ModelBundle, params, *, batch: int = 4,
                 max_len: int = 256, **cache_kw):
        self.bundle = bundle
        self.params = params
        self.kv = KVCacheManager(bundle, batch, max_len, **cache_kw)
        self._decode = jax.jit(
            lambda p, c, b: bundle.decode_step(p, c, b))

    def generate(self, prompts: List[np.ndarray], n_tokens: int = 16,
                 greedy: bool = True) -> List[np.ndarray]:
        """Serve a batch of prompts (token id arrays) for n_tokens each."""
        assert len(prompts) <= self.kv.batch
        plen = max(len(p) for p in prompts)
        padded = np.stack([np.pad(p, (0, plen - len(p))) for p in prompts])
        batch = {"tokens": jnp.asarray(padded, jnp.int32)}
        # prefill all at once (batch-padded)
        caches, _ = self.bundle.cache_init(len(prompts), self.kv.max_len)
        logits, caches = self.bundle.prefill(self.params, batch, caches=caches)
        last = jnp.argmax(logits[:, -1], axis=-1)
        outs = [[int(t)] for t in last]
        cur = plen
        for _ in range(n_tokens - 1):
            step = {"tokens": last[:, None].astype(jnp.int32),
                    "cur_index": jnp.int32(cur)}
            logits, caches = self._decode(self.params, caches, step)
            last = jnp.argmax(logits[:, -1], axis=-1)
            for i, t in enumerate(last):
                outs[i].append(int(t))
            cur += 1
        return [np.array(o) for o in outs]
