"""Batched KV-cache slot manager for text-decoder serving.

Maintains one batched cache pytree (from bundle.cache_init) plus per-slot
lengths; requests are assigned to free slots, prefilled, and decoded in
lockstep (continuous-batching-lite).  Small-scale CPU serving substrate for
the decode-based architectures; the dry-run exercises the pod-scale shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Slot:
    active: bool = False
    length: int = 0
    request_id: int = -1
    tokens: Optional[list] = None


class KVCacheManager:
    def __init__(self, bundle, batch: int, max_len: int, **kw):
        self.bundle = bundle
        self.batch = batch
        self.max_len = max_len
        self.caches, self.cache_specs = bundle.cache_init(batch, max_len, **kw)
        self.slots = [Slot() for _ in range(batch)]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def assign(self, request_id: int, prompt_len: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free KV-cache slots")
        i = free[0]
        self.slots[i] = Slot(True, prompt_len, request_id, [])
        return i

    def release(self, slot: int):
        self.slots[slot] = Slot()

    def write_prefill(self, slot: int, caches_one):
        """Insert a single-sequence cache (batch=1, stacked-layer axis 0) into
        batch position ``slot`` of the pooled cache."""
        self.caches = jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), slot, axis=1),
            self.caches, caches_one)

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)
