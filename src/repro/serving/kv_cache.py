"""KV state managers for serving.

Two families live here:

``KVCacheManager``   batched decode-cache slot manager for the text
                     architectures (continuous-batching-lite): one pooled
                     cache pytree, per-slot lengths, prefill-insert/release.

``HistoryKVPool``    byte-budgeted, optionally quantized, two-tier LRU pool
                     of cached *history-side* SUMI K/V for GR serving — the
                     PDA v2 realization of the MTServe / "One Pool, Two
                     Caches" hierarchical-cache idea.  The SUMI mask makes
                     the history prefix self-contained, so its per-layer K/V
                     depend only on the user history; FlameEngine encodes it
                     once, parks it here, and repeat/session-re-rank traffic
                     runs candidate-only executors against the pooled entry.

Pool contract (PDA v2)
----------------------
*Keys and staleness.*  Entries are keyed by a stable user identity (or a
content hash of the history) and carry a **fingerprint** — a hash of the
full upstream history array.  A key hit whose fingerprint differs means the
user's history advanced since the encode: the entry is *stale* and must not
be scored against.  ``lookup`` drops it but can hand the dropped entry back
as an **extension basis** (K/V + the history window it encoded) so the
engine can re-encode only the changed suffix instead of the whole window.

*Capacity.*  ``slots`` bounds the entry count, ``budget_bytes`` bounds the
primary tier's stored bytes (entries vary in size with ``n_history``; the
paper-scale entry is ~6.5 MB/user, so bytes — not counts — are the real HBM
constraint).  Eviction is strictly LRU; both limits may be combined.  An
entry that alone exceeds ``budget_bytes`` is *rejected* (counted in
``rejects``) rather than admitted, so ``bytes_used <= budget_bytes`` is a
hard invariant.

*Placement.*  ``placement="device"`` keeps stored leaves as JAX device
arrays (HBM-resident next to the weights — dispatches consume them without
a host round-trip); ``placement="host"`` stores host numpy (the PR 2
behavior, kept for A/B benchmarking).  ``spill_bytes > 0`` enables a
host-RAM second tier: primary-tier evictions demote there instead of
dropping, and a later hit promotes back (counted as ``spill_hits``) —
"One Pool, Two Caches" within one process.

*Quantization.*  ``dtype`` selects the stored precision: ``"native"``
(compute dtype), ``"bf16"``, or ``"int8"`` with a per-(layer, head)
absmax scale.  Dequantization happens at lookup (on device under device
placement), so executor input signatures never change; int8 roughly
quadruples users-per-budget vs f32 at a bounded score drift (asserted in
tests/test_pda_v2.py, measured in BENCH_serving.json).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
from typing import Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Slot:
    active: bool = False
    length: int = 0
    request_id: int = -1
    tokens: Optional[list] = None


class KVCacheManager:
    def __init__(self, bundle, batch: int, max_len: int, **kw):
        self.bundle = bundle
        self.batch = batch
        self.max_len = max_len
        self.caches, self.cache_specs = bundle.cache_init(batch, max_len, **kw)
        self.slots = [Slot() for _ in range(batch)]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def assign(self, request_id: int, prompt_len: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free KV-cache slots")
        i = free[0]
        self.slots[i] = Slot(True, prompt_len, request_id, [])
        return i

    def release(self, slot: int):
        self.slots[slot] = Slot()

    def write_prefill(self, slot: int, caches_one):
        """Insert a single-sequence cache (batch=1, stacked-layer axis 0) into
        batch position ``slot`` of the pooled cache."""
        self.caches = jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), slot, axis=1),
            self.caches, caches_one)

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)


# ---------------------------------------------------------------------------
# quantization hooks (shared by pool entries; per-(layer, head) scaling)
# ---------------------------------------------------------------------------

POOL_DTYPES = ("native", "bf16", "int8")


@dataclasses.dataclass
class _QuantLeaf:
    """One quantized KV leaf: values + (for int8) per-(layer, head) scale.

    KV leaves are [B, L, S, Hkv, D]; the int8 scale reduces over the
    position and feature axes (S, D) and keeps (B, L, 1, Hkv, 1), so every
    attention head of every layer owns its own dynamic range.  ``scale is
    None`` marks a plain bf16 cast.  ``dtype`` is the original compute
    dtype to dequantize back to (executor input signatures are fixed, so a
    natively-f32 leaf must come back f32 — a natively-bf16 leaf stored
    under ``dtype="bf16"`` round-trips losslessly)."""

    q: object          # int8 (or bf16) values, original shape
    scale: object      # f32 absmax scale, reduced shape; None for bf16
    dtype: object      # original jnp dtype to dequantize back to


def _scale_axes(ndim: int) -> Tuple[int, ...]:
    if ndim >= 4:
        return (ndim - 3, ndim - 1)          # (S, D) of [..., S, Hkv, D]
    return tuple(range(ndim))                # fallback: one global scale


def quantize_leaf(a, dtype: str):
    """Quantize one KV leaf to the pool's stored precision.

    Returns the stored representation: the array itself for ``native``, a
    bf16 cast for ``bf16``, or a :class:`_QuantLeaf` for ``int8``."""
    if dtype == "native":
        return a
    a = jnp.asarray(a)
    if dtype == "bf16":
        return _QuantLeaf(a.astype(jnp.bfloat16), None, a.dtype)
    if dtype == "int8":
        af = a.astype(jnp.float32)
        scale = jnp.maximum(
            jnp.max(jnp.abs(af), axis=_scale_axes(a.ndim), keepdims=True),
            1e-8)
        q = jnp.clip(jnp.round(af / scale * 127.0), -127, 127).astype(jnp.int8)
        return _QuantLeaf(q, scale, a.dtype)
    raise ValueError(f"pool dtype must be one of {POOL_DTYPES}, got {dtype!r}")


def dequantize_leaf(stored):
    """Invert :func:`quantize_leaf` back to the original dtype.  Native
    (unwrapped) leaves pass through untouched (no host/device migration);
    host-resident quantized leaves dequantize in numpy (cheap elementwise,
    no JAX dispatch), device-resident ones on device."""
    if isinstance(stored, _QuantLeaf):
        xp = np if isinstance(stored.q, np.ndarray) else jnp
        if stored.scale is None:               # bf16 cast
            return xp.asarray(stored.q).astype(stored.dtype)
        return (xp.asarray(stored.q, np.float32)
                * (xp.asarray(stored.scale) / 127.0)).astype(stored.dtype)
    return stored


def quantize_kv(kv, dtype: str):
    """Quantize a KV pytree; returns (payload pytree, stored nbytes)."""
    payload = jax.tree.map(lambda a: quantize_leaf(a, dtype), kv)
    return payload, payload_bytes(payload)


def quantize_kv_graph(kv, dtype: str):
    """In-graph pool quantization for fused encode/append epilogues
    (FKE v2): emits the :func:`raw_kv_view` structure directly —
    ``(int8 values, f32 scale)`` tuples, ``(bf16 values, None)`` casts,
    or plain native leaves — so a jitted executor's OUTPUT already *is*
    the pool's stored representation and ``put(prequantized=True)`` can
    admit it without a separate quantize pass (and without ever
    materializing the fp KV on the host).  Op-for-op the same jnp
    computation as :func:`quantize_leaf`, so the emitted codes/scales are
    bitwise identical to a post-hoc :func:`quantize_kv` of the same
    values (asserted in tests/test_decode_serving.py)."""
    if dtype == "native":
        return kv

    def one(a):
        a = jnp.asarray(a)
        if dtype == "bf16":
            return (a.astype(jnp.bfloat16), None)
        if dtype == "int8":
            af = a.astype(jnp.float32)
            scale = jnp.maximum(
                jnp.max(jnp.abs(af), axis=_scale_axes(a.ndim),
                        keepdims=True), 1e-8)
            q = jnp.clip(jnp.round(af / scale * 127.0),
                         -127, 127).astype(jnp.int8)
            return (q, scale)
        raise ValueError(
            f"pool dtype must be one of {POOL_DTYPES}, got {dtype!r}")
    return jax.tree.map(one, kv)


def _shard_elems(shape, shard_spec) -> int:
    """Element count ONE shard holds of an array with this global shape.
    ``shard_spec`` maps a shape to a NamedSharding (or None = replicated);
    the per-shard shape comes from the sharding itself, so the accounting
    follows whatever layout (head-split, sequence-split, replicated) the
    divisibility fallback actually resolved — analytically, which keeps it
    true on CPU hosts where forced host "devices" share one allocator."""
    shape = tuple(int(s) for s in shape)
    if shard_spec is not None:
        sh = shard_spec(shape)
        if sh is not None:
            return math.prod(sh.shard_shape(shape))
    return math.prod(shape)


def quantized_nbytes(
        kv, dtype: str, shard_spec=None
) -> int:
    """Stored bytes :func:`quantize_kv` would produce, WITHOUT quantizing —
    shape/dtype arithmetic only, so admission prechecks are free.  With
    ``shard_spec`` (shape -> NamedSharding), the bytes one shard holds."""
    total = 0
    for a in jax.tree.leaves(kv):
        n = _shard_elems(a.shape, shard_spec)
        if dtype == "native":
            total += n * jnp.dtype(a.dtype).itemsize
        elif dtype == "bf16":
            total += n * 2
        elif dtype == "int8":
            scale_shape = tuple(1 if i in _scale_axes(a.ndim) else s
                                for i, s in enumerate(a.shape))
            total += n + _shard_elems(scale_shape, shard_spec) * 4
        else:
            raise ValueError(
                f"pool dtype must be one of {POOL_DTYPES}, got {dtype!r}")
    return total


def dequantize_kv(payload):
    """Dequantize a payload pytree back to original-dtype leaves."""
    return jax.tree.map(
        dequantize_leaf, payload,
        is_leaf=lambda x: isinstance(x, _QuantLeaf))


def raw_kv_view(payload):
    """Zero-copy *raw* view of a stored payload for quantization-aware
    executors (the FKE path): every quantized leaf becomes a ``(values,
    scale)`` tuple over THE stored arrays (scale ``None`` for a plain bf16
    cast — dropped by ``jax.tree.flatten``), native leaves pass through.
    The executor dequantizes tiles in-kernel, so a lookup never
    materializes the dequantized entry on the host.  Callers must treat
    the arrays as immutable — they alias pool storage."""
    return jax.tree.map(
        lambda s: (s.q, s.scale) if isinstance(s, _QuantLeaf) else s,
        payload, is_leaf=lambda x: isinstance(x, _QuantLeaf))


def raw_kv_specs(kv_specs, dtype: str):
    """ShapeDtypeStruct pytree matching :func:`raw_kv_view` output for a
    pool storing ``dtype`` — what a quantization-aware AOT executor is
    compiled against (shape/dtype arithmetic only)."""
    def one(spec):
        if dtype == "native":
            return spec
        if dtype == "bf16":
            return (jax.ShapeDtypeStruct(spec.shape, jnp.bfloat16), None)
        if dtype == "int8":
            scale_shape = tuple(1 if i in _scale_axes(len(spec.shape)) else s
                                for i, s in enumerate(spec.shape))
            return (jax.ShapeDtypeStruct(spec.shape, jnp.int8),
                    jax.ShapeDtypeStruct(scale_shape, jnp.float32))
        raise ValueError(f"pool dtype must be one of {POOL_DTYPES}, "
                         f"got {dtype!r}")
    return jax.tree.map(one, kv_specs)


def _stored_arrays(payload):
    out = []
    for leaf in jax.tree.leaves(
            payload, is_leaf=lambda x: isinstance(x, _QuantLeaf)):
        if isinstance(leaf, _QuantLeaf):
            out.append(leaf.q)
            if leaf.scale is not None:
                out.append(leaf.scale)
        else:
            out.append(leaf)
    return out

def payload_bytes(
        payload
) -> int:  # flamecheck: host-sync-ok(shape arithmetic over .shape tuples and Python ints; no device data is read)
    """Stored bytes of a (possibly quantized) payload pytree."""
    return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
               for a in _stored_arrays(payload))


def _device_move(a):
    """Pin one array in the serving accelerator's memory.  On the CPU
    backend host and device memory coincide, so plain numpy is the faster
    representation of the same placement (no per-op dispatch overhead);
    with a real accelerator attached this is the HBM residency that spares
    the per-dispatch H2D copy."""
    if jax.default_backend() == "cpu":
        return np.asarray(a)  # flamecheck: host-sync-ok(CPU tier: source is already host-resident, asarray is a no-op view — host and device memory coincide)
    return jnp.asarray(a)


def _place(payload, placement: str):
    """Move every stored array to the tier's memory space."""
    move = _device_move if placement == "device" else np.asarray
    return jax.tree.map(
        lambda s: _QuantLeaf(
            move(s.q), None if s.scale is None else move(s.scale), s.dtype)
        if isinstance(s, _QuantLeaf) else move(s),
        payload, is_leaf=lambda x: isinstance(x, _QuantLeaf))


# ---------------------------------------------------------------------------
# history-KV pool (GR serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)           # identity semantics: tier members
class _PoolEntry:
    fingerprint: Hashable          # content hash of the full history array
    payload: object                # stored (possibly quantized) KV pytree
    nbytes: int                    # stored bytes (quantized size)
    hist_window: Optional[np.ndarray]   # model-window ids at encode time
    refreshes: int = 0             # incremental extensions since full encode
    shard_nbytes: int = 0          # bytes ONE model shard holds (== nbytes
                                   # for mesh-less pools / replicated leaves)


@dataclasses.dataclass
class StaleBasis:
    """What ``lookup`` hands back for a dropped stale entry so the engine
    can extend the cached prefix instead of re-encoding from scratch."""

    kv: object                     # K/V extension basis (dequantized, or a
                                   # raw stored view under ``raw_basis``)
    hist_window: Optional[np.ndarray]  # window the basis encoded
    refreshes: int = 0             # extensions already layered on this basis


class HistoryKVPool:
    """Byte-budgeted two-tier LRU pool of encoded history K/V (PDA v2).

    See the module docstring for the full contract.  Quick API tour:

    ``lookup(key, fingerprint, want_basis=..., raw=...)``
        one counted probe: returns ``(kv, status, basis)`` with status
        ``"hit"`` (kv is the dequantized entry, recency refreshed),
        ``"stale"`` (entry dropped; ``basis`` carries its K/V + encoded
        window + extension refresh count when ``want_basis``) or
        ``"miss"``.  Stale and miss both count as misses, so hit-rate
        math is unchanged from v1.  ``raw=True`` (the FKE executors)
        skips dequantization: hits return :func:`raw_kv_view` of the
        stored payload — (values, scale) over the stored arrays, no copy.
    ``get(key, fingerprint)``
        v1 sugar over ``lookup``: the kv on hit, else None.
    ``peek(key, fingerprint)``
        uncounted re-check for single-flight leader election.
    ``put(key, fingerprint, kv, hist_window=None, refreshes=0)``
        quantize + admit, then evict LRU-first until both the ``slots`` and
        ``budget_bytes`` limits hold (evictions demote to the spill tier
        when enabled); oversized entries are rejected, never admitted.
        ``refreshes`` counts incremental extensions layered on the entry
        since its last full encode (the engine's drift cap).
    ``count_extension()`` / ``count_refresh_reencode()``
        engine callbacks: one stale hit was served by incremental suffix
        extension (``extensions`` stat) / the extension-drift cap forced a
        full re-encode instead (``refresh_reencodes`` stat).

    All methods are thread-safe — pipeline workers hit the pool
    concurrently."""

    def __init__(self, slots: Optional[int] = 256, *,
                 budget_bytes: Optional[int] = None,
                 dtype: str = "native", placement: str = "device",
                 spill_bytes: int = 0, mesh=None, shard_spec=None):
        if slots is None and budget_bytes is None:
            raise ValueError("pool needs slots and/or budget_bytes")
        if slots is not None and slots < 1:
            raise ValueError(f"pool needs >= 1 slot, got {slots}")
        if budget_bytes is not None and budget_bytes < 1:
            raise ValueError(f"budget_bytes must be >= 1, got {budget_bytes}")
        if dtype not in POOL_DTYPES:
            raise ValueError(f"dtype must be one of {POOL_DTYPES}, got {dtype!r}")
        if placement not in ("device", "host"):
            raise ValueError(f"placement must be device|host, got {placement!r}")
        self.slots = slots
        self.budget_bytes = budget_bytes
        self.dtype = dtype
        self.placement = placement
        self.spill_budget = int(spill_bytes)
        # mesh-sharded serving: ``shard_spec`` (shape -> NamedSharding, or
        # None for replicated) commits device-placed leaves to the layout
        # the sharded executors consume — pooled KV lives where its heads
        # live — and drives the analytic per-shard byte accounting.  The
        # byte budget is the pool's TOTAL across shards; each model shard
        # gets an even share of it.
        self.mesh = mesh
        self._shard_spec = shard_spec
        self._model_ways = 1
        if mesh is not None and "model" in mesh.axis_names:
            self._model_ways = int(mesh.shape["model"])
        self._shard_budget = None
        if budget_bytes is not None and self._model_ways > 1:
            self._shard_budget = budget_bytes // self._model_ways
        self._entries: "collections.OrderedDict[Hashable, _PoolEntry]" = \
            collections.OrderedDict()
        self._spill: "collections.OrderedDict[Hashable, _PoolEntry]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.evictions = 0
        self.rejects = 0
        self.extensions = 0
        self.refresh_reencodes = 0
        self.spill_hits = 0
        self.bytes_used = 0
        self.spill_bytes_used = 0
        self.shard_bytes_used = 0

    @staticmethod
    def entry_bytes(kv) -> int:
        """Unquantized (compute-dtype) bytes of a KV pytree."""
        return payload_bytes(kv)

    # ---- placement (mesh-aware) ----
    def _move(self, a):
        """Shard-aware device placement of one stored array: with a mesh,
        commit it to the executor-facing NamedSharding layout (heads on the
        model axis, pooled-user rows replicated) so the hot path never
        reshards it.  On the CPU backend forced host "devices" share one
        allocator and AOT executables auto-place uncommitted host arrays,
        so plain numpy stays the faster representation of the same
        placement (and keeps the bitwise single- vs multi-device parity
        path committed-array free)."""
        if self._shard_spec is not None and jax.default_backend() != "cpu":
            sh = self._shard_spec(np.shape(a))
            if sh is not None:
                return jax.device_put(a, sh)  # flamecheck: host-sync-ok(async H2D publish committing pool KV to the executors' NamedSharding layout, not a device->host sync)
        return _device_move(a)

    def _place_stored(self, payload, placement: str):
        """Tier placement honoring the pool's mesh layout for the device
        tier; host-tier moves fall through to the plain numpy path."""
        if placement == "device" and self._shard_spec is not None:
            return jax.tree.map(
                lambda s: _QuantLeaf(
                    self._move(s.q),
                    None if s.scale is None else self._move(s.scale),
                    s.dtype)
                if isinstance(s, _QuantLeaf) else self._move(s),
                payload, is_leaf=lambda x: isinstance(x, _QuantLeaf))
        return _place(payload, placement)

    # ---- lookup side ----
    def _load(self, e: _PoolEntry, raw: bool = False):
        if raw:
            # quantization-aware executor path: hand back the stored
            # arrays themselves ((values, scale) tuples for quantized
            # leaves) — no dequantization, no copy
            return raw_kv_view(e.payload)
        kv = dequantize_kv(e.payload)
        if self.placement == "host":
            kv = jax.tree.map(
                np.asarray, kv)  # flamecheck: host-sync-ok(host-placement pools hand out host arrays by contract)
        return kv

    def lookup(self, key: Hashable, fingerprint: Hashable, *,
               want_basis: bool = False, raw: bool = False,
               raw_basis: bool = False):
        """One counted probe; see the class docstring.  Checks the primary
        tier, then the spill tier (promoting on a spill hit).  Counter
        bookkeeping happens under the lock; dequantization runs after
        releasing it (payloads are immutable once stored), so concurrent
        workers never serialize on the dequant math.  ``raw_basis=True``
        hands a dropped stale entry back as its :func:`raw_kv_view` —
        the quantized-extend-basis path: extend executors compiled
        against raw pool specs dequantize in-graph, so the host never
        pays the dequant (or ships the dequantized bytes)."""
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                if e.fingerprint == fingerprint:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    status = "hit"
                else:
                    del self._entries[key]      # stale: history advanced
                    self.bytes_used -= e.nbytes
                    self.shard_bytes_used -= e.shard_nbytes
                    self.stale += 1
                    self.misses += 1
                    status = "stale"
            else:
                e = self._spill.pop(key, None)
                if e is not None:
                    self.spill_bytes_used -= e.nbytes
                    if e.fingerprint == fingerprint:
                        self.hits += 1
                        self.spill_hits += 1
                        status = "promote"
                    else:
                        self.stale += 1
                        self.misses += 1
                        status = "stale"
                else:
                    self.misses += 1
                    return None, "miss", None
        if status == "promote":
            # re-place toward the primary tier OUTSIDE the lock (a
            # paper-scale promotion is a multi-MB H2D copy), then admit.
            # While in flight the entry sits in neither tier; a concurrent
            # same-key miss may encode and put() meanwhile (promotions are
            # not single-flighted), so only admit if the key is still
            # absent — the racing entry is at least as fresh, and this
            # request is still correctly served from the promoted copy.
            e.payload = self._place_stored(e.payload, self.placement)
            demoted: List[_PoolEntry] = []
            with self._lock:
                if key not in self._entries:
                    demoted = self._admit(key, e)
            self._finish_demotions(demoted)
            return self._load(e, raw), "hit", None
        if status == "hit":
            return self._load(e, raw), "hit", None
        basis = StaleBasis(self._load(e, raw_basis), e.hist_window,
                           e.refreshes) if want_basis else None
        return None, "stale", basis

    def get(self, key: Hashable, fingerprint: Hashable):
        """v1 surface: the cached pytree on a fresh hit, else None."""
        kv, _, _ = self.lookup(key, fingerprint)
        return kv

    def contains(self, key: Hashable, fingerprint: Hashable) -> bool:
        """Uncounted O(1) existence probe (either tier, no recency touch,
        no dequantization) — the engine's admit-time prefetch short-circuit
        only needs to know whether a fresh entry exists."""
        with self._lock:
            e = self._entries.get(key) or self._spill.get(key)
            return e is not None and e.fingerprint == fingerprint

    def peek(self, key: Hashable, fingerprint: Hashable, *,
             raw: bool = False):
        """Like ``get`` but without touching hit/miss/stale counters (and
        without dropping stale entries) — used by the engine's single-flight
        leader election to re-check the pool after the initial counted miss,
        so each request still counts exactly one lookup."""
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e.fingerprint == fingerprint:
                self._entries.move_to_end(key)
            else:
                e = self._spill.get(key)
                if e is None or e.fingerprint != fingerprint:
                    return None
        return self._load(e, raw)

    # ---- admission side ----
    def _admit(self, key: Hashable, entry: _PoolEntry
               ) -> List[_PoolEntry]:  # flamecheck: locked-by-caller(self._lock)
        """Insert into the primary tier and evict until limits hold.
        Caller holds the lock.  Returns the entries demoted to the spill
        tier — their payloads still sit in the primary tier's memory space;
        the caller moves them host-side AFTER releasing the lock (a
        paper-scale demotion is a multi-MB D2H copy, and lookups must not
        serialize behind it) via :meth:`_finish_demotions`."""
        demoted: List[_PoolEntry] = []
        old = self._entries.pop(key, None)
        if old is not None:                 # replace, don't leak its bytes
            self.bytes_used -= old.nbytes
            self.shard_bytes_used -= old.shard_nbytes
        self._entries[key] = entry
        self.bytes_used += entry.nbytes
        self.shard_bytes_used += entry.shard_nbytes
        while (self.slots is not None and len(self._entries) > self.slots) \
                or (self.budget_bytes is not None
                    and self.bytes_used > self.budget_bytes) \
                or (self._shard_budget is not None
                    and self.shard_bytes_used > self._shard_budget):
            k, ev = self._entries.popitem(last=False)   # LRU end
            self.bytes_used -= ev.nbytes
            self.shard_bytes_used -= ev.shard_nbytes
            self.evictions += 1
            if self.spill_budget > 0:
                stale_sp = self._spill.pop(k, None)   # defensive: keep the
                if stale_sp is not None:              # byte accounting true
                    self.spill_bytes_used -= stale_sp.nbytes
                self._spill[k] = ev
                self.spill_bytes_used += ev.nbytes
                demoted.append(ev)
        while self.spill_bytes_used > self.spill_budget and self._spill:
            _, ev = self._spill.popitem(last=False)
            self.spill_bytes_used -= ev.nbytes
            if ev in demoted:
                demoted.remove(ev)          # evicted again before placement
        return demoted

    def _finish_demotions(self, demoted: List[_PoolEntry]):
        """Host-place payloads of freshly demoted entries, outside the lock.
        The conversion is only committed if the entry still sits in the
        spill tier — a concurrent promotion (which re-places the payload
        toward the primary tier) wins the race either way, since dispatch
        consumes host and device arrays alike."""
        for ev in demoted:
            host_payload = _place(ev.payload, "host")
            with self._lock:
                if any(e is ev for e in self._spill.values()):
                    ev.payload = host_payload

    def put(self, key: Hashable, fingerprint: Hashable, kv,
            hist_window: Optional[np.ndarray] = None,
            refreshes: int = 0, *, prequantized: bool = False,
            compute_dtype=None) -> bool:
        """Quantize + admit; returns False when the entry was rejected for
        exceeding ``budget_bytes`` on its own.  ``refreshes`` records how
        many incremental extensions are layered on this entry since its
        last full encode (the engine's extension-drift cap reads it back
        through :class:`StaleBasis`).

        ``prequantized=True`` (FKE v2 in-epilogue quantization): ``kv``
        already IS the stored representation — the :func:`raw_kv_view`
        structure a fused encode/append epilogue emits
        (:func:`quantize_kv_graph`), with ``(values, scale)`` tuples as
        quantized leaves — and is wrapped into pool entries with no
        quantize pass.  ``compute_dtype`` (default f32) is what
        dequantizing lookups hand back."""
        payload = None
        if prequantized:
            cdt = jnp.dtype(compute_dtype or jnp.float32)
            payload = jax.tree.map(
                lambda x: _QuantLeaf(x[0], x[1], cdt)
                if isinstance(x, tuple) else x,
                kv, is_leaf=lambda x: isinstance(x, tuple))
            nbytes = payload_bytes(payload)
            shard_nbytes = nbytes if self._shard_spec is None else sum(
                _shard_elems(a.shape, self._shard_spec)
                * jnp.dtype(a.dtype).itemsize
                for a in _stored_arrays(payload))
        else:
            # size precheck BEFORE quantizing/placing: a rejected entry
            # must not pay the (multi-MB at paper scale) quantize +
            # transfer cost.  The per-shard share is prechecked too — an
            # entry whose replicated leaves alone exceed one shard's
            # budget slice can never be held.  (Prequantized payloads
            # above skip the quantize pass entirely, so their precheck is
            # plain shape arithmetic over the stored arrays.)
            nbytes = quantized_nbytes(kv, self.dtype)
            shard_nbytes = nbytes if self._shard_spec is None else \
                quantized_nbytes(kv, self.dtype, shard_spec=self._shard_spec)
        if (self.budget_bytes is not None and nbytes > self.budget_bytes) \
                or (self._shard_budget is not None
                    and shard_nbytes > self._shard_budget):
            with self._lock:
                self.rejects += 1
            return False
        if payload is None:
            payload, nbytes = quantize_kv(kv, self.dtype)
        payload = self._place_stored(payload, self.placement)
        if hist_window is not None:
            hist_window = np.array(
                hist_window)  # flamecheck: host-sync-ok(defensive copy of the caller-owned host id window)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_used -= old.nbytes
                self.shard_bytes_used -= old.shard_nbytes
            sp = self._spill.pop(key, None)
            if sp is not None:
                self.spill_bytes_used -= sp.nbytes
            demoted = self._admit(key, _PoolEntry(fingerprint, payload,
                                                  nbytes, hist_window,
                                                  refreshes, shard_nbytes))
        self._finish_demotions(demoted)
        return True

    def count_extension(self):
        with self._lock:
            self.extensions += 1

    def count_refresh_reencode(self):
        """Engine callback: a stale hit had an extendable basis, but the
        extension-drift cap (``--extend-refresh-limit``) forced a full
        re-encode instead."""
        with self._lock:
            self.refresh_reencodes += 1

    # ---- introspection / lifecycle ----
    def keys(self) -> List[Hashable]:
        """Primary-tier keys, LRU -> MRU order (for tests/introspection)."""
        with self._lock:
            return list(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def drop(self, key: Hashable) -> bool:
        """Force-evict one key from BOTH tiers (fault injection / admin
        invalidation — ``serving.faults`` eviction storms drive this).
        Returns True when an entry was actually dropped; counted in
        ``evictions`` so storm pressure shows up in the pool stats."""
        with self._lock:
            e = self._entries.pop(key, None)
            if e is not None:
                self.bytes_used -= e.nbytes
                self.shard_bytes_used -= e.shard_nbytes
            sp = self._spill.pop(key, None)
            if sp is not None:
                self.spill_bytes_used -= sp.nbytes
            if e is None and sp is None:
                return False
            self.evictions += 1
            return True

    def release(self) -> None:
        """Drop every entry (engine shutdown); counters survive for metrics."""
        with self._lock:
            self._entries.clear()
            self._spill.clear()
            self.bytes_used = 0
            self.spill_bytes_used = 0
            self.shard_bytes_used = 0

    def shard_bytes(self) -> List[int]:
        """Primary-tier stored bytes per model shard (one gauge per shard;
        [] for mesh-less pools).  The serving layout is symmetric by
        construction — every stored leaf is either split evenly over the
        model axis or replicated on all of its shards — so the shards hold
        identical byte counts."""
        with self._lock:
            if self.mesh is None:
                return []
            return [self.shard_bytes_used] * self._model_ways

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            shard = {}
            if self.mesh is not None:
                shard["shard_ways"] = self._model_ways
                for i in range(self._model_ways):
                    shard[f"bytes_shard{i}"] = self.shard_bytes_used
            return {
                **shard,
                "entries": len(self._entries),
                "slots": self.slots if self.slots is not None else -1,
                "budget_bytes": (self.budget_bytes
                                 if self.budget_bytes is not None else -1),
                "hits": self.hits,
                "misses": self.misses,
                "stale": self.stale,
                "evictions": self.evictions,
                "rejects": self.rejects,
                "extensions": self.extensions,
                "refresh_reencodes": self.refresh_reencodes,
                "hit_rate": self.hits / total if total else 0.0,
                "bytes": self.bytes_used,
                "spill_entries": len(self._spill),
                "spill_bytes": self.spill_bytes_used,
                "spill_hits": self.spill_hits,
            }
