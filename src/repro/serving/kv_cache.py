"""KV state managers for serving.

Two families live here:

``KVCacheManager``   batched decode-cache slot manager for the text
                     architectures (continuous-batching-lite): one pooled
                     cache pytree, per-slot lengths, prefill-insert/release.

``HistoryKVPool``    per-user LRU pool of cached *history-side* SUMI K/V for
                     GR serving (the MTServe / "One Pool, Two Caches"
                     hierarchical-cache idea).  The SUMI mask makes the
                     history prefix self-contained, so its per-layer K/V
                     depend only on the user history; FlameEngine encodes it
                     once, parks it here, and repeat/session-re-rank traffic
                     runs candidate-only executors against the pooled entry.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Slot:
    active: bool = False
    length: int = 0
    request_id: int = -1
    tokens: Optional[list] = None


class KVCacheManager:
    def __init__(self, bundle, batch: int, max_len: int, **kw):
        self.bundle = bundle
        self.batch = batch
        self.max_len = max_len
        self.caches, self.cache_specs = bundle.cache_init(batch, max_len, **kw)
        self.slots = [Slot() for _ in range(batch)]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def assign(self, request_id: int, prompt_len: int) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free KV-cache slots")
        i = free[0]
        self.slots[i] = Slot(True, prompt_len, request_id, [])
        return i

    def release(self, slot: int):
        self.slots[slot] = Slot()

    def write_prefill(self, slot: int, caches_one):
        """Insert a single-sequence cache (batch=1, stacked-layer axis 0) into
        batch position ``slot`` of the pooled cache."""
        self.caches = jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), slot, axis=1),
            self.caches, caches_one)

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)


# ---------------------------------------------------------------------------
# history-KV pool (GR serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _PoolEntry:
    fingerprint: Hashable      # content hash of the history prefix
    kv: object                 # HistoryKV pytree (or flattened leaves)
    nbytes: int


class HistoryKVPool:
    """Per-user LRU pool of encoded history K/V.

    ``get(key, fingerprint)`` returns the cached pytree and refreshes the
    entry's recency, or None on miss.  A key hit whose fingerprint differs
    (the user's history advanced since the encode) is *stale*: the entry is
    dropped and the call counts as a miss, so serving re-encodes rather than
    scoring against outdated state.  ``put`` inserts/overwrites and evicts
    from the LRU end until at most ``slots`` entries remain.  All methods
    are thread-safe — pipeline workers hit the pool concurrently.
    """

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"pool needs >= 1 slot, got {slots}")
        self.slots = slots
        self._entries: "collections.OrderedDict[Hashable, _PoolEntry]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.stale = 0
        self.evictions = 0
        self.bytes_used = 0

    @staticmethod
    def entry_bytes(kv) -> int:
        return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                   for a in jax.tree.leaves(kv))

    def get(self, key: Hashable, fingerprint: Hashable):
        with self._lock:
            e = self._entries.get(key)
            if e is None:
                self.misses += 1
                return None
            if e.fingerprint != fingerprint:
                del self._entries[key]          # stale: history advanced
                self.bytes_used -= e.nbytes
                self.stale += 1
                self.misses += 1
                return None
            self._entries.move_to_end(key)      # refresh recency
            self.hits += 1
            return e.kv

    def peek(self, key: Hashable, fingerprint: Hashable):
        """Like ``get`` but without touching hit/miss/stale counters (and
        without dropping stale entries) — used by the engine's single-flight
        leader election to re-check the pool after the initial counted miss,
        so each request still counts exactly one lookup."""
        with self._lock:
            e = self._entries.get(key)
            if e is None or e.fingerprint != fingerprint:
                return None
            self._entries.move_to_end(key)
            return e.kv

    def put(self, key: Hashable, fingerprint: Hashable, kv) -> None:
        nbytes = self.entry_bytes(kv)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.bytes_used -= old.nbytes
            self._entries[key] = _PoolEntry(fingerprint, kv, nbytes)
            self.bytes_used += nbytes
            while len(self._entries) > self.slots:
                _, ev = self._entries.popitem(last=False)   # LRU end
                self.bytes_used -= ev.nbytes
                self.evictions += 1

    def keys(self) -> List[Hashable]:
        """LRU -> MRU order (for tests/introspection)."""
        with self._lock:
            return list(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def release(self) -> None:
        """Drop every entry (engine shutdown); counters survive for metrics."""
        with self._lock:
            self._entries.clear()
            self.bytes_used = 0

    def stats(self) -> Dict[str, float]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "slots": self.slots,
                "hits": self.hits,
                "misses": self.misses,
                "stale": self.stale,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
                "bytes": self.bytes_used,
            }
