"""Pass 3 — recompile and tracer hazards.

Four rules:

R1  ``jax.jit(...)`` reachable from the serving hot path.  The AOT executor
    design (DSO) compiles everything up front; a jit call on the hot path
    means a per-request trace/compile is possible.

R2  Python ``if``/``while`` on traced values inside jit-compiled functions.
    A function is "jitted" when decorated with ``@jax.jit`` (directly or via
    ``functools.partial(jax.jit, static_argnames=...)``) or wrapped by name
    in a ``jax.jit(fn, ...)`` call in the same module.  Branch tests are
    fine when *static*: literals, ``static_argnames`` parameters, shape
    metadata (``x.shape`` / ``x.ndim`` / ``x.dtype`` / ``len(...)`` /
    ``isinstance(...)``), ``is None`` checks, ``self.*`` config reads, and
    locals assigned from static expressions (``b, m = q.shape``).

R3  Unhashable or non-canonical keys stored into executor caches: subscript
    stores / ``.add`` / ``.get`` / ``.setdefault`` on ``self`` attributes
    whose name matches ``cache|memo|seen|inflight|executor`` with a key
    expression containing a list/set/dict display, an ``np.array`` call, or
    a bare float literal.  Lists raise ``TypeError`` at runtime; arrays and
    floats silently fragment the executor family.

R4  Shape-dependent Python branching inside the serving/orchestration
    modules (``engine.py`` / ``dso.py``) — ``if``/``while`` on ``.shape``
    subscripts outside ``__init__`` fragments AOT executor families one
    request at a time.  Bucketing is expected to go through the canonical
    bucket tables, not ad-hoc shape comparisons.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.common import Finding, ModuleSource, dotted_name, \
    self_attr
from repro.analysis.host_sync import reachable_from_roots

PASS = "recompile"

CACHE_ATTR_RE = re.compile(r"cache|memo|seen|inflight|executor")
R4_FILES = ("engine.py", "dso.py")
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = {"len", "isinstance", "min", "max", "bool"}


# -- R1 ------------------------------------------------------------------

def _r1(sources: Sequence[ModuleSource]) -> List[Finding]:
    nodes, reach = reachable_from_roots(sources)
    out: List[Finding] = []
    for i in sorted(reach):
        node = nodes[i]
        for n in ast.walk(node.fn):
            if isinstance(n, ast.Call) and dotted_name(n.func) == "jax.jit":
                out.append(Finding(
                    node.module.path, n.lineno, PASS, "FC-JIT-HOT",
                    f"{node.qualname}: jax.jit() on the serving hot path — "
                    f"trace/compile can happen per request; build AOT "
                    f"executors instead"))
    return out


# -- R2 ------------------------------------------------------------------

def _static_argnames(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return names


def _jit_wrapper_call(node: ast.AST) -> Optional[ast.Call]:
    """Return the Call node if ``node`` is ``jax.jit(...)`` or
    ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    dn = dotted_name(node.func)
    if dn in ("jax.jit", "jit"):
        return node
    if dn in ("functools.partial", "partial") and node.args \
            and dotted_name(node.args[0]) in ("jax.jit", "jit"):
        return node
    return None


def _jitted_functions(src: ModuleSource) -> Dict[str, Set[str]]:
    """function name -> static arg names, for jitted defs in the module."""
    jitted: Dict[str, Set[str]] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = _jit_wrapper_call(dec)
                if call is not None:
                    jitted[node.name] = _static_argnames(call)
                elif dotted_name(dec) in ("jax.jit", "jit"):
                    jitted[node.name] = set()
        elif isinstance(node, ast.Call):
            call = _jit_wrapper_call(node)
            if call is not None and call is node:
                # jax.jit(fn, static_argnames=...) applied by name
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        jitted[arg.id] = _static_argnames(node)
    return jitted


class _StaticExpr:
    """Classifies whether an expression is trace-time static."""

    def __init__(self, static_names: Set[str]):
        self.static = set(static_names) | {"self"}

    def is_static(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.static
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True
            return self.is_static(node.value)
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in _STATIC_CALLS:
                return True
            return False
        if isinstance(node, ast.Compare):
            if any(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return True
            return self.is_static(node.left) and \
                all(self.is_static(c) for c in node.comparators)
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, (ast.BinOp,)):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self.is_static(e) for e in node.elts)
        if isinstance(node, ast.Subscript):
            return self.is_static(node.value)
        if isinstance(node, ast.IfExp):
            return all(self.is_static(e)
                       for e in (node.test, node.body, node.orelse))
        return False


def _r2_function(src: ModuleSource, fn: ast.AST,
                 statics: Set[str]) -> List[Finding]:
    classifier = _StaticExpr(statics)
    out: List[Finding] = []
    for stmt in ast.walk(fn):
        # grow the static-local set in statement order (approximate: one
        # forward pass is enough for the straight-line preambles jitted
        # kernels use, e.g. ``b, m, h, d = q.shape``)
        if isinstance(stmt, ast.Assign) and \
                classifier.is_static(stmt.value):
            for t in stmt.targets:
                elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                    else [t]
                for e in elts:
                    if isinstance(e, ast.Name):
                        classifier.static.add(e.id)
    for stmt in ast.walk(fn):
        if isinstance(stmt, (ast.If, ast.While)) and \
                not classifier.is_static(stmt.test):
            kw = "while" if isinstance(stmt, ast.While) else "if"
            out.append(Finding(
                src.path, stmt.lineno, PASS, "FC-TRACED-BRANCH",
                f"Python `{kw}` on a traced value inside a jitted function "
                f"— use lax.cond/select or mark the argument static"))
    return out


def _module_constants(src: ModuleSource) -> Set[str]:
    """Module-level names bound to literal constants — trace-time static
    by construction (e.g. threshold knobs like ``_SEG_GEMM_MIN_S``)."""
    out: Set[str] = set()
    for stmt in src.tree.body:
        if isinstance(stmt, ast.Assign) and \
                isinstance(stmt.value, ast.Constant):
            out.update(t.id for t in stmt.targets
                       if isinstance(t, ast.Name))
    return out


def _r2(sources: Sequence[ModuleSource]) -> List[Finding]:
    out: List[Finding] = []
    for src in sources:
        jitted = _jitted_functions(src)
        if not jitted:
            continue
        consts = _module_constants(src)
        for node in ast.walk(src.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in jitted:
                out.extend(_r2_function(src, node,
                                        jitted[node.name] | consts))
    return out


# -- R3 ------------------------------------------------------------------

def _bad_key(expr: ast.AST) -> Optional[str]:
    for n in ast.walk(expr):
        if isinstance(n, (ast.List, ast.Set, ast.Dict, ast.ListComp,
                          ast.SetComp, ast.DictComp)):
            return "unhashable list/set/dict"
        if isinstance(n, ast.Call) and dotted_name(n.func) in (
                "np.array", "np.asarray", "numpy.array", "numpy.asarray",
                "jnp.array", "jnp.asarray"):
            return "array object (identity-hashed / unhashable)"
        if isinstance(n, ast.Constant) and isinstance(n.value, float):
            return "bare float literal (non-canonical)"
    return None


def _r3(sources: Sequence[ModuleSource]) -> List[Finding]:
    out: List[Finding] = []
    for src in sources:
        for n in ast.walk(src.tree):
            key: Optional[ast.AST] = None
            attr: Optional[str] = None
            if isinstance(n, (ast.Assign, ast.AugAssign)):
                targets = n.targets if isinstance(n, ast.Assign) \
                    else [n.target]
                for t in targets:
                    if isinstance(t, ast.Subscript):
                        attr = self_attr(t.value)
                        key = t.slice
            elif isinstance(n, ast.Call) and \
                    isinstance(n.func, ast.Attribute) and \
                    n.func.attr in ("add", "get", "setdefault", "pop") \
                    and n.args:
                attr = self_attr(n.func.value)
                key = n.args[0]
            if attr is None or key is None or \
                    not CACHE_ATTR_RE.search(attr):
                continue
            why = _bad_key(key)
            if why is not None:
                out.append(Finding(
                    src.path, n.lineno, PASS, "FC-CACHE-KEY",
                    f"non-canonical key into self.{attr}: {why} — "
                    f"canonicalize to a tuple of hashable scalars"))
    return out


# -- R4 ------------------------------------------------------------------

def _r4(sources: Sequence[ModuleSource]) -> List[Finding]:
    out: List[Finding] = []
    for src in sources:
        if os.path.basename(src.path) not in R4_FILES:
            continue
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                    or node.name == "__init__":
                continue
            for stmt in ast.walk(node):
                if not isinstance(stmt, (ast.If, ast.While)):
                    continue
                for n in ast.walk(stmt.test):
                    if isinstance(n, ast.Subscript) and \
                            isinstance(n.value, ast.Attribute) and \
                            n.value.attr == "shape":
                        out.append(Finding(
                            src.path, stmt.lineno, PASS, "FC-SHAPE-BRANCH",
                            f"{node.name}: branching on .shape[...] — "
                            f"shape-dependent control flow fragments AOT "
                            f"executor families; route through the bucket "
                            f"tables"))
                        break
    return out


def run(sources: Sequence[ModuleSource]) -> List[Finding]:
    return _r1(sources) + _r2(sources) + _r3(sources) + _r4(sources)
