"""Pass 2 — hidden device→host synchronization on the serving hot path.

Builds an intra-repo call graph rooted at the request hot path —
``FlameEngine.submit`` (inherited from ``_PipelinedEngine``), the pipelined
worker loop, and the ``CoalescingOrchestrator`` flush loop — and flags every
construct reachable from it that forces a device→host sync or host copy:

- ``np.asarray(...)`` / ``np.array(...)`` calls (S1),
- ``jax.device_get(...)`` / ``jax.block_until_ready(...)`` (S2),
- ``.item()`` / ``.block_until_ready()`` method calls (S3),
- ``float(...)`` / ``int(...)`` whose argument mentions ``np.`` / ``jnp.``
  (S4 — conversion of an array scalar blocks on the device),
- ``np.asarray`` / ``jax.device_get`` passed as a callback, e.g.
  ``jax.tree.map(np.asarray, out)`` (S5),
- ``jax.device_put(...)`` (S6 — a host→device transfer staged from the
  hot path; blocks on the source buffer and, without a committed sharding,
  can force a later reshard.  A deliberate publish of pool KV into the
  executors' ``NamedSharding`` layout is the justified form — mesh-sharded
  serving commits KV where its heads live — and carries a pragma).

Call resolution is name-based (CHA-style): ``self.m(...)`` and ``obj.m(...)``
link to every analyzed class defining ``m``; bare names link to module-level
functions.  This over-approximates — acceptable, because the flagged sync
constructs are precisely the ones that need a written justification anywhere
near the hot path.  Deliberate dispatch-boundary syncs carry
``# flamecheck: host-sync-ok(reason)`` pragmas.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, ModuleSource, dotted_name

PASS = "host-sync"

#: (class name, method name) roots of the hot path.  Name-based so test
#: fixtures defining a class with one of these shapes are analyzed too.
ROOT_METHODS = {
    ("FlameEngine", "submit"),
    ("_PipelinedEngine", "submit"),
    ("_PipelinedEngine", "_worker_loop"),
    ("CoalescingOrchestrator", "submit"),
    ("CoalescingOrchestrator", "_worker"),
}

#: callback indirection the name-based resolver cannot see: a method that
#: stores/passes a bound helper which a callee later invokes.
EXTRA_EDGES = {
    "pad_slice": ("_pad_slice",),
    "gather": ("_gather",),
}

SYNC_NP_FUNCS = {"asarray", "array"}
SYNC_JAX_FUNCS = {"device_get", "block_until_ready"}
SYNC_JAX_PUT = {"device_put"}
SYNC_METHODS = {"item", "block_until_ready"}


class _Node:
    __slots__ = ("module", "cls", "name", "fn")

    def __init__(self, module: ModuleSource, cls: Optional[str], name: str,
                 fn: ast.AST):
        self.module = module
        self.cls = cls
        self.name = name
        self.fn = fn

    @property
    def qualname(self) -> str:
        return f"{self.cls}.{self.name}" if self.cls else self.name


def _collect_nodes(sources: Sequence[ModuleSource]) -> List[_Node]:
    nodes: List[_Node] = []
    for src in sources:
        for top in src.tree.body:
            if isinstance(top, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nodes.append(_Node(src, None, top.name, top))
            elif isinstance(top, ast.ClassDef):
                for item in top.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        nodes.append(_Node(src, top.name, item.name, item))
    return nodes


def _called_names(fn: ast.AST) -> Set[str]:
    """Names of everything syntactically called inside ``fn``."""
    out: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif isinstance(f, ast.Attribute):
                out.add(f.attr)
    return out


def build_call_graph(sources: Sequence[ModuleSource]
                     ) -> Tuple[List[_Node], Dict[int, Set[int]]]:
    """Returns (nodes, edges) with edges keyed/valued by node index."""
    nodes = _collect_nodes(sources)
    by_method: Dict[str, List[int]] = {}
    by_func: Dict[str, List[int]] = {}
    for i, node in enumerate(nodes):
        (by_method if node.cls else by_func).setdefault(
            node.name, []).append(i)

    edges: Dict[int, Set[int]] = {}
    for i, node in enumerate(nodes):
        callees: Set[int] = set()
        names = set(_called_names(node.fn))
        for name in list(names):
            names.update(EXTRA_EDGES.get(name, ()))
        for name in names:
            callees.update(by_method.get(name, []))
            callees.update(by_func.get(name, []))
        edges[i] = callees
    return nodes, edges


def reachable_from_roots(sources: Sequence[ModuleSource],
                         roots: Iterable[Tuple[str, str]] = ROOT_METHODS
                         ) -> Tuple[List[_Node], Set[int]]:
    nodes, edges = build_call_graph(sources)
    roots = set(roots)
    work = [i for i, n in enumerate(nodes) if (n.cls, n.name) in roots]
    seen: Set[int] = set(work)
    while work:
        i = work.pop()
        for j in edges.get(i, ()):
            if j not in seen:
                seen.add(j)
                work.append(j)
    return nodes, seen


def _mentions_array_ns(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name) \
                and n.value.id in ("np", "jnp", "numpy", "jax"):
            return True
    return False


def _scan_function(node: _Node) -> List[Finding]:
    src = node.module
    out: List[Finding] = []

    def add(line: int, code: str, msg: str):
        out.append(Finding(
            src.path, line, PASS, code,
            f"{node.qualname}: {msg} (reachable from the serving hot path)"))

    for n in ast.walk(node.fn):
        if not isinstance(n, ast.Call):
            continue
        f = n.func
        dn = dotted_name(f)
        if dn is not None:
            head, _, tail = dn.partition(".")
            if head in ("np", "numpy") and tail in SYNC_NP_FUNCS:
                add(n.lineno, "FC-SYNC-NP",
                    f"{dn}() forces a host copy/device sync")
                continue
            if head == "jax" and tail in SYNC_JAX_FUNCS:
                add(n.lineno, "FC-SYNC-JAX", f"{dn}() blocks on the device")
                continue
            if head == "jax" and tail in SYNC_JAX_PUT:
                add(n.lineno, "FC-SYNC-PUT",
                    f"{dn}() stages a host->device transfer on the hot "
                    f"path (justified when publishing into a committed "
                    f"NamedSharding layout)")
                continue
        if isinstance(f, ast.Attribute) and f.attr in SYNC_METHODS \
                and dotted_name(f.value) not in ("np", "numpy", "jnp"):
            add(n.lineno, "FC-SYNC-METHOD",
                f".{f.attr}() blocks on the device")
            continue
        if isinstance(f, ast.Name) and f.id in ("float", "int") \
                and n.args and _mentions_array_ns(n.args[0]):
            add(n.lineno, "FC-SYNC-SCALAR",
                f"{f.id}() of an array expression syncs the device")
            continue
        for arg in list(n.args) + [kw.value for kw in n.keywords]:
            adn = dotted_name(arg)
            if adn in ("np.asarray", "numpy.asarray", "jax.device_get"):
                add(n.lineno, "FC-SYNC-CALLBACK",
                    f"{adn} passed as a callback forces host copies")
                break
    return out


def run(sources: Sequence[ModuleSource]) -> List[Finding]:
    nodes, reach = reachable_from_roots(sources)
    findings: List[Finding] = []
    for i in sorted(reach):
        findings.extend(_scan_function(nodes[i]))
    return findings
