"""flamecheck — repo-specific static analysis for the FLAME serving stack.

Four passes (see the module docstrings for details):

- :mod:`repro.analysis.lock_discipline` — unguarded shared-state access in
  the threaded classes;
- :mod:`repro.analysis.host_sync` — hidden device→host syncs reachable from
  the serving hot path;
- :mod:`repro.analysis.recompile` — jit-recompile and tracer hazards;
- :mod:`repro.analysis.kernel_contracts` — Pallas BlockSpec/grid contracts.

Run as ``python -m repro.analysis [--strict]``; stdlib-only (imports neither
jax nor numpy) so it is fast enough to gate CI.
"""
from repro.analysis.common import Finding, ModuleSource  # noqa: F401
