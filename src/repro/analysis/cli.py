"""flamecheck CLI — ``python -m repro.analysis``.

Usage::

    python -m repro.analysis                      # default target set
    python -m repro.analysis --strict             # CI gate (pragma hygiene)
    python -m repro.analysis path.py --json       # machine-readable
    python -m repro.analysis --passes lock-discipline,host-sync

Exit codes: 0 clean, 1 findings, 2 usage/parse error.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Sequence

from repro.analysis import future_leak, host_sync, kernel_contracts, \
    lock_discipline, recompile
from repro.analysis.common import Finding, ModuleSource

PASSES = {
    "lock-discipline": lock_discipline.run,
    "host-sync": host_sync.run,
    "recompile": recompile.run,
    "kernel-contract": kernel_contracts.run,
    "future-leak": future_leak.run,
}

#: the repo modules flamecheck gates by default
DEFAULT_TARGETS = (
    "src/repro/serving/api.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/faults.py",
    "src/repro/serving/kv_cache.py",
    "src/repro/serving/scheduler.py",
    "src/repro/core/dso.py",
    "src/repro/core/pda.py",
    "src/repro/kernels/*/kernel.py",
    "src/repro/kernels/*/ops.py",
)


def _repo_root() -> str:
    # src/repro/analysis/cli.py -> repo root is three levels above src/
    return os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def default_paths() -> List[str]:
    root = _repo_root()
    out: List[str] = []
    for pat in DEFAULT_TARGETS:
        out.extend(sorted(glob.glob(os.path.join(root, pat))))
    return out


def load_sources(paths: Sequence[str]) -> List[ModuleSource]:
    return [ModuleSource.load(p) for p in paths]


def run_passes(sources: Sequence[ModuleSource],
               passes: Sequence[str] = tuple(PASSES),
               strict: bool = False) -> List[Finding]:
    """Run the requested passes, apply pragma suppression, and (in strict
    mode) append pragma-hygiene findings.  Returns *all* findings; callers
    filter on ``.suppressed``."""
    by_path: Dict[str, ModuleSource] = {s.path: s for s in sources}
    findings: List[Finding] = []
    for name in passes:
        findings.extend(PASSES[name](sources))
    for f in findings:
        src = by_path.get(f.path)
        if src is not None:
            src.suppress(f)
    if strict:
        for src in sources:
            findings.extend(src.pragma_findings())
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="flamecheck: repo-specific static analysis for the "
                    "FLAME serving stack")
    ap.add_argument("paths", nargs="*",
                    help="files to analyze (default: the serving/core/"
                         "kernel modules)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on unused pragmas and empty reasons")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help="comma-separated subset of: " + ", ".join(PASSES))
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print pragma-suppressed findings")
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        print(f"flamecheck: unknown pass(es): {', '.join(unknown)}",
              file=sys.stderr)
        return 2

    paths = list(args.paths) or default_paths()
    try:
        sources = load_sources(paths)
    except (OSError, SyntaxError) as e:
        print(f"flamecheck: {e}", file=sys.stderr)
        return 2

    findings = run_passes(sources, passes, strict=args.strict)
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    if args.as_json:
        print(json.dumps([f.as_dict() for f in findings], indent=2))
    else:
        shown = findings if args.show_suppressed else active
        for f in shown:
            tag = " (suppressed)" if f.suppressed else ""
            print(f.format() + tag)
        print(f"flamecheck: {len(active)} finding(s), "
              f"{len(suppressed)} suppressed, "
              f"{len(sources)} file(s), passes: {', '.join(passes)}")
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
