"""Pass 5 — ResponseFuture leak lint (liveness at the API boundary).

The serving API's liveness contract is that every :class:`ResponseFuture` a
caller can block on eventually resolves — the overload/fault machinery
(watchdog, shed errors, drain-on-shutdown) exists to guarantee it.  That
guarantee is easiest to break at the source: a future constructed and then
dropped on an early-return path resolves never, and the submitter hangs.

This pass flags every ``ResponseFuture(...)`` construction that, within the
same function, is neither

- *resolved* — ``.set_result(...)`` / ``.set_exception(...)`` /
  ``.cancel()`` called on it,
- *returned or yielded* — ownership passes to the caller,
- *handed off* — passed as an argument to any call (registration in an
  admission record, ``_try_fail(fut, ...)``, ``list.append``), or stored
  into an attribute / container slot (``self._futs[k] = fut``),

nor a bare-expression construction (created and immediately dropped — no
name ever binds it, nothing can resolve it).

The check is intraprocedural and name-based: handing the future anywhere
counts as discharging the obligation, so the pass only catches the
outright leak, not a callee that forgets.  That is deliberate — the
fan-out makes whole-graph tracking noisy, and the leak-at-birth case is
the one the overload work actually hit in review.  Deliberate leaks (test
fixtures building dead futures on purpose) carry
``# flamecheck: future-ok(reason)``.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from repro.analysis.common import Finding, ModuleSource, walk_scoped

PASS = "future-leak"

#: constructor names whose result carries the resolve-or-hang obligation
FUTURE_CTORS = {"ResponseFuture"}
#: attribute calls on the future that discharge the obligation
RESOLVE_METHODS = {"set_result", "set_exception", "cancel"}


def _ctor_name(call: ast.Call) -> Optional[str]:
    f = call.func
    name = f.id if isinstance(f, ast.Name) else (
        f.attr if isinstance(f, ast.Attribute) else None)
    return name if name in FUTURE_CTORS else None


def _mentions(node: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               for n in ast.walk(node))


def _is_discharged(fn: ast.AST, name: str, birth: ast.Assign) -> bool:
    """Does ``fn`` resolve, return, or hand off the future bound to
    ``name``?  Closures count: a nested def that resolves it is a valid
    discharge (the watchdog-forget callback pattern)."""
    for n in ast.walk(fn):
        if isinstance(n, ast.Call):
            f = n.func
            # fut.set_result(...) / fut.set_exception(...) / fut.cancel()
            if (isinstance(f, ast.Attribute) and f.attr in RESOLVE_METHODS
                    and isinstance(f.value, ast.Name)
                    and f.value.id == name):
                return True
            # handed off as an argument: record(fut=...), append(fut), ...
            for arg in list(n.args) + [kw.value for kw in n.keywords]:
                if _mentions(arg, name):
                    return True
        elif isinstance(n, (ast.Return, ast.Yield, ast.YieldFrom)):
            if n.value is not None and _mentions(n.value, name):
                return True
        elif isinstance(n, ast.Assign) and n is not birth:
            # stored into shared state: self._futs[k] = fut / d[k] = fut
            if _mentions(n.value, name):
                for t in n.targets:
                    if isinstance(t, (ast.Attribute, ast.Subscript)):
                        return True
    return False


def _scan_function(src: ModuleSource, cls: Optional[str],
                   fn: ast.AST) -> List[Finding]:
    qual = f"{cls}.{fn.name}" if cls else fn.name
    out: List[Finding] = []
    for n in walk_scoped(fn):
        if isinstance(n, ast.Expr) and isinstance(n.value, ast.Call):
            ctor = _ctor_name(n.value)
            if ctor is not None:
                out.append(Finding(
                    src.path, n.lineno, PASS, "FC-FUTURE",
                    f"{qual}: {ctor}() constructed and dropped — nothing "
                    f"can ever resolve it, a blocked caller hangs"))
        elif isinstance(n, ast.Assign) and isinstance(n.value, ast.Call):
            ctor = _ctor_name(n.value)
            if ctor is None:
                continue
            names = [t.id for t in n.targets if isinstance(t, ast.Name)]
            if not names:
                continue  # attribute/subscript target IS the hand-off
            if not any(_is_discharged(fn, name, n) for name in names):
                out.append(Finding(
                    src.path, n.lineno, PASS, "FC-FUTURE",
                    f"{qual}: {ctor}() bound to {names[0]!r} is never "
                    f"resolved, returned, or handed off — a caller "
                    f"blocking on .result() hangs forever"))
    return out


def run(sources: Sequence[ModuleSource]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        for top in src.tree.body:
            if isinstance(top, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_scan_function(src, None, top))
            elif isinstance(top, ast.ClassDef):
                for item in top.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        findings.extend(
                            _scan_function(src, top.name, item))
    return findings
