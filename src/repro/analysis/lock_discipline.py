"""Pass 1 — lock discipline for the threaded serving/core classes.

For every class that uses instance locks (``self._lock = threading.Lock()``
in ``__init__``, or ``with self._x:`` anywhere), the pass *infers* which
``self._*`` attributes are lock-guarded: an attribute is guarded iff it is
mutated at least once while a lock is held, outside ``__init__``.  Each
guarded attribute accumulates a *guard set* (every lock observed held at one
of its guarded mutations); any read or write of the attribute that holds
none of the locks in its guard set is flagged.

This matches how the repo actually uses locks: ``CoalescingOrchestrator``
guards its EDF heaps with per-(kind,bucket) condition variables and its
cost/stat counters with ``_stat_lock``; ``HistoryKVPool`` guards everything
with one ``_lock``; an access is fine under *any* lock in the attribute's
guard set (per-key conditions are statically one attribute).

Conventions understood:

- local aliases: ``cond = self._cond[key]`` then ``with cond:`` counts as
  holding ``_cond`` (tuple assignments too);
- mutations: attribute stores/augstores/deletes, subscript stores through
  the attribute (``self._x[k] = v``), nested attribute stores
  (``self._stats.hits += 1`` mutates ``_stats``), mutating method calls
  (``self._x.append(...)``, also via aliases), and calls taking the
  attribute (or an alias) as first argument (``heapq.heappush(self._x[k],
  item)``);
- ``__init__`` is construction-time and exempt;
- ``# flamecheck: locked-by-caller(self._lock)`` on a method header makes
  the body analyze as if ``_lock`` were held on entry;
- ``# flamecheck: unguarded-ok(reason)`` suppresses a finding.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import (Finding, ModuleSource, attr_chain_base,
                                   self_attr)

PASS = "lock-discipline"

LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                  "BoundedSemaphore"}
MUTATOR_METHODS = {"append", "appendleft", "add", "update", "clear", "pop",
                   "popleft", "popitem", "remove", "discard", "extend",
                   "extendleft", "insert", "setdefault", "move_to_end",
                   "sort", "reverse", "difference_update",
                   "intersection_update", "symmetric_difference_update"}
#: construction-time methods whose accesses are exempt (object not shared)
CTOR_METHODS = {"__init__", "__post_init__"}
# free functions that mutate their first argument in place
_FIRST_ARG_MUTATORS = {"heappush", "heappop", "heapify", "heappushpop",
                       "heapreplace"}


def _is_lock_factory_value(node: ast.AST) -> bool:
    """True if the expression constructs a Lock/RLock/Condition somewhere
    (covers ``threading.Lock()`` and dict-comprehension-of-Condition)."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            fn = n.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None)
            if name in LOCK_FACTORIES:
                return True
    return False


class _Access:
    __slots__ = ("attr", "line", "mutation", "held", "method")

    def __init__(self, attr: str, line: int, mutation: bool,
                 held: Set[str], method: str):
        self.attr = attr
        self.line = line
        self.mutation = mutation
        self.held = frozenset(held)
        self.method = method


class _MethodVisitor(ast.NodeVisitor):
    """Walks one method body tracking held locks and local lock aliases."""

    def __init__(self, method_name: str, initial_held: Set[str],
                 accesses: List[_Access], lock_attrs: Set[str]):
        self.method = method_name
        self.held: Set[str] = set(initial_held)
        self.accesses = accesses
        self.lock_attrs = lock_attrs      # grown as `with self.X:` is seen
        self.aliases: Dict[str, str] = {}  # local name -> self attr

    # -- helpers ---------------------------------------------------------
    def _record(self, attr: Optional[str], line: int, mutation: bool):
        if attr is not None:
            self.accesses.append(
                _Access(attr, line, mutation, self.held, self.method))

    def _aliased_attr(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name (or Name[...] chain) back to a self attribute."""
        base = attr_chain_base(node)
        attr = self_attr(base)
        if attr is not None:
            return attr
        if isinstance(base, ast.Name):
            return self.aliases.get(base.id)
        return None

    def _mutation_targets(self, target: ast.AST) -> List[ast.AST]:
        if isinstance(target, (ast.Tuple, ast.List)):
            out: List[ast.AST] = []
            for elt in target.elts:
                out.extend(self._mutation_targets(elt))
            return out
        return [target]

    def _record_store(self, target: ast.AST):
        for t in self._mutation_targets(target):
            if isinstance(t, ast.Starred):
                t = t.value
            attr = self_attr(t)
            if attr is not None:               # self.X = ...
                self._record(attr, t.lineno, True)
                continue
            if isinstance(t, (ast.Subscript, ast.Attribute)):
                # self.X[k] = v / self.X.y = v / alias[k] = v
                attr = self._aliased_attr(t)
                if attr is not None:
                    self._record(attr, t.lineno, True)

    def _maybe_alias(self, target: ast.AST, value: ast.AST):
        """Track ``name = self.X`` / ``name = self.X[k]`` aliases."""
        if isinstance(target, (ast.Tuple, ast.List)) and \
                isinstance(value, (ast.Tuple, ast.List)) and \
                len(target.elts) == len(value.elts):
            for t, v in zip(target.elts, value.elts):
                self._maybe_alias(t, v)
            return
        if not isinstance(target, ast.Name):
            return
        base = attr_chain_base(value)
        attr = self_attr(base)
        if attr is not None:
            self.aliases[target.id] = attr
        else:
            self.aliases.pop(target.id, None)

    # -- visitors --------------------------------------------------------
    def visit_With(self, node: ast.With):
        acquired: Set[str] = set()
        for item in node.items:
            expr = item.context_expr
            # `with self._lock:` / `with cond:` where cond aliases self._cond
            attr = self._aliased_attr(expr)
            if attr is not None:
                self.lock_attrs.add(attr)
                acquired.add(attr)
            self.visit(expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self.held |= acquired
        for stmt in node.body:
            self.visit(stmt)
        self.held -= acquired

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._record_store(t)
        self.visit(node.value)
        for t in node.targets:
            self._maybe_alias(t, node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self._record_store(node.target)
        if node.value is not None:
            self.visit(node.value)
            self._maybe_alias(node.target, node.value)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._record_store(node.target)
        self.visit(node.value)

    def visit_Delete(self, node: ast.Delete):
        for t in node.targets:
            self._record_store(t)
            for child in ast.walk(t):
                if child is not t:
                    self.visit(child)

    def visit_Call(self, node: ast.Call):
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in MUTATOR_METHODS:
            attr = self._aliased_attr(fn.value)
            if attr is not None and attr not in self.lock_attrs:
                self._record(attr, node.lineno, True)
        fname = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if node.args and fname in _FIRST_ARG_MUTATORS:
            # heapq.heappush(self._pending[key], item) mutates _pending
            attr = self._aliased_attr(node.args[0])
            if attr is not None and attr not in self.lock_attrs:
                self._record(attr, node.lineno, True)
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        attr = self_attr(node)
        if attr is not None and isinstance(node.ctx, ast.Load):
            self._record(attr, node.lineno, False)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef):
        # nested closures run with whatever the enclosing context holds at
        # definition point — a pragmatic approximation for local helpers
        for stmt in node.body:
            self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda):
        self.visit(node.body)


def _caller_locks(src: ModuleSource, fn: ast.FunctionDef) -> Set[str]:
    held: Set[str] = set()
    for p in src.header_pragmas(fn, "locked-by-caller"):
        p.used = True
        for part in p.reason.split(","):
            part = part.strip()
            if part.startswith("self."):
                part = part[len("self."):]
            if part:
                held.add(part)
    return held


def analyze_class(src: ModuleSource, cls: ast.ClassDef) -> List[Finding]:
    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    lock_attrs: Set[str] = set()
    lock_equiv: Dict[str, str] = {}   # cv attr -> the Lock it wraps
    accesses: List[_Access] = []

    # attrs assigned a Lock/RLock/Condition anywhere in the class; a
    # Condition built over an existing lock (``threading.Condition(
    # self._x_lock)``) shares that lock — holding either is holding both
    for m in methods:
        for n in ast.walk(m):
            if isinstance(n, ast.Assign) and _is_lock_factory_value(n.value):
                for t in n.targets:
                    attr = self_attr(t)
                    if attr is None:
                        continue
                    lock_attrs.add(attr)
                    v = n.value
                    if isinstance(v, ast.Call) and v.args:
                        wrapped = self_attr(v.args[0])
                        if wrapped is not None:
                            lock_equiv[attr] = wrapped

    def canon(lock: str) -> str:
        seen_chain = set()
        while lock in lock_equiv and lock not in seen_chain:
            seen_chain.add(lock)
            lock = lock_equiv[lock]
        return lock

    for m in methods:
        visitor = _MethodVisitor(m.name, _caller_locks(src, m),
                                 accesses, lock_attrs)
        for stmt in m.body:
            visitor.visit(stmt)

    if not lock_attrs:
        return []

    # guarded attrs: mutated under some lock, outside construction
    guards: Dict[str, Set[str]] = {}
    for a in accesses:
        if (a.mutation and a.method not in CTOR_METHODS
                and a.attr not in lock_attrs and a.held):
            guards.setdefault(a.attr, set()).update(
                canon(h) for h in a.held)

    findings: List[Finding] = []
    seen: Set[Tuple[int, str]] = set()
    for a in accesses:
        if a.method in CTOR_METHODS or a.attr not in guards:
            continue
        if {canon(h) for h in a.held} & guards[a.attr]:
            continue
        key = (a.line, a.attr)
        if key in seen:
            continue
        seen.add(key)
        kind = "write to" if a.mutation else "read of"
        locks = " or ".join(f"self.{g}" for g in sorted(guards[a.attr]))
        findings.append(Finding(
            src.path, a.line, PASS, "FC-LOCK",
            f"{cls.name}.{a.method}: unguarded {kind} self.{a.attr} "
            f"(guarded by {locks} elsewhere)"))
    return findings


def run(sources: Sequence[ModuleSource]) -> List[Finding]:
    findings: List[Finding] = []
    for src in sources:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                findings.extend(analyze_class(src, node))
    return findings
