"""Pass 4 — Pallas kernel contracts.

Runs over the kernel packages (``kernels/*/kernel.py`` + wrapping
``ops.py``) and checks three contracts the TPU lowering depends on:

K1  **BlockSpec index-map purity.**  Index maps run at trace time inside the
    pipeline emitter: they must be pure functions of the grid indices (and
    scalar-prefetch refs).  Flagged: direct ``jnp.*`` calls in the map body
    (Python scalar clamps of grid indices are preferred; where a traced
    clamp is genuinely required — e.g. clamping against a traced step count
    — suppress with ``kernel-ok`` and say why) and closures over mutable
    module-level state (list/dict/set globals) or ``self``.  Calls to
    module-level *helper functions* are allowed (flash-attention's
    ``_k_index`` pattern); the helper is the auditable unit.

K2  **Divisibility guards in the wrapper.**  Every ``ops.py`` that invokes a
    ``*_kernel`` entry point must contain padding/divisibility logic — a
    ``_pad_to`` helper or a ``%``-based pad computation — so grid/block
    shapes always divide. A wrapper with neither is assumed to pass raw
    shapes through.

K3  **Scalar-prefetch argument ordering.**  With
    ``pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=N, grid=<len G>)``,
    every index map must take exactly ``G + N`` parameters (grid indices
    first, then the prefetch refs).  An arity mismatch silently misbinds
    refs to grid axes.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.common import Finding, ModuleSource, dotted_name

PASS = "kernel-contract"


def _is_kernel_file(src: ModuleSource) -> bool:
    return os.path.basename(src.path) == "kernel.py" or \
        "pallas" in src.text


def _module_defs(src: ModuleSource) -> Dict[str, ast.FunctionDef]:
    defs: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for item in ast.walk(node):
                if isinstance(item, ast.FunctionDef) and item is not node:
                    defs.setdefault(item.name, item)
            defs.setdefault(node.name, node)
    return defs


def _mutable_globals(src: ModuleSource) -> Set[str]:
    out: Set[str] = set()
    for node in src.tree.body:
        if isinstance(node, ast.Assign) and isinstance(
                node.value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _index_map_exprs(call: ast.Call) -> List[ast.AST]:
    """The index-map argument of a ``pl.BlockSpec(shape, index_map)`` call."""
    if dotted_name(call.func) not in ("pl.BlockSpec", "BlockSpec",
                                      "pallas.BlockSpec"):
        return []
    args = list(call.args)
    for kw in call.keywords:
        if kw.arg == "index_map":
            return [kw.value]
    if len(args) >= 2:
        return [args[1]]
    return []


def _map_params(expr: ast.AST,
                defs: Dict[str, ast.FunctionDef]) -> Optional[List[str]]:
    if isinstance(expr, ast.Lambda):
        return [a.arg for a in expr.args.args]
    if isinstance(expr, ast.Name) and expr.id in defs:
        return [a.arg for a in defs[expr.id].args.args]
    return None


def _map_body(expr: ast.AST,
              defs: Dict[str, ast.FunctionDef]) -> Optional[ast.AST]:
    if isinstance(expr, ast.Lambda):
        return expr.body
    if isinstance(expr, ast.Name) and expr.id in defs:
        return defs[expr.id]
    return None


def _k1(src: ModuleSource) -> List[Finding]:
    defs = _module_defs(src)
    mutable = _mutable_globals(src)
    out: List[Finding] = []
    seen: Set[int] = set()
    for call in ast.walk(src.tree):
        if not isinstance(call, ast.Call):
            continue
        for expr in _index_map_exprs(call):
            body = _map_body(expr, defs)
            if body is None or id(body) in seen:
                continue
            seen.add(id(body))
            params = set(_map_params(expr, defs) or [])
            for n in ast.walk(body):
                if isinstance(n, ast.Call):
                    dn = dotted_name(n.func)
                    if dn and dn.split(".")[0] in ("jnp", "jax", "lax"):
                        out.append(Finding(
                            src.path, n.lineno, PASS, "FC-INDEX-MAP-JNP",
                            f"index map calls {dn}() — index maps must be "
                            f"pure Python over grid indices; use Python "
                            f"min/max or justify with kernel-ok"))
                elif isinstance(n, ast.Name) and isinstance(
                        n.ctx, ast.Load):
                    if n.id == "self":
                        out.append(Finding(
                            src.path, n.lineno, PASS, "FC-INDEX-MAP-STATE",
                            "index map closes over self — instance state "
                            "can change between trace and execution"))
                    elif n.id in mutable and n.id not in params:
                        out.append(Finding(
                            src.path, n.lineno, PASS, "FC-INDEX-MAP-STATE",
                            f"index map closes over mutable module global "
                            f"{n.id!r}"))
    return out


def _k2(src: ModuleSource) -> List[Finding]:
    if os.path.basename(src.path) != "ops.py":
        return []
    calls_kernel = None
    for n in ast.walk(src.tree):
        if isinstance(n, ast.Call):
            dn = dotted_name(n.func)
            if dn and dn.split(".")[-1].endswith("_kernel"):
                calls_kernel = n
                break
    if calls_kernel is None:
        return []
    has_guard = False
    for n in ast.walk(src.tree):
        if isinstance(n, ast.Call) and dotted_name(n.func) in (
                "_pad_to", "pad_to"):
            has_guard = True
            break
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
            has_guard = True
            break
    if not has_guard:
        return [Finding(
            src.path, calls_kernel.lineno, PASS, "FC-NO-PAD-GUARD",
            "ops wrapper invokes a *_kernel entry point but has no "
            "padding/divisibility guard (_pad_to or %-based) — grid/block "
            "shapes may not divide for arbitrary inputs")]
    return []


def _k3(src: ModuleSource) -> List[Finding]:
    defs = _module_defs(src)
    out: List[Finding] = []
    for call in ast.walk(src.tree):
        if not isinstance(call, ast.Call) or dotted_name(call.func) not in (
                "pltpu.PrefetchScalarGridSpec", "PrefetchScalarGridSpec"):
            continue
        n_prefetch: Optional[int] = None
        grid_len: Optional[int] = None
        spec_args: List[ast.AST] = []
        for kw in call.keywords:
            if kw.arg == "num_scalar_prefetch" and \
                    isinstance(kw.value, ast.Constant) and \
                    isinstance(kw.value.value, int):
                n_prefetch = kw.value.value
            elif kw.arg == "grid":
                grid_expr = kw.value
                if isinstance(grid_expr, ast.Name):
                    # resolve `grid = (...)` assigned earlier in the file
                    gname = grid_expr.id
                    for n in ast.walk(src.tree):
                        if isinstance(n, ast.Assign) and any(
                                isinstance(t, ast.Name) and t.id == gname
                                for t in n.targets):
                            grid_expr = n.value
                            break
                if isinstance(grid_expr, (ast.Tuple, ast.List)):
                    grid_len = len(grid_expr.elts)
            elif kw.arg in ("in_specs", "out_specs"):
                spec_args.append(kw.value)
        if n_prefetch is None or grid_len is None:
            continue
        expected = grid_len + n_prefetch
        # the grid spec's BlockSpecs may be passed inline or via the
        # surrounding pallas_call — scan the whole file's BlockSpecs
        for n in ast.walk(src.tree):
            if not isinstance(n, ast.Call):
                continue
            for expr in _index_map_exprs(n):
                params = _map_params(expr, defs)
                if params is None:
                    continue
                if len(params) != expected:
                    out.append(Finding(
                        src.path, expr.lineno, PASS, "FC-PREFETCH-ARITY",
                        f"index map takes {len(params)} params but the "
                        f"PrefetchScalarGridSpec implies "
                        f"{grid_len} grid indices + {n_prefetch} prefetch "
                        f"refs = {expected} — refs would misbind to grid "
                        f"axes"))
    return out


def run(sources: Sequence[ModuleSource]) -> List[Finding]:
    out: List[Finding] = []
    for src in sources:
        if not _is_kernel_file(src):
            continue
        out.extend(_k1(src))
        out.extend(_k3(src))
    for src in sources:
        out.extend(_k2(src))
    return out
