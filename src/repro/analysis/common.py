"""Shared infrastructure for flamecheck (`repro.analysis`).

flamecheck is a repo-specific, stdlib-only static-analysis suite: it parses
the serving/core/kernel modules with :mod:`ast` and checks the invariants the
FLAME reproduction's performance story rests on (lock discipline, no hidden
host syncs on the hot path, no recompile hazards, Pallas kernel contracts).
It deliberately imports neither jax nor numpy so `python -m repro.analysis`
stays fast enough to gate CI.

Pragmas
-------
Findings are suppressed with written justifications::

    x = self._pending[key]  # flamecheck: unguarded-ok(dict frozen after init)

Grammar: ``# flamecheck: <token>(<reason>)``.  Several pragmas may share one
comment, separated by whitespace.  Suppression tokens map 1:1 to passes:

==================  =====================
pass                token
==================  =====================
lock-discipline     ``unguarded-ok``
host-sync           ``host-sync-ok``
recompile           ``recompile-ok``
kernel-contract     ``kernel-ok``
future-leak         ``future-ok``
==================  =====================

A pragma suppresses a finding when it sits on the finding's line, on the
header of an enclosing ``def`` (between ``def`` and the first body
statement), or on the header of an enclosing ``class``.

One pragma is *semantic* rather than suppressive:
``locked-by-caller(self._lock)`` on a method header tells the
lock-discipline pass to analyze the body as if the named lock were held on
entry (for helpers whose docstring says "caller holds the lock").

``--strict`` additionally fails on pragmas with empty reasons and pragmas
that suppress nothing (so stale justifications rot loudly, not silently).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

PRAGMA_RE = re.compile(r"flamecheck:\s*((?:[a-z-]+\([^)]*\)\s*)+)")
PRAGMA_ITEM_RE = re.compile(r"([a-z-]+)\(([^)]*)\)")

#: pass name -> pragma token that suppresses its findings
SUPPRESS_TOKENS = {
    "lock-discipline": "unguarded-ok",
    "host-sync": "host-sync-ok",
    "recompile": "recompile-ok",
    "kernel-contract": "kernel-ok",
    "future-leak": "future-ok",
}
#: tokens with semantics beyond suppression (never "unused")
SEMANTIC_TOKENS = {"locked-by-caller"}
KNOWN_TOKENS = set(SUPPRESS_TOKENS.values()) | SEMANTIC_TOKENS


@dataclasses.dataclass
class Pragma:
    token: str
    reason: str
    line: int
    used: bool = False


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    pass_name: str
    code: str
    message: str
    suppressed: bool = False

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.code}] {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def _iter_pragmas(text: str) -> Iterable[Pragma]:
    reader = io.StringIO(text).readline
    try:
        tokens = list(tokenize.generate_tokens(reader))
    except tokenize.TokenError:
        return
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = PRAGMA_RE.search(tok.string)
        if not m:
            continue
        for token, reason in PRAGMA_ITEM_RE.findall(m.group(1)):
            yield Pragma(token=token, reason=reason.strip(),
                         line=tok.start[0])


class ModuleSource:
    """A parsed module plus its pragmas and scope map."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.pragmas: Dict[int, List[Pragma]] = {}
        for p in _iter_pragmas(text):
            self.pragmas.setdefault(p.line, []).append(p)
        # (lineno, header_end, end_lineno) for every def/class, innermost last
        self._scopes: List[Tuple[int, int, int]] = []
        # line spans of simple (non-compound) statements, so a pragma may
        # trail any line of a multi-line statement
        self._stmt_spans: List[Tuple[int, int]] = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                header_end = node.body[0].lineno - 1 if node.body \
                    else node.lineno
                self._scopes.append(
                    (node.lineno, max(node.lineno, header_end),
                     node.end_lineno or node.lineno))
            elif isinstance(node, (ast.If, ast.While)):
                # a pragma may trail any line of a multi-line condition
                end = node.test.end_lineno or node.test.lineno
                if end > node.lineno:
                    self._stmt_spans.append((node.lineno, end))
            elif isinstance(node, ast.stmt) and not isinstance(
                    node, (ast.For, ast.AsyncFor,
                           ast.With, ast.AsyncWith, ast.Try)):
                end = node.end_lineno or node.lineno
                if end > node.lineno:
                    self._stmt_spans.append((node.lineno, end))

    @classmethod
    def load(cls, path: str) -> "ModuleSource":
        with open(path, "r", encoding="utf-8") as f:
            return cls(path, f.read())

    # -- pragma lookup ---------------------------------------------------
    def pragma_lines_for(self, line: int) -> Set[int]:
        """Lines whose pragmas may suppress a finding at ``line``."""
        lines = {line}
        for start, end in self._stmt_spans:
            if start <= line <= end:
                lines.update(range(start, end + 1))
        for start, header_end, end in self._scopes:
            if start <= line <= end:
                lines.update(range(start, header_end + 1))
        return lines

    def suppress(self, finding: Finding) -> bool:
        """Mark ``finding`` suppressed if a matching pragma covers it."""
        token = SUPPRESS_TOKENS.get(finding.pass_name)
        if token is None:
            return False
        for ln in sorted(self.pragma_lines_for(finding.line)):
            for p in self.pragmas.get(ln, []):
                if p.token == token:
                    p.used = True
                    finding.suppressed = True
                    return True
        return False

    def header_pragmas(self, node: ast.AST, token: str) -> List[Pragma]:
        """Pragmas with ``token`` on the header lines of a def/class."""
        body = getattr(node, "body", None)
        header_end = body[0].lineno - 1 if body else node.lineno
        out = []
        for ln in range(node.lineno, max(node.lineno, header_end) + 1):
            for p in self.pragmas.get(ln, []):
                if p.token == token:
                    out.append(p)
        return out

    # -- strict-mode checks ----------------------------------------------
    def pragma_findings(self) -> List[Finding]:
        out = []
        for plist in self.pragmas.values():
            for p in plist:
                if p.token not in KNOWN_TOKENS:
                    out.append(Finding(
                        self.path, p.line, "pragma", "FC-PRAGMA-UNKNOWN",
                        f"unknown flamecheck pragma token {p.token!r}"))
                if not p.reason:
                    out.append(Finding(
                        self.path, p.line, "pragma", "FC-PRAGMA-REASON",
                        f"flamecheck pragma {p.token!r} has an empty reason "
                        f"— justify the suppression"))
                if (p.token not in SEMANTIC_TOKENS and not p.used
                        and p.token in KNOWN_TOKENS):
                    out.append(Finding(
                        self.path, p.line, "pragma", "FC-PRAGMA-UNUSED",
                        f"flamecheck pragma {p.token!r} suppresses nothing "
                        f"— remove it or fix its placement"))
        return out


# -- small AST helpers shared by passes ----------------------------------

def self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` -> ``'X'`` else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def attr_chain_base(node: ast.AST) -> ast.AST:
    """Peel Subscript/Attribute layers: ``self.X[k].y`` -> the self.X node."""
    while True:
        if isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Attribute) and self_attr(node) is None:
            node = node.value
        else:
            return node


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> ``'a.b.c'`` for pure Name/Attribute chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_scoped(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk that does not descend into nested def/class scopes."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(n))
