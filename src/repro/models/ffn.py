"""Dense feed-forward blocks (MLP / SwiGLU) with an optional fused-kernel path.

``impl="pallas"`` routes through kernels/fused_ffn — the FKE fusion of
norm + W1(+gate) + activation + W2 in one VMEM-resident kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def ffn_init(key, cfg, d_ff=None, stacked: int = 0):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": L.dense_init(ks[0], (d, f), ("embed", "mlp"), stacked=stacked),
         "w_down": L.dense_init(ks[1], (f, d), ("mlp", "embed"), stacked=stacked)}
    if cfg.activation == "swiglu":
        p["w_gate"] = L.dense_init(ks[2], (d, f), ("embed", "mlp"), stacked=stacked)
    return p


def ffn_apply(params, x, cfg, impl: str = "xla"):
    if impl == "pallas":
        from repro.kernels.fused_ffn import ops as ffn_ops
        return ffn_ops.fused_ffn(x, params, activation=cfg.activation)
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if cfg.activation == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = L.activation_fn(cfg.activation)(up.astype(jnp.float32))
    h = h.astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])
