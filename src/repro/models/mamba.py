"""Mamba (S6) block for the Jamba hybrid — selective state-space scan.

Continuous params (A, B, C, dt) discretized per token:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t        (state [d_inner, N])
    y_t = C_t . h_t + D * x_t

Prefill uses ``jax.lax.associative_scan`` over the (decay, increment) pairs —
the TPU-native mapping of the paper's parallel-scan kernel (log-depth, MXU
friendly).  Decode is the single-step recurrence on the carried
(conv_state, ssm_state) — O(1) in context, which is why jamba runs long_500k.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

DT_RANK_DIV = 16
MAMBA_CHUNK = 256


def mamba_init(key, cfg, stacked: int = 0):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = max(1, d // DT_RANK_DIV)
    ks = jax.random.split(key, 8)
    # S4D-real init for A
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)))
    p = {
        "w_in": L.dense_init(ks[0], (d, 2 * di), ("embed", "ssm_inner"), stacked=stacked),
        "conv_w": L.dense_init(ks[1], (cfg.mamba_d_conv, di), (None, "ssm_inner"),
                               stacked=stacked, scale=0.5),
        "conv_b": L.zeros_init((di,), ("ssm_inner",), stacked=stacked),
        "w_x": L.dense_init(ks[2], (di, dtr + 2 * n), ("ssm_inner", None),
                            stacked=stacked),
        "w_dt": L.dense_init(ks[3], (dtr, di), (None, "ssm_inner"), stacked=stacked),
        "dt_bias": L.zeros_init((di,), ("ssm_inner",), stacked=stacked, fill=-4.6),
        "a_log": L.Param(jnp.broadcast_to(
            a_init, ((stacked,) if stacked else ()) + (di, n)).astype(jnp.float32),
            (("stack",) if stacked else ()) + ("ssm_inner", "ssm_state")),
        "d_skip": L.ones_init((di,), ("ssm_inner",), stacked=stacked),
        "w_out": L.dense_init(ks[4], (di, d), ("ssm_inner", "embed"), stacked=stacked),
    }
    return p


def _conv1d(x, w, b, conv_state=None):
    """Depthwise causal conv.  x [B,S,di]; w [K,di].  Returns (y, new_state)."""
    ksz = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], ksz - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(ksz))
    new_state = xp[:, -(ksz - 1):] if ksz > 1 else conv_state
    return y + b[None, None], new_state


def _ssm_params(params, xc, cfg):
    """xc [B,S,di] -> dt [B,S,di], B,C [B,S,N] (f32)."""
    n = cfg.mamba_d_state
    xdbc = jnp.einsum("bsd,de->bse", xc, params["w_x"]).astype(jnp.float32)
    dtr = xdbc.shape[-1] - 2 * n
    dt_in, b_in, c_in = jnp.split(xdbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_in,
                                    params["w_dt"].astype(jnp.float32))
                         + params["dt_bias"].astype(jnp.float32))
    return dt, b_in, c_in


def mamba_apply(params, x, cfg, *, state: Optional[Tuple] = None,
                decode: bool = False):
    """x [B,S,d] -> (y [B,S,d], (conv_state, ssm_state))."""
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    conv_state, ssm_state = state if state is not None else (None, None)

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _conv1d(xi, params["conv_w"], params["conv_b"], conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    dt, b_in, c_in = _ssm_params(params, xc, cfg)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # [di,N], negative
    decay = jnp.exp(dt[..., None] * a[None, None])            # [B,S,di,N]
    incr = (dt * xc.astype(jnp.float32))[..., None] * b_in[:, :, None, :]  # [B,S,di,N]

    if decode:
        if ssm_state is None:
            ssm_state = jnp.zeros((b, di, n), jnp.float32)
        h = decay[:, 0] * ssm_state + incr[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0])[:, None]
        ssm_state = h
    else:
        if ssm_state is None:
            ssm_state = jnp.zeros((b, di, n), jnp.float32)
        # chunked selective scan: sequential over chunks (O(c) state memory),
        # log-depth associative scan within each chunk.  Under cost-transparent
        # lowering the chunk loop is unrolled, so use few big chunks there
        # (the associative scan inside is real ops, counted correctly).
        from repro import flags
        c = min(MAMBA_CHUNK, s)
        if flags.unroll_scans():
            c = min(s, max(MAMBA_CHUNK, -(-s // 8)))
        pad = (-s) % c
        if pad:
            decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)),
                            constant_values=1.0)
            incr = jnp.pad(incr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nch = (s + pad) // c

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        def chunk_step(h0, inp):
            dc, ic = inp                                   # [b,c,di,n]
            ic0 = ic.at[:, 0].add(dc[:, 0] * h0)
            _, h = jax.lax.associative_scan(combine, (dc, ic0), axis=1)
            return h[:, -1], h

        dc = jnp.moveaxis(decay.reshape(b, nch, c, di, n), 1, 0)
        ic = jnp.moveaxis(incr.reshape(b, nch, c, di, n), 1, 0)
        ssm_state, hs = jax.lax.scan(chunk_step, ssm_state, (dc, ic),
                                     unroll=flags.unroll_scans())
        h = jnp.moveaxis(hs, 0, 1).reshape(b, nch * c, di, n)[:, :s]
        y = jnp.einsum("bsdn,bsn->bsd", h, c_in)

    y = y + params["d_skip"].astype(jnp.float32)[None, None] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, (conv_state, ssm_state)
