"""Shared building blocks: params-with-logical-specs, norms, RoPE, embeddings.

Every init function returns a pytree whose leaves are :class:`Param`
(value + logical axis names).  ``split_params`` separates values from specs so
the dry-run can map specs to NamedShardings while the training/serving code
works with plain arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Param:
    value: jnp.ndarray
    logical: Tuple[Optional[str], ...]

    def __post_init__(self):
        assert len(self.logical) == self.value.ndim, (
            f"logical {self.logical} vs shape {self.value.shape}")


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """(values_tree, specs_tree) from a tree of Params."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    specs = jax.tree.map(lambda p: p.logical, tree, is_leaf=is_param)
    return values, specs


def param_count(values_tree) -> int:
    return sum(int(np.prod(v.shape)) for v in jax.tree.leaves(values_tree))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, logical, dtype=jnp.bfloat16, scale=None,
               stacked: int = 0, fan_in_axes=None) -> Param:
    """Truncated-normal dense init; fan-in scaled.  ``stacked>0`` prepends a
    layer-stack axis (for lax.scan over layers).  ``fan_in_axes`` names the
    contraction axes (default: all but the last)."""
    if fan_in_axes is None:
        fan_in_axes = tuple(range(len(shape) - 1)) if len(shape) >= 2 else (0,)
    fan_in = int(np.prod([shape[a] for a in fan_in_axes]))
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    if stacked:
        shape = (stacked,) + tuple(shape)
        logical = ("stack",) + tuple(logical)
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    return Param(w.astype(dtype), tuple(logical))


def zeros_init(shape, logical, dtype=jnp.bfloat16, stacked: int = 0,
               fill: float = 0.0) -> Param:
    if stacked:
        shape = (stacked,) + tuple(shape)
        logical = ("stack",) + tuple(logical)
    return Param(jnp.full(shape, fill, dtype), tuple(logical))


def ones_init(shape, logical, dtype=jnp.bfloat16, stacked: int = 0) -> Param:
    return zeros_init(shape, logical, dtype, stacked, fill=1.0)


# ---------------------------------------------------------------------------
# norms (always computed in f32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(cfg, d, stacked: int = 0):
    if cfg.norm == "rmsnorm":
        return {"scale": zeros_init((d,), ("embed",), stacked=stacked)}
    return {"scale": ones_init((d,), ("embed",), stacked=stacked),
            "bias": zeros_init((d,), ("embed",), stacked=stacked)}


def apply_norm(cfg, params, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S].  theta==0 disables RoPE."""
    if theta == 0.0:
        return x
    d = x.shape[-1]
    d2 = d // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(d2, dtype=jnp.float32) / d2)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, d2]
    cos = jnp.cos(ang)[..., :, None, :]                        # [..., S, 1, d2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :d2], x[..., d2:2 * d2]
    rest = x[..., 2 * d2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)
    if rest.shape[-1]:
        out = jnp.concatenate([out, rest], axis=-1)
    return out


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg):
    p = {"embedding": dense_init(key, (cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), scale=0.02)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"))
    return p


def embed(params, tokens, cfg):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x, cfg):
    if cfg.tie_embeddings:
        w = params["embedding"].T
    else:
        w = params["unembed"]
    return jnp.einsum("...d,dv->...v", x, w)


def activation_fn(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "silu": jax.nn.silu}.get(name, jax.nn.gelu)
