"""RWKV-6 (Finch) — attention-free, data-dependent-decay linear attention.

Per head h with key/value dim D (head_size):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T           (state [D, D])
    o_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t     (bonus u for current token)

w_t in (0,1) is data-dependent: w_t = exp(-exp(w0 + lora_w(x_t))).
The prefill path uses the chunked formulation (intra-chunk pairwise decays in
log space — always <= 1, numerically stable; inter-chunk state carry), which
is also what the Pallas kernel (kernels/rwkv6_scan) implements.  Decode is a
single recurrence step on the [B,H,D,D] state — O(1) in context length, which
is why rwkv6-7b runs the long_500k shape.

Token-shift "ddlerp" mixing and the squared-relu channel-mix follow the RWKV-6
structure [arXiv:2404.05892] (low-rank data-dependent mixing, single shared
lora rank for compactness).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

LORA_RANK = 32
CHUNK = 64


def rwkv_init(key, cfg, stacked: int = 0):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    ks = jax.random.split(key, 16)
    p = {
        # time-mix projections
        "wr": L.dense_init(ks[0], (d, d), ("embed", "heads"), stacked=stacked),
        "wk": L.dense_init(ks[1], (d, d), ("embed", "heads"), stacked=stacked),
        "wv": L.dense_init(ks[2], (d, d), ("embed", "heads"), stacked=stacked),
        "wg": L.dense_init(ks[3], (d, d), ("embed", "heads"), stacked=stacked),
        "wo": L.dense_init(ks[4], (d, d), ("heads", "embed"), stacked=stacked),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": L.zeros_init((d,), ("heads",), stacked=stacked, fill=-1.0),
        "wA": L.dense_init(ks[5], (d, LORA_RANK), ("embed", None), stacked=stacked),
        "wB": L.dense_init(ks[6], (LORA_RANK, d), (None, "heads"), stacked=stacked),
        # per-channel bonus
        "u": L.zeros_init((d,), ("heads",), stacked=stacked, fill=0.5),
        # token-shift mix coefficients (one per r/k/v/w/g)
        "mu": L.zeros_init((5, d), (None, "embed"), stacked=stacked, fill=0.5),
        # ddlerp low-rank adapter (shared)
        "muA": L.dense_init(ks[7], (d, LORA_RANK), ("embed", None), stacked=stacked),
        "muB": L.dense_init(ks[8], (LORA_RANK, 5, d), (None, None, "embed"),
                            stacked=stacked, fan_in_axes=(0,)),
        # group-norm over heads
        "ln_x_scale": L.ones_init((d,), ("heads",), stacked=stacked),
        "ln_x_bias": L.zeros_init((d,), ("heads",), stacked=stacked),
        # channel-mix
        "ck": L.dense_init(ks[9], (d, cfg.d_ff), ("embed", "mlp"), stacked=stacked),
        "cv": L.dense_init(ks[10], (cfg.d_ff, d), ("mlp", "embed"), stacked=stacked),
        "c_mu": L.zeros_init((d,), ("embed",), stacked=stacked, fill=0.5),
    }
    return p


def _ddlerp(params, x, x_prev):
    """Data-dependent token-shift: returns 5 mixed streams [B,S,d] each."""
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)  # shifted
    diff = (xs - x).astype(jnp.float32)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", diff,
                               params["muA"].astype(jnp.float32)))
    dyn = jnp.einsum("bsr,rfd->fbsd", lora, params["muB"].astype(jnp.float32))
    mixed = x.astype(jnp.float32)[None] + diff[None] * (
        params["mu"].astype(jnp.float32)[:, None, None] + dyn)
    return mixed.astype(x.dtype), x[:, -1]


def wkv_chunked(r, k, v, w_log, u, state: Optional[jnp.ndarray] = None,
                chunk: int = CHUNK):
    """Chunked linear-attention scan.

    r,k,v: [B,S,H,D]; w_log: [B,S,H,D] = log(w_t) (<=0); u: [H,D].
    Returns (o [B,S,H,D], final state [B,H,D,D]).
    """
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, zp) for a in (r, k, v))
        w_log = jnp.pad(w_log, zp)  # log w = 0 -> w = 1 (no decay) for padding
    rf = r.astype(jnp.float32).reshape(b, n, chunk, h, d)
    kf = k.astype(jnp.float32).reshape(b, n, chunk, h, d)
    vf = v.astype(jnp.float32).reshape(b, n, chunk, h, d)
    wl = w_log.astype(jnp.float32).reshape(b, n, chunk, h, d)
    uf = u.astype(jnp.float32)

    if state is None:
        state = jnp.zeros((b, h, d, d), jnp.float32)

    def step(S, inp):
        rc, kc, vc, wc = inp            # [b,chunk,h,d]
        la = jnp.cumsum(wc, axis=1)     # inclusive cumulative log-decay
        la_prev = la - wc               # exclusive (through t-1)
        # inter-chunk: o_inter[t] = (r_t * exp(la_prev_t)) @ S
        r_dec = rc * jnp.exp(la_prev)
        o_inter = jnp.einsum("bthd,bhde->bthe", r_dec, S)
        # intra-chunk pairwise: D[t,s,d] = exp(la_prev[t] - la[s]) for s < t
        diff = la_prev[:, :, None] - la[:, None, :, :, :]     # [b,t,s,h,d]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        dec = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthd,bshd,btshd->bths", rc, kc, dec)
        o_intra = jnp.einsum("bths,bshd->bthd", scores, vc)
        # current-token bonus
        o_bonus = jnp.einsum("bthd,bthd->bth", rc * uf[None, None], kc)[..., None] * vc
        # state update: S' = diag(exp(la_c)) S + sum_s (k_s exp(la_c - la_s)) v_s^T
        la_c = la[:, -1:]
        k_dec = kc * jnp.exp(la_c - la)
        S_new = jnp.exp(la_c[:, 0])[..., None] * S + jnp.einsum(
            "bshd,bshe->bhde", k_dec, vc)
        return S_new, o_inter + o_intra + o_bonus

    from repro import flags
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wl))
    state, o = jax.lax.scan(step, state, xs, unroll=flags.unroll_scans())
    o = jnp.moveaxis(o, 0, 1).reshape(b, n * chunk, h, d)[:, :s]
    return o.astype(r.dtype), state


def wkv_decode_step(r, k, v, w, u, state):
    """One-token recurrence.  r,k,v,w: [B,H,D]; state [B,H,D,D] (f32)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = w.astype(jnp.float32)
    o = jnp.einsum("bhd,bhde->bhe", rf, state) + \
        jnp.einsum("bhd,bhd->bh", rf * u.astype(jnp.float32)[None], kf)[..., None] * vf
    state = wf[..., None] * state + jnp.einsum("bhd,bhe->bhde", kf, vf)
    return o.astype(r.dtype), state


def _group_norm(x, scale, bias, nh, eps=64e-5):
    """Per-head group norm on [B,S,d] flattened heads."""
    b, s, d = x.shape
    xf = x.astype(jnp.float32).reshape(b, s, nh, d // nh)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(b, s, d) * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def time_mix(params, x, cfg, *, x_prev=None, state=None, decode=False):
    """RWKV-6 time-mix.  Prefill: x [B,S,d]. Decode: x [B,1,d] with carried
    (x_prev [B,d], state [B,H,D,D])."""
    b = x.shape[0]
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    mixed, last_x = _ddlerp(params, x, x_prev)
    xr, xk, xv, xw, xg = mixed
    r = jnp.einsum("bsd,de->bse", xr, params["wr"])
    k = jnp.einsum("bsd,de->bse", xk, params["wk"])
    v = jnp.einsum("bsd,de->bse", xv, params["wv"])
    g = jnp.einsum("bsd,de->bse", xg, params["wg"])
    w_log = -jnp.exp(
        params["w0"].astype(jnp.float32)
        + jnp.einsum("bsr,rd->bsd",
                     jnp.tanh(jnp.einsum("bsd,dr->bsr",
                                         xw.astype(jnp.float32),
                                         params["wA"].astype(jnp.float32))),
                     params["wB"].astype(jnp.float32)))
    w_log = jnp.clip(w_log, -20.0, -1e-4)
    shp = (b, -1, nh, hs)
    r4, k4, v4 = (a.reshape(shp) for a in (r, k, v))
    u = params["u"].reshape(nh, hs)
    if decode:
        o, state = wkv_decode_step(r4[:, 0], k4[:, 0], v4[:, 0],
                                   jnp.exp(w_log.reshape(shp)[:, 0]), u, state)
        o = o[:, None].reshape(b, 1, d)
    else:
        o, state = wkv_chunked(r4, k4, v4, w_log.reshape(shp), u, state)
        o = o.reshape(b, -1, d)
    o = _group_norm(o, params["ln_x_scale"], params["ln_x_bias"], nh)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    out = jnp.einsum("bsd,de->bse", o, params["wo"])
    return out, (last_x, state)


def channel_mix(params, x, cfg, x_prev=None):
    """Squared-relu channel mix with token shift."""
    b = x.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((b, cfg.d_model), x.dtype)
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mu = params["c_mu"].astype(jnp.float32)
    xk = (x.astype(jnp.float32) * (1 - mu) + xs.astype(jnp.float32) * mu).astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, params["ck"])
    h = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["cv"]), x[:, -1]
