"""Decoder-only transformer stack with periodic layer patterns.

The layer stack lowers as a ``lax.scan`` over *pattern groups* (one group =
one period of ``cfg.layer_pattern``), with per-layer parameters stacked on a
leading "stack" axis.  HLO size is therefore O(pattern period), not O(depth):
qwen2-72b's 80 layers compile as a scan of 80 steps over one layer body.

Layer kinds: "attn" (global), "swa" (sliding window), "mamba", "rwkv".
MoE layers are determined by ``cfg.moe.every_n_layers`` (static within the
period — enforced by ModelConfig).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv6 as R
from repro.models.ffn import ffn_init, ffn_apply
from repro.models.moe import moe_init, moe_dispatch


def scan_or_unroll(body, carry, xs, threshold: int = 2):
    """lax.scan, or a python unroll when the trip count is tiny.

    The unrolled form is what the dry-run's 1/2-group cost extrapolation
    lowers (XLA cost analysis counts a while-loop body once, so scanned
    stacks under-count by the trip count)."""
    n = jax.tree.leaves(xs)[0].shape[0]
    if n > threshold:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = ys[0]
    return carry, stacked


def _is_moe_layer(cfg, j: int) -> bool:
    return cfg.moe is not None and (j % cfg.moe.every_n_layers
                                    == cfg.moe.every_n_layers - 1)


def stack_init(key, cfg, *, n_layers: Optional[int] = None,
               pattern: Optional[Tuple[str, ...]] = None):
    """Stacked-by-group parameters for the layer stack."""
    pattern = pattern or cfg.layer_pattern
    n_layers = n_layers or cfg.n_layers
    n_groups = n_layers // len(pattern)
    layers = {}
    for j, kind in enumerate(pattern):
        kj = jax.random.fold_in(key, j)
        ks = jax.random.split(kj, 4)
        p: Dict[str, Any] = {"norm1": L.norm_init(cfg, cfg.d_model, stacked=n_groups)}
        if kind in ("attn", "swa"):
            p["attn"] = A.qkv_init(ks[0], cfg, stacked=n_groups)
        elif kind == "mamba":
            p["mamba"] = M.mamba_init(ks[0], cfg, stacked=n_groups)
        elif kind == "rwkv":
            p["rwkv"] = R.rwkv_init(ks[0], cfg, stacked=n_groups)
        else:
            raise ValueError(kind)
        if kind != "rwkv":  # rwkv carries its own channel-mix
            p["norm2"] = L.norm_init(cfg, cfg.d_model, stacked=n_groups)
            if _is_moe_layer(cfg, j):
                p["ffn"] = moe_init(ks[1], cfg, stacked=n_groups)
            else:
                p["ffn"] = ffn_init(ks[1], cfg, stacked=n_groups)
        else:
            p["norm2"] = L.norm_init(cfg, cfg.d_model, stacked=n_groups)
        layers[f"l{j}"] = p
    return {"layers": layers,
            "final_norm": L.norm_init(cfg, cfg.d_model)}


def init_caches(cfg, batch: int, max_len: int, *, dtype=jnp.bfloat16,
                pattern: Optional[Tuple[str, ...]] = None,
                n_layers: Optional[int] = None, quant: bool = False):
    """Decode caches, stacked over groups.  Returns (caches, specs).

    ``quant=True`` stores K/V as int8 with per-(position, head) bf16 scales
    (~0.5x the bf16 cache footprint — halves the decode HBM floor)."""
    pattern = pattern or cfg.layer_pattern
    n_layers = n_layers or cfg.n_layers
    n_groups = n_layers // len(pattern)
    hd = cfg.head_dim
    caches = {}
    for j, kind in enumerate(pattern):
        if kind in ("attn", "swa"):
            clen = min(cfg.sliding_window, max_len) if kind == "swa" and \
                cfg.sliding_window else max_len
            shape = (n_groups, batch, clen, cfg.n_kv_heads, hd)
            logical = ("stack", "cache_batch", "cache_seq", "cache_heads", None)
            if quant:
                sshape = shape[:-1] + (1,)
                caches[f"l{j}"] = {
                    "k": L.Param(jnp.zeros(shape, jnp.int8), logical),
                    "v": L.Param(jnp.zeros(shape, jnp.int8), logical),
                    "k_scale": L.Param(jnp.zeros(sshape, dtype), logical),
                    "v_scale": L.Param(jnp.zeros(sshape, dtype), logical),
                }
            else:
                caches[f"l{j}"] = {
                    "k": L.Param(jnp.zeros(shape, dtype), logical),
                    "v": L.Param(jnp.zeros(shape, dtype), logical),
                }
        elif kind == "mamba":
            di = cfg.mamba_expand * cfg.d_model
            caches[f"l{j}"] = {
                "conv": L.Param(jnp.zeros((n_groups, batch, cfg.mamba_d_conv - 1, di),
                                          dtype),
                                ("stack", "cache_batch", None, "ssm_inner")),
                "ssm": L.Param(jnp.zeros((n_groups, batch, di, cfg.mamba_d_state),
                                         jnp.float32),
                               ("stack", "cache_batch", "ssm_inner", "ssm_state")),
            }
        elif kind == "rwkv":
            hs = cfg.rwkv_head_size
            nh = cfg.d_model // hs
            caches[f"l{j}"] = {
                "x_tm": L.Param(jnp.zeros((n_groups, batch, cfg.d_model), dtype),
                                ("stack", "cache_batch", "embed")),
                "x_cm": L.Param(jnp.zeros((n_groups, batch, cfg.d_model), dtype),
                                ("stack", "cache_batch", "embed")),
                "state": L.Param(jnp.zeros((n_groups, batch, nh, hs, hs), jnp.float32),
                                 ("stack", "cache_batch", "heads", None, None)),
            }
    return L.split_params(caches)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _quantize_kv(x):
    """[B,S,H,D] -> (int8 values, bf16 per-(pos,head) scales)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    q = jnp.round(xf / jnp.maximum(scale, 1e-8)).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequant_kv(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def _dus_batch(cache, new, slot):
    return jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(
        c, n, s, axis=0))(cache, new, slot)


def _attn_layer(p, x, cfg, kind, *, mode, positions, cache, cur_len, impl,
                mask_mode):
    window = cfg.sliding_window if kind == "swa" else 0
    q, k, v = A.project_qkv(p["attn"], x, cfg, positions)
    quant = cache is not None and "k_scale" in cache
    if mode == "decode":
        clen = cache["k"].shape[1]
        is_ring = bool(window) and clen <= window
        slot = positions[:, 0] % clen                     # ring (or identity) slot
        if quant:
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache = {"k": _dus_batch(cache["k"], kq, slot),
                         "v": _dus_batch(cache["v"], vq, slot),
                         "k_scale": _dus_batch(cache["k_scale"], ks, slot),
                         "v_scale": _dus_batch(cache["v_scale"], vs, slot)}
            k_cache = _dequant_kv(new_cache["k"], new_cache["k_scale"])
            v_cache = _dequant_kv(new_cache["v"], new_cache["v_scale"])
        else:
            k_cache = _dus_batch(cache["k"], k, slot)
            v_cache = _dus_batch(cache["v"], v, slot)
            new_cache = {"k": k_cache, "v": v_cache}
        if is_ring:
            # the ring holds exactly the last <=window tokens; validity only
            o = A.decode_attention(q, k_cache, v_cache,
                                   jnp.minimum(cur_len, clen), window=0)
        elif impl == "pallas":
            # FKE serving kernel: block-skipped single-token flash decode
            from repro.kernels.flash_decode import flash_decode
            lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32),
                                    (q.shape[0],))
            o = flash_decode(q[:, 0], k_cache.astype(q.dtype),
                             v_cache.astype(q.dtype), lens,
                             window=window)[:, None]
        else:
            o = A.decode_attention(q, k_cache, v_cache, cur_len, window=window)
    else:
        eff_mode = "sliding" if (kind == "swa" and window) else mask_mode
        o = A.attention(q, k, v, eff_mode, impl=impl, window=window)
        new_cache = None
        if cache is not None:  # prefill into cache buffers
            clen = cache["k"].shape[1]
            s = k.shape[1]
            if clen < s:
                # ring cache: position p sits at slot p % clen; the last clen
                # positions [s-clen, s) land at slots rolled by s % clen.
                k_w, v_w = jnp.roll(k[:, -clen:], s % clen, axis=1), \
                    jnp.roll(v[:, -clen:], s % clen, axis=1)
            else:
                pad = clen - s
                k_w = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v_w = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if quant:
                kq, ks = _quantize_kv(k_w)
                vq, vs = _quantize_kv(v_w)
                new_cache = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
            else:
                new_cache = {"k": k_w, "v": v_w}
    return A.project_out(p["attn"], o), new_cache


def layer_apply(p, x, cfg, kind, j, *, mode, positions, cache, cur_len,
                impl, mask_mode):
    """One (mixer + ffn) layer.  Returns (x, new_cache, aux)."""
    from repro import sharding as shd
    aux = {}
    x = shd.constrain_ctx(x, "batch", "seq", None)
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache = cache
    if kind in ("attn", "swa"):
        y, new_cache = _attn_layer(p, h, cfg, kind, mode=mode, positions=positions,
                                   cache=cache, cur_len=cur_len, impl=impl,
                                   mask_mode=mask_mode)
    elif kind == "mamba":
        state = (cache["conv"], cache["ssm"]) if cache is not None else None
        y, (conv_s, ssm_s) = M.mamba_apply(p["mamba"], h, cfg, state=state,
                                           decode=(mode == "decode"))
        new_cache = {"conv": conv_s, "ssm": ssm_s} if cache is not None else None
    elif kind == "rwkv":
        x_prev = cache["x_tm"] if cache is not None else None
        st = cache["state"] if cache is not None else None
        y, (last_x, st_new) = R.time_mix(p["rwkv"], h, cfg, x_prev=x_prev,
                                         state=st, decode=(mode == "decode"))
        new_cache = dict(cache) if cache is not None else None
        if new_cache is not None:
            new_cache["x_tm"] = last_x
            new_cache["state"] = st_new
    x = x + y

    h2 = L.apply_norm(cfg, p["norm2"], x)
    if kind == "rwkv":
        x_prev_cm = cache["x_cm"] if cache is not None else None
        f, last_cm = R.channel_mix(p["rwkv"], h2, cfg, x_prev=x_prev_cm)
        if new_cache is not None:
            new_cache["x_cm"] = last_cm
    elif _is_moe_layer(cfg, j):
        f, aux = moe_dispatch(p["ffn"], h2, cfg, impl=impl)
        f = shd.constrain_ctx(f, "batch", "seq", None)
    else:
        f = ffn_apply(p["ffn"], h2, cfg, impl=impl)
    return x + f, new_cache, aux


def stack_apply(params, x, cfg, *, mode: str, positions, caches=None,
                cur_len=None, impl: str = "chunked", mask_mode: str = "causal",
                pattern: Optional[Tuple[str, ...]] = None, remat: bool = False):
    """Run the full layer stack.  Returns (x, new_caches, aux_sums)."""
    pattern = pattern or cfg.layer_pattern

    def group_fn(x, group_params, group_caches):
        aux_sum = {"load_balance_loss": 0.0, "router_z_loss": 0.0}
        new_caches = {}
        for j, kind in enumerate(pattern):
            cj = group_caches.get(f"l{j}") if group_caches is not None else None
            x, nc, aux = layer_apply(
                group_params[f"l{j}"], x, cfg, kind, j, mode=mode,
                positions=positions, cache=cj, cur_len=cur_len, impl=impl,
                mask_mode=mask_mode)
            if nc is not None:
                new_caches[f"l{j}"] = nc
            for k_, v_ in aux.items():
                if k_ in aux_sum:
                    aux_sum[k_] = aux_sum[k_] + v_
        return x, new_caches, aux_sum

    if remat:
        group_fn = jax.checkpoint(group_fn)

    def scan_body(carry, xs):
        x, aux_acc = carry
        gp, gc = xs
        x, new_caches, aux = group_fn(x, gp, gc)
        aux_acc = {k_: aux_acc[k_] + aux[k_] for k_ in aux_acc}
        return (x, aux_acc), new_caches

    aux0 = {"load_balance_loss": jnp.zeros((), jnp.float32),
            "router_z_loss": jnp.zeros((), jnp.float32)}
    (x, aux), new_caches = scan_or_unroll(
        scan_body, (x, aux0), (params["layers"], caches))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, (new_caches if caches is not None else None), aux
