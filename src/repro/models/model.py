"""ModelBundle: a uniform functional API over every assigned architecture.

    bundle = build_model(cfg)
    params, specs = bundle.init(key)
    loss, metrics = bundle.loss_fn(params, batch)            # train shapes
    logits = bundle.prefill(params, batch)                   # prefill shapes
    logits, caches = bundle.decode_step(params, caches, batch)  # decode shapes
    bundle.input_specs(shape_cfg) / bundle.cache_init(...)   # dry-run stand-ins

Families: text decoders (dense/moe/hybrid/ssm/vlm) share one implementation
(vlm prepends stub patch embeddings); audio is encoder-decoder; climber (the
paper's GR model) is provided by repro.core.climber and dispatched here.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.models import encdec as E
from repro.models import layers as L
from repro.models import transformer as T
from repro.types import ModelConfig, ShapeConfig


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable                    # (params, batch) -> (loss, metrics)
    prefill: Callable                    # (params, batch) -> logits
    decode_step: Callable                # (params, caches, batch) -> (logits, caches)
    input_specs: Callable                # (ShapeConfig) -> {name: ShapeDtypeStruct}
    input_logical: Callable              # (ShapeConfig) -> {name: logical tuple}
    cache_init: Callable                 # (batch, max_len) -> (caches, specs)
    # split-forward serving surface (GR models; None for decode families):
    # prefill == score_candidates(params, encode_history(params, hist), cand)
    encode_history: Optional[Callable] = None   # (params, batch) -> HistoryKV
    score_candidates: Optional[Callable] = None  # (params, kv, cand) -> scores
    history_kv_specs: Optional[Callable] = None  # (params, n_hist, b) -> specs
    # incremental suffix extension: re-encode only the changed window suffix
    # + side token against a cached HistoryKV (PDA v2 stale-hit path)
    extend_history: Optional[Callable] = None   # (params, kv, batch, *, prefix_len) -> HistoryKV
    # generative decode surface (ISSUE 8): one vocab-scoring step against
    # padded beam caches + the KV append that grows them — a decode step is
    # score_candidates(M=V) at the beam's current length plus append_token
    decode_logits: Optional[Callable] = None    # (params, kv, cand, lengths) -> probs [B,M,T]
    append_token: Optional[Callable] = None     # (params, kv, tok, lengths) -> HistoryKV


def cross_entropy(logits, targets, mask):
    """Mean CE over masked positions; computed in f32."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# text decoder family (dense / moe / hybrid / ssm / vlm)
# ---------------------------------------------------------------------------

def _build_text(cfg: ModelConfig) -> ModelBundle:
    is_vlm = cfg.modality == "vision"

    def init(key):
        k1, k2 = jax.random.split(key)
        params = {"embed": L.embed_init(k1, cfg),
                  "stack": T.stack_init(k2, cfg)}
        if is_vlm:
            k3 = jax.random.fold_in(key, 3)
            params["projector"] = L.dense_init(k3, (cfg.d_model, cfg.d_model),
                                               ("embed", "act_model"))
        return L.split_params(params)

    def embed_inputs(params, batch):
        x = L.embed(params["embed"], batch["tokens"], cfg)
        if is_vlm and "patch_embeds" in batch:
            pe = jnp.einsum("bpd,de->bpe", batch["patch_embeds"].astype(x.dtype),
                            params["projector"])
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def forward(params, batch, *, mode, impl="chunked", remat=False,
                caches=None, cur_len=None):
        x = embed_inputs(params, batch)
        x = shd.constrain_ctx(x, "batch", None, None)
        b, s = x.shape[:2]
        if mode == "decode":
            positions = jnp.broadcast_to(batch["cur_index"][None, None], (b, 1))
        else:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, new_caches, aux = T.stack_apply(
            params["stack"], x, cfg, mode=mode, positions=positions,
            caches=caches, cur_len=cur_len, impl=impl, remat=remat)
        logits = L.unembed(params["embed"], x, cfg)
        logits = shd.constrain_ctx(logits, "batch", None, "vocab")
        return logits, new_caches, aux

    def loss_fn(params, batch, impl="chunked"):
        logits, _, aux = forward(params, batch, mode="train", impl=impl,
                                 remat=True)
        n_front = batch["patch_embeds"].shape[1] if (is_vlm and "patch_embeds"
                                                     in batch) else 0
        lg = logits[:, n_front:]
        targets = batch["tokens"][:, 1:]
        mask = jnp.ones_like(targets, jnp.float32)
        loss = cross_entropy(lg[:, :-1], targets, mask)
        total = loss + aux["load_balance_loss"] + aux["router_z_loss"]
        return total, {"ce_loss": loss, **aux}

    def prefill(params, batch, impl="chunked", caches=None):
        logits, new_caches, _ = forward(params, batch, mode="prefill",
                                        impl=impl, caches=caches,
                                        cur_len=batch["tokens"].shape[1])
        if caches is not None:
            return logits, new_caches
        return logits

    def decode_step(params, caches, batch, impl="reference"):
        cur_len = batch["cur_index"] + 1
        logits, new_caches, _ = forward(params, batch, mode="decode",
                                        impl=impl, caches=caches,
                                        cur_len=cur_len)
        return logits, new_caches

    def cache_init(batch, max_len, dtype=jnp.bfloat16, quant=False):
        return T.init_caches(cfg, batch, max_len, dtype=dtype, quant=quant)

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        if shape.kind == "decode":
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                     "cur_index": jax.ShapeDtypeStruct((), jnp.int32)}
            return specs
        s = shape.seq_len
        specs = {}
        if is_vlm:
            p = min(cfg.frontend_tokens, s // 2)
            specs["patch_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                                         jnp.bfloat16)
            s = s - p
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs

    def input_logical(shape: ShapeConfig):
        lg = {"tokens": ("batch", None)}
        if shape.kind == "decode":
            lg["cur_index"] = ()
        elif is_vlm:
            lg["patch_embeds"] = ("batch", None, None)
        return lg

    return ModelBundle(cfg, init, loss_fn, prefill, decode_step,
                       input_specs, input_logical, cache_init)


# ---------------------------------------------------------------------------
# audio encoder-decoder family
# ---------------------------------------------------------------------------

def _frames_for(cfg: ModelConfig, seq_len: int) -> int:
    return max(8, seq_len // 4)      # stub conv frontend downsamples 4x


def _build_audio(cfg: ModelConfig) -> ModelBundle:

    def init(key):
        k1, k2 = jax.random.split(key)
        params = {"embed": L.embed_init(k1, cfg), **E.encdec_init(k2, cfg)}
        return L.split_params(params)

    def loss_fn(params, batch, impl="chunked"):
        enc_out = E.encode(params, batch["frames"], cfg, impl=impl)
        x = L.embed(params["embed"], batch["tokens"], cfg)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, _ = E.decode_stack(params, x, enc_out, cfg, mode="train",
                              positions=positions, impl=impl, remat=True)
        logits = L.unembed(params["embed"], x, cfg)
        targets = batch["tokens"][:, 1:]
        loss = cross_entropy(logits[:, :-1], targets,
                             jnp.ones_like(targets, jnp.float32))
        return loss, {"ce_loss": loss}

    def prefill(params, batch, impl="chunked", caches=None):
        enc_out = E.encode(params, batch["frames"], cfg, impl=impl)
        x = L.embed(params["embed"], batch["tokens"], cfg)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, new_caches = E.decode_stack(params, x, enc_out, cfg, mode="prefill",
                                       positions=positions, caches=caches,
                                       cur_len=s, impl=impl)
        logits = L.unembed(params["embed"], x, cfg)
        if caches is not None:
            xk, xv = E.cross_kv(params, enc_out, cfg)
            new_caches = {**new_caches, "xk": xk, "xv": xv}
            return logits, new_caches
        return logits

    def decode_step(params, caches, batch, impl="reference"):
        x = L.embed(params["embed"], batch["tokens"], cfg)
        b = x.shape[0]
        positions = jnp.broadcast_to(batch["cur_index"][None, None], (b, 1))
        x, new_caches = E.decode_stack(params, x, None, cfg, mode="decode",
                                       positions=positions, caches=caches,
                                       cur_len=batch["cur_index"] + 1, impl=impl)
        logits = L.unembed(params["embed"], x, cfg)
        return logits, new_caches

    def cache_init(batch, max_len, dtype=jnp.bfloat16, n_frames=None,
                   quant=False):
        del quant  # enc-dec caches stay bf16 (cross-attn K/V reused per step)
        n_frames = n_frames or _frames_for(cfg, 4096)
        return E.init_dec_caches(cfg, batch, max_len, n_frames, dtype)

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        if shape.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                    "cur_index": jax.ShapeDtypeStruct((), jnp.int32)}
        f = _frames_for(cfg, shape.seq_len)
        return {"frames": jax.ShapeDtypeStruct((b, f, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}

    def input_logical(shape: ShapeConfig):
        lg = {"tokens": ("batch", None)}
        if shape.kind == "decode":
            lg["cur_index"] = ()
        else:
            lg["frames"] = ("batch", None, None)
        return lg

    return ModelBundle(cfg, init, loss_fn, prefill, decode_step,
                       input_specs, input_logical, cache_init)


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "climber":
        from repro.core.climber import build_climber
        return build_climber(cfg)
    if cfg.enc_dec:
        return _build_audio(cfg)
    return _build_text(cfg)
