"""Sort-based fixed-capacity top-k mixture of experts.

Dispatch is the standard sort/scatter formulation (no [T,E,C] one-hot
blow-up, which would be ~100TB at kimi-k2 scale):

  1. router logits -> top_k expert ids + gates per token
  2. flatten (token, k) assignments, sort by expert id
  3. position-within-expert via running counts; drop past capacity
  4. scatter rows into a [E, C, d] buffer, batched expert GEMMs
  5. gather back, gate-weight, sum over k

The [E, C, d] buffer carries logical axes ("experts", None, None) so experts
shard over the data/pod axes (expert parallelism); the scatter/gather lower to
all-to-all style collectives under GSPMD — visible in the roofline's
collective term and targeted by the §Perf hillclimb.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.models import layers as L
from repro.models.ffn import ffn_init, ffn_apply


def moe_init(key, cfg, stacked: int = 0):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 5)
    n_gate = cfg.activation == "swiglu"
    shape_up = (e, d, f)
    logical_up = ("experts", "embed", "expert_mlp")
    p = {
        "router": L.dense_init(ks[0], (d, e), ("embed", None),
                               stacked=stacked, dtype=jnp.float32),
        "w_up": L.dense_init(ks[1], shape_up, logical_up, stacked=stacked,
                             fan_in_axes=(1,)),
        "w_down": L.dense_init(ks[2], (e, f, d), ("experts", "expert_mlp", "embed"),
                               stacked=stacked, fan_in_axes=(1,)),
    }
    if n_gate:
        p["w_gate"] = L.dense_init(ks[3], shape_up, logical_up, stacked=stacked,
                                   fan_in_axes=(1,))
    if m.num_shared_experts:
        p["shared"] = ffn_init(ks[4], cfg, d_ff=f * m.num_shared_experts,
                               stacked=stacked)
    return p


def _capacity(n_tokens: int, m) -> int:
    c = int(np.ceil(n_tokens * m.top_k * m.capacity_factor / m.num_experts))
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8


def moe_dispatch(params, x, cfg, impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """Dispatch-mode switch: GSPMD scatter/gather vs explicit all-to-all
    (flags.MOE_DISPATCH, requires an active mesh context)."""
    from repro import flags
    from repro import sharding as shd
    active = shd._ACTIVE.get()
    if flags.MOE_DISPATCH.get() == "a2a" and active is not None:
        mesh, _rules = active
        # a2a shards tokens over EVERY mesh axis; fall back when the token
        # count doesn't divide (e.g. single-token decode steps)
        if cfg.moe.num_experts % mesh.shape["data"] == 0 and \
                int(np.prod(x.shape[:2])) % mesh.size == 0:
            return moe_apply_a2a(params, x, cfg, mesh=mesh, axis="data",
                                 impl=impl)
    return moe_apply(params, x, cfg, impl=impl)


def moe_apply(params, x, cfg, impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """x [B,S,d] -> (out [B,S,d], aux {load_balance_loss, router_z_loss, ...})."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    cap = _capacity(t, m)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, k)            # [t,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch-style) ----
    me = probs.mean(axis=0)                                 # mean router prob
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch ----
    flat_expert = expert_idx.reshape(-1)                    # [t*k]
    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    token_of = order // k                                   # source token row
    counts = jnp.zeros((e,), jnp.int32).at[sorted_expert].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[sorted_expert]
    keep = pos < cap
    dest = jnp.where(keep, sorted_expert * cap + pos, e * cap)  # overflow -> scratch row

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xt[token_of])
    buf = buf[:-1].reshape(e, cap, d)
    buf = shd.constrain_ctx(buf, "experts", None, None)

    # ---- expert GEMMs ----
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    if "w_gate" in params:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        h = jax.nn.silu(g.astype(jnp.float32)) * up.astype(jnp.float32)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32))
    h = h.astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = shd.constrain_ctx(out_buf, "experts", None, None).reshape(e * cap, d)

    # ---- combine ----
    gathered = jnp.where(keep[:, None], out_buf[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    combined = jnp.zeros((t, d), x.dtype).at[token_of].add(
        gathered * gates.reshape(-1)[order][:, None].astype(x.dtype))

    if "shared" in params:
        combined = combined + ffn_apply(params["shared"], xt, cfg, impl=impl).reshape(t, d)

    aux = {"load_balance_loss": load_balance * m.load_balance_loss,
           "router_z_loss": z_loss * m.router_z_loss,
           "dropped_fraction": 1.0 - keep.mean()}
    return combined.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# all-to-all expert-parallel dispatch (§Perf hillclimb: the GSPMD scatter
# formulation above lowers to full-dispatch-buffer all-reduces; this
# shard_map path exchanges only the routed tokens over the ICI).
# ---------------------------------------------------------------------------

def moe_apply_a2a(params, x, cfg, *, mesh, axis: str = "data",
                  impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    """Expert-parallel MoE with explicit all_to_all dispatch.

    Experts are sharded over ``axis`` (E % n_shards == 0).  Each shard
    routes its local tokens, builds a [n_shards, E_local, C, d] send buffer
    (capacity per (shard, expert)), exchanges it with all_to_all, runs its
    local experts, and reverses the exchange.  ICI traffic per layer is
    2 * tokens * top_k * d * capacity_factor bytes — independent of E.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    n_shards = mesh.shape[axis]
    assert e % n_shards == 0, (e, n_shards)
    e_local = e // n_shards
    # tokens are sharded over every mesh axis (data x model x pod): each
    # device runs its own token slice against its data-shard's experts, so
    # expert GEMM FLOPs stay 1/devices each — no model-axis replication.
    token_axes = tuple(a for a in mesh.axis_names)
    t_local = (b * s) // mesh.size
    # per (shard, global expert) capacity
    cap = int(np.ceil(t_local * k * m.capacity_factor / e))
    cap = max(4, -(-cap // 4) * 4)

    router = params["router"]
    w_up, w_down = params["w_up"], params["w_down"]
    w_gate = params.get("w_gate")
    has_gate = w_gate is not None
    if not has_gate:
        w_gate = w_up  # placeholder with identical sharding

    def local_fn(xt, router, w_up, w_gate, w_down):
        # xt [t_local, d]; expert weights [e_local, d, f] (this shard's)
        tl = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, expert_idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        flat_expert = expert_idx.reshape(-1)
        order = jnp.argsort(flat_expert)
        sorted_expert = flat_expert[order]
        token_of = order // k
        counts = jnp.zeros((e,), jnp.int32).at[sorted_expert].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(tl * k) - starts[sorted_expert]
        keep = pos < cap
        dest = jnp.where(keep, sorted_expert * cap + pos, e * cap)

        send = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[token_of])
        send = send[:-1].reshape(n_shards, e_local * cap, d)
        # exchange: shard i sends its tokens for shard j's experts to shard j
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        # recv [n_shards, e_local*cap, d] -> [e_local, n_shards*cap, d]
        buf = recv.reshape(n_shards, e_local, cap, d).transpose(1, 0, 2, 3) \
            .reshape(e_local, n_shards * cap, d)

        up = jnp.einsum("ecd,edf->ecf", buf, w_up)
        if has_gate:
            g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
            h = jax.nn.silu(g.astype(jnp.float32)) * up.astype(jnp.float32)
        else:
            h = jax.nn.gelu(up.astype(jnp.float32))
        out = jnp.einsum("ecf,efd->ecd", h.astype(xt.dtype), w_down)

        # reverse exchange
        back = out.reshape(e_local, n_shards, cap, d).transpose(1, 0, 2, 3) \
            .reshape(n_shards, e_local * cap, d)
        got = jax.lax.all_to_all(back, axis, 0, 0, tiled=False)
        got = got.reshape(e * cap, d)
        gathered = jnp.where(keep[:, None],
                             got[jnp.clip(dest, 0, e * cap - 1)], 0.0)
        combined = jnp.zeros((tl, d), xt.dtype).at[token_of].add(
            gathered * gates.reshape(-1)[order][:, None].astype(xt.dtype))

        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[flat_expert].add(1.0) / (tl * k)
        lb = jax.lax.pmean(e * jnp.sum(me * ce), token_axes)
        zl = jax.lax.pmean(jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
                           token_axes)
        dropped = jax.lax.pmean(1.0 - keep.mean(), token_axes)
        return combined, lb, zl, dropped

    xt = x.reshape(b * s, d)
    combined, lb, zl, dropped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(token_axes, None), P(None, None), P(axis, None, None),
                  P(axis, None, None), P(axis, None, None)),
        out_specs=(P(token_axes, None), P(), P(), P()),
        check_vma=False,
    )(xt, router, w_up, w_gate, w_down)

    if "shared" in params:
        combined = combined + ffn_apply(params["shared"], xt, cfg,
                                        impl=impl).reshape(b * s, d)
    aux = {"load_balance_loss": lb * m.load_balance_loss,
           "router_z_loss": zl * m.router_z_loss,
           "dropped_fraction": dropped}
    return combined.reshape(b, s, d), aux
