"""Encoder-decoder transformer (seamless-m4t style) for the [audio] arch.

The audio frontend (mel-spectrogram + conformer conv feature extractor) is a
stub per the assignment: the model consumes precomputed frame embeddings
[B, n_frames, d].  Encoder = bidirectional self-attention; decoder = causal
self-attention + cross-attention to the encoder output.  Decode carries a
self-attention KV cache plus the precomputed cross-attention K/V.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.ffn import ffn_init, ffn_apply


def encdec_init(key, cfg):
    ks = jax.random.split(key, 6)
    ng_e, ng_d = cfg.n_enc_layers, cfg.n_layers
    enc_layers = {
        "norm1": L.norm_init(cfg, cfg.d_model, stacked=ng_e),
        "attn": A.qkv_init(ks[0], cfg, stacked=ng_e),
        "norm2": L.norm_init(cfg, cfg.d_model, stacked=ng_e),
        "ffn": ffn_init(ks[1], cfg, stacked=ng_e),
    }
    dec_layers = {
        "norm1": L.norm_init(cfg, cfg.d_model, stacked=ng_d),
        "self_attn": A.qkv_init(ks[2], cfg, stacked=ng_d),
        "norm_x": L.norm_init(cfg, cfg.d_model, stacked=ng_d),
        "cross_attn": A.qkv_init(ks[3], cfg, stacked=ng_d),
        "norm2": L.norm_init(cfg, cfg.d_model, stacked=ng_d),
        "ffn": ffn_init(ks[4], cfg, stacked=ng_d),
    }
    return {
        "frame_proj": L.dense_init(ks[5], (cfg.d_model, cfg.d_model),
                                   ("embed", "embed_fsdp")),
        "enc": enc_layers,
        "enc_norm": L.norm_init(cfg, cfg.d_model),
        "dec": dec_layers,
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }


def encode(params, frames, cfg, *, impl="chunked"):
    """frames [B,F,d] (stub frontend embeddings) -> encoder states [B,F,d]."""
    x = jnp.einsum("bfd,de->bfe", frames, params["frame_proj"])
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, p):
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions)
        x = x + A.project_out(p["attn"], A.attention(q, k, v, "full", impl=impl))
        h2 = L.apply_norm(cfg, p["norm2"], x)
        return x + ffn_apply(p["ffn"], h2, cfg, impl=impl), None

    from repro.models.transformer import scan_or_unroll
    x, _ = scan_or_unroll(body, x, params["enc"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def cross_kv(params, enc_out, cfg):
    """Precompute per-decoder-layer cross K/V (stacked): [L,B,F,Hkv,D] x2."""
    def per_layer(p):
        pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]), enc_out.shape[:2])
        _, k, v = A.project_qkv(p["cross_attn"], enc_out, cfg, pos)
        return k, v
    return jax.vmap(per_layer)(params["dec"])


def decode_stack(params, x, enc_out, cfg, *, mode, positions, caches=None,
                 cur_len=None, impl="chunked", remat=False):
    """Decoder over targets x [B,S,d].  caches: {"k","v"} stacked self caches
    + {"xk","xv"} cross K/V (precomputed for decode)."""

    def body_fn(x, p, cache):
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["self_attn"], h, cfg, positions)
        new_cache = None
        if mode == "decode":
            clen = cache["k"].shape[1]
            slot = positions[:, 0]
            k_c = jax.vmap(lambda c, kk, s: jax.lax.dynamic_update_slice_in_dim(
                c, kk, s, axis=0))(cache["k"], k, slot)
            v_c = jax.vmap(lambda c, vv, s: jax.lax.dynamic_update_slice_in_dim(
                c, vv, s, axis=0))(cache["v"], v, slot)
            o = A.decode_attention(q, k_c, v_c, cur_len)
            new_cache = {"k": k_c, "v": v_c, "xk": cache["xk"], "xv": cache["xv"]}
        else:
            o = A.attention(q, k, v, "causal", impl=impl)
            if cache is not None:
                pad = cache["k"].shape[1] - k.shape[1]
                new_cache = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "xk": cache["xk"], "xv": cache["xv"]}
        x = x + A.project_out(p["self_attn"], o)

        # cross attention (full mask over encoder frames)
        hx = L.apply_norm(cfg, p["norm_x"], x)
        qx = jnp.einsum("bsd,dhk->bshk", hx, p["cross_attn"]["wq"])
        if "bq" in p["cross_attn"]:
            qx = qx + p["cross_attn"]["bq"]
        qx = L.rope(qx, positions, cfg.rope_theta)
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
            ox = A.decode_attention(qx, xk, xv, xk.shape[1])
        else:
            pos_e = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                     enc_out.shape[:2])
            _, xk, xv = A.project_qkv(p["cross_attn"], enc_out, cfg, pos_e)
            ox = A.attention(qx, xk, xv, "full", impl=impl)
        x = x + A.project_out(p["cross_attn"], ox)

        h2 = L.apply_norm(cfg, p["norm2"], x)
        return x + ffn_apply(p["ffn"], h2, cfg, impl=impl), new_cache

    if remat:
        body_fn = jax.checkpoint(body_fn)

    def scan_body(x, xs):
        p, cache = xs
        return body_fn(x, p, cache)

    from repro.models.transformer import scan_or_unroll
    x, new_caches = scan_or_unroll(scan_body, x, (params["dec"], caches))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, (new_caches if caches is not None else None)


def init_dec_caches(cfg, batch: int, max_len: int, n_frames: int,
                    dtype=jnp.bfloat16):
    """Decoder self caches + cross K/V placeholders, stacked over layers."""
    hd = cfg.head_dim
    ng = cfg.n_layers
    shape_self = (ng, batch, max_len, cfg.n_kv_heads, hd)
    shape_cross = (ng, batch, n_frames, cfg.n_kv_heads, hd)
    logical = ("stack", "cache_batch", "cache_seq", "cache_heads", None)
    caches = {
        "k": L.Param(jnp.zeros(shape_self, dtype), logical),
        "v": L.Param(jnp.zeros(shape_self, dtype), logical),
        "xk": L.Param(jnp.zeros(shape_cross, dtype), logical),
        "xv": L.Param(jnp.zeros(shape_cross, dtype), logical),
    }
    return L.split_params(caches)
