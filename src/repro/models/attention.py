"""GQA attention with the mask modes FLAME needs.

Mask modes
----------
``causal``   standard autoregressive
``full``     bidirectional (encoder / cross-attention)
``sliding``  causal within ``window``
``sumi``     FLAME's single-user-multi-items mask: the first ``n_history``
             positions are causal among themselves; the remaining candidate
             positions attend to all history and to themselves only —
             candidates never see each other (HSTU-style parallel scoring).

Implementations
---------------
``reference``  materialized scores — oracle + small shapes only
``chunked``    flash-style online softmax over KV chunks in pure jnp; used by
               the dry-run (no O(S^2) temporaries).  Sliding mode slices only
               the in-window KV chunks, so FLOPs scale with S*W, not S^2.
``pallas``     the mask-aware flash-attention Pallas kernel
               (kernels/flash_attention) — TPU target.
``fused``      the FKE candidate-scoring engine (kernels/fused_score): the
               cached-candidate SUMI path runs a two-segment fused kernel
               that can read quantized pool KV and the DSO's dedup row
               index directly; other mask/offset combinations fall back to
               ``chunked``.
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import flags
from repro.models import layers as L


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def mask_value(q_pos, k_pos, mode: str, *, window: int = 0, n_history: int = 0):
    """Boolean mask (True = attend) broadcast over q_pos x k_pos index arrays."""
    if mode == "full":
        return jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if mode == "causal":
        return k_pos <= q_pos
    if mode == "sliding":
        return (k_pos <= q_pos) & (q_pos - k_pos < window)
    if mode == "sumi":
        q_is_hist = q_pos < n_history
        k_is_hist = k_pos < n_history
        hist_mask = k_pos <= q_pos                      # causal (k<=q<n_hist => k in history)
        cand_mask = k_is_hist | (k_pos == q_pos)        # history + self only
        return jnp.where(q_is_hist, hist_mask, cand_mask)
    raise ValueError(mode)


def make_mask(s_q: int, s_k: int, mode: str, *, window: int = 0,
              n_history: int = 0, q_offset: int = 0):
    q = jnp.arange(s_q)[:, None] + q_offset
    k = jnp.arange(s_k)[None, :]
    return mask_value(q, k, mode, window=window, n_history=n_history)


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def qkv_init(key, cfg, stacked: int = 0, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, cfg.n_heads, hd), ("embed", "heads", None),
                           stacked=stacked, fan_in_axes=(0,)),
        "wk": L.dense_init(ks[1], (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None),
                           stacked=stacked, fan_in_axes=(0,)),
        "wv": L.dense_init(ks[2], (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", None),
                           stacked=stacked, fan_in_axes=(0,)),
        "wo": L.dense_init(ks[3], (cfg.n_heads, hd, d), ("heads", None, "embed"),
                           stacked=stacked, fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = L.zeros_init((cfg.n_heads, hd), ("heads", None), stacked=stacked)
        p["bk"] = L.zeros_init((cfg.n_kv_heads, hd), ("kv_heads", None), stacked=stacked)
        p["bv"] = L.zeros_init((cfg.n_kv_heads, hd), ("kv_heads", None), stacked=stacked)
    return p


def project_qkv(params, x, cfg, positions):
    """x [B,S,d] -> q [B,S,H,D], k/v [B,S,Hkv,D], RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_out(params, o):
    """o [B,S,H,D] -> [B,S,d]."""
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


# ---------------------------------------------------------------------------
# reference attention (materialized)
# ---------------------------------------------------------------------------

def reference_attention(q, k, v, mode: str, *, window: int = 0,
                        n_history: int = 0, q_offset: int = 0,
                        temperature=None):
    """q [B,Sq,H,D], k/v [B,Sk,Hkv,D] -> [B,Sq,H,D].  GQA via head groups."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / np.sqrt(d)
    if temperature is not None:
        scores = scores / temperature
    mask = make_mask(sq, k.shape[1], mode, window=window,
                     n_history=n_history, q_offset=q_offset)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked flash-style attention (pure jnp, no O(S^2) memory)
# ---------------------------------------------------------------------------

def _visible_kv_blocks(mode: str, qi: int, *, q_chunk: int, k_chunk: int,
                       nk: int, sk: int, n_history: int,
                       q_offset: int) -> List[int]:
    """KV chunk indices a q chunk can see under a static mask (exact block
    skip, mirroring the pallas kernel's grid trimming).

    ``causal`` (and ``sumi`` with ``q_offset == 0``, whose candidate rows
    attend only at-or-below their own position): chunks up to the one holding
    the q chunk's last diagonal element.  ``sumi`` with ``q_offset > 0``
    (every query is a candidate): the history chunks plus the chunk(s)
    holding the queries' own keys — per-candidate work is O(n_history +
    q_chunk), independent of where the candidate block sits.
    """
    hi = min(q_offset + (qi + 1) * q_chunk, sk)        # exclusive col bound
    n_vis = min(nk, max(1, -(-hi // k_chunk)))
    if mode == "sumi" and q_offset:
        nhb = min(nk, -(-min(n_history, sk) // k_chunk)) if n_history else 0
        d0 = min(nk - 1, (q_offset + qi * q_chunk) // k_chunk)
        return list(range(nhb)) + [j for j in range(d0, n_vis) if j >= nhb]
    return list(range(n_vis))


def chunked_attention(q, k, v, mode: str, *, window: int = 0, n_history: int = 0,
                      q_chunk: int = 1024, k_chunk: int = 1024,
                      q_offset: int = 0):
    """Online-softmax attention over KV chunks.

    Shapes as in reference_attention.  KV chunks that a q chunk provably
    cannot see under the static mask are skipped outright, so FLOPs match
    the mask support rather than the dense S^2 rectangle:

      ``sliding``  only the in-window KV slice per q chunk (S*window);
      ``causal``   chunks at-or-below the diagonal (~S^2/2, exact skip);
      ``sumi``     ditto — candidate rows never look above their own
                   position, and the cached-candidate path (``q_offset`` >
                   0) touches history chunks + the self diagonal only;
      ``full``     every chunk (no structure to exploit).

    Skipped chunks are numerically inert in the online softmax (their masked
    scores contribute exact zeros), so outputs are identical to the
    visit-everything formulation.

    ``q_offset`` shifts the query positions against the KV positions — the
    cached-history serving paths run suffix/candidate queries against cached
    K/V rows plus their own, so q row i sits at absolute position
    ``q_offset + i``.  Supported for ``sumi`` (candidate scoring) and
    ``causal`` (incremental history extension).
    """
    if q_offset and mode not in ("sumi", "causal"):
        # the sliding fast path slices KV around un-offset q positions —
        # fail loudly rather than window the wrong region (mirrors the
        # pallas kernel's guard)
        raise NotImplementedError(
            f"q_offset is only supported for mode in ('sumi', 'causal'), "
            f"got {mode!r}")
    b, sq, h, d = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    nq = -(-sq // q_chunk)
    pad_q = nq * q_chunk - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    scale = 1.0 / np.sqrt(d)

    if mode == "sliding" and window and window < sk:
        return _sliding_chunked(q, k, v, window, q_chunk, sq, pad_q)

    nk = -(-sk // k_chunk)
    pad_k = nk * k_chunk - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    ks = k.reshape(b, nk, k_chunk, hkv, d)
    vs = v.reshape(b, nk, k_chunk, hkv, d)

    def q_block(qi, q_blk, ids, k_sel, v_sel):
        """Online softmax of one q chunk over the selected KV chunks.
        ``qi`` may be a Python int (per-chunk block lists) or traced (the
        uniform-visibility scan path)."""
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        qf = q_blk.astype(jnp.float32).reshape(b, q_chunk, hkv, g, d) * scale

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_blk.astype(jnp.float32))
            msk = mask_value(q_pos[:, None], k_pos[None, :], mode,
                             window=window, n_history=n_history)
            msk = msk & (k_pos[None, :] < sk)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ids, k_sel, v_sel),
            unroll=flags.unroll_scans())
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(o, 3, 1).reshape(b, q_chunk, h, d)  # bhgqd->bqhgd

    if mode in ("causal", "sumi"):
        # python loop over q chunks: the visible-KV count varies per chunk,
        # so each iteration scans its own (static) block list — trace size
        # grows with nq, FLOPs shrink to the mask support
        def one(qi: int):
            ids = jnp.asarray(
                _visible_kv_blocks(mode, qi, q_chunk=q_chunk,
                                   k_chunk=k_chunk, nk=nk, sk=sk,
                                   n_history=n_history, q_offset=q_offset),
                jnp.int32)
            k_sel = jnp.moveaxis(jnp.take(ks, ids, axis=1), 1, 0)
            v_sel = jnp.moveaxis(jnp.take(vs, ids, axis=1), 1, 0)
            return q_block(qi, q[:, qi * q_chunk:(qi + 1) * q_chunk],
                           ids, k_sel, v_sel)
        out = jnp.concatenate([one(qi) for qi in range(nq)], axis=1)
    else:
        # full mode sees every KV chunk from every q chunk: one outer scan
        # keeps trace size O(1) in nq (no per-chunk specialization to gain)
        ids = jnp.arange(nk, dtype=jnp.int32)
        k_all = jnp.moveaxis(ks, 1, 0)
        v_all = jnp.moveaxis(vs, 1, 0)
        q_blocks = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)
        _, out = jax.lax.scan(
            lambda _, args: (None, q_block(args[0], args[1],
                                           ids, k_all, v_all)),
            None, (jnp.arange(nq), q_blocks), unroll=flags.unroll_scans())
        out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq].astype(q.dtype)


def _sliding_chunked(q, k, v, window: int, q_chunk: int, sq: int, pad_q: int):
    """Sliding-window chunked attention: per q chunk slice KV[start:start+W+C].

    Compute is O(S * (W + C)) instead of O(S^2)."""
    b, sq_p, h, d = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    nq = sq_p // q_chunk
    span = window + q_chunk  # kv span each q chunk can see
    span = min(span, sk)
    scale = 1.0 / np.sqrt(d)

    def q_block(qi, q_blk):
        q_pos = qi * q_chunk + jnp.arange(q_chunk)
        start = jnp.clip(qi * q_chunk + q_chunk - span, 0, max(sk - span, 0))
        k_blk = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        k_pos = start + jnp.arange(span)
        qf = q_blk.astype(jnp.float32).reshape(b, q_chunk, hkv, g, d) * scale
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_blk.astype(jnp.float32))
        msk = mask_value(q_pos[:, None], k_pos[None, :], "sliding", window=window)
        msk = msk & (k_pos[None, :] < sk)
        s = jnp.where(msk[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", w, v_blk.astype(jnp.float32))
        return jnp.moveaxis(o, 3, 1).reshape(b, q_chunk, h, d)

    q_blocks = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)
    _, out = jax.lax.scan(
        lambda _, args: (None, q_block(*args)), None,
        (jnp.arange(nq), q_blocks), unroll=flags.unroll_scans())
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# single-token decode attention (memory-bound gather; no kernel needed)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """q [B,1,H,D]; caches [B,Smax,Hkv,D]; cur_len = tokens valid in cache
    (including the new one).  Sliding window masks positions older than W."""
    b, _, h, d = q.shape
    smax = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) / np.sqrt(d)
    pos = jnp.arange(smax)[None, :]
    cur = jnp.reshape(jnp.asarray(cur_len), (-1, 1))     # scalar or [B]
    valid = pos < cur
    if window:
        valid = valid & (pos >= cur - window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


def _masked_attention_pos(q, k, v, q_pos, k_pos, mode: str, *, window: int):
    """Attention with explicit absolute positions (context-parallel local
    shards).  q [B,Sq,H,D], k/v [B,Sk,Hkv,D]; q_pos [Sq], k_pos [Sk]."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) / np.sqrt(d)
    msk = mask_value(q_pos[:, None], k_pos[None, :], mode, window=window)
    msk = msk & (k_pos[None, :] >= 0)
    s = jnp.where(msk[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(msk.any(-1)[None, None, None, :, None], w, 0.0)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", w, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, d).astype(q.dtype)


def context_parallel_attention(q, k, v, mode: str, *, window: int, mesh,
                               seq_axis: str = "model"):
    """Context parallelism over ``seq_axis`` (shard_map, beyond-paper §Perf).

    q/k/v [B,S,H,D] with batch sharded over data/pod and S over ``seq_axis``.
      sliding: halo exchange — each shard ppermutes its last ``window`` K/V
               to the next shard; attention is fully local (exact for SWA).
      causal/full: K/V all-gathered over the seq axis; Q stays local.
    Compute uses all mesh axes; comm is O(window) or O(S*Hkv*D) per layer
    instead of O(S*d_model) activation all-reduces.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    n = mesh.shape[seq_axis]
    batch_axes = tuple(a for a in mesh.axis_names if a != seq_axis)
    s_total = q.shape[1]
    s_loc = s_total // n

    def local_fn(ql, kl, vl):
        idx = jax.lax.axis_index(seq_axis)
        off = idx * s_loc
        q_pos = off + jnp.arange(s_loc)
        if mode == "sliding" and window and window <= s_loc:
            perm = [(i, (i + 1) % n) for i in range(n)]
            k_halo = jax.lax.ppermute(kl[:, -window:], seq_axis, perm)
            v_halo = jax.lax.ppermute(vl[:, -window:], seq_axis, perm)
            kk = jnp.concatenate([k_halo, kl], axis=1)
            vv = jnp.concatenate([v_halo, vl], axis=1)
            k_pos = off - window + jnp.arange(window + s_loc)
            # shard 0's halo wraps from the last shard -> masked (k_pos < 0)
            return _masked_attention_pos(ql, kk, vv, q_pos, k_pos, "sliding",
                                         window=window)
        kk = jax.lax.all_gather(kl, seq_axis, axis=1, tiled=True)
        vv = jax.lax.all_gather(vl, seq_axis, axis=1, tiled=True)
        k_pos = jnp.arange(s_total)
        return _masked_attention_pos(ql, kk, vv, q_pos, k_pos, mode,
                                     window=window)

    spec = P(batch_axes, seq_axis, None, None)
    return shard_map(local_fn, mesh=mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_vma=False)(q, k, v)


def attention(q, k, v, mode: str, *, impl: str = "chunked", window: int = 0,
              n_history: int = 0, temperature=None, q_offset: int = 0):
    """Dispatch wrapper used by the transformer stack.

    ``impl="fused"`` is the FKE candidate-scoring engine
    (kernels/fused_score): the cached-candidate SUMI case (``q_offset > 0``
    — every query is a candidate against ``n_history`` cached rows plus its
    own key) splits the KV axis at ``n_history`` and runs the two-segment
    fused path without re-materializing the concatenation; other (mode,
    offset) combinations have no fused kernel and fall back to ``chunked``
    (the serving entry points in core/sumi.py call the fused ops directly
    with separate operands, so this route only serves callers that already
    concatenated)."""
    if impl == "fused":
        if mode == "sumi" and q_offset and q_offset == n_history \
                and k.shape[1] == n_history + q.shape[1]:
            from repro.kernels.fused_score import ops as fs_ops
            return fs_ops.fused_cached_attention(
                q, k[:, :n_history], v[:, :n_history],
                k[:, n_history:], v[:, n_history:],
                temperature=temperature)
        impl = "chunked"
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, mode, window=window,
                                      n_history=n_history, q_offset=q_offset)
    if impl == "cp":
        from repro import sharding as shd
        active = shd._ACTIVE.get()
        if active is not None and mode in ("sliding", "causal", "full"):
            mesh = active[0]
            if "model" in mesh.axis_names and \
                    q.shape[1] % mesh.shape["model"] == 0:
                return context_parallel_attention(q, k, v, mode,
                                                  window=window, mesh=mesh)
        impl = "chunked"
    if impl == "reference" or q.shape[1] * k.shape[1] <= 256 * 256:
        return reference_attention(q, k, v, mode, window=window,
                                   n_history=n_history, temperature=temperature,
                                   q_offset=q_offset)
    return chunked_attention(q, k, v, mode, window=window, n_history=n_history,
                             q_offset=q_offset)
