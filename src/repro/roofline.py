"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (lower bound per step):

    compute    = HLO_FLOPs            / (chips * peak_FLOP/s)
    memory     = HLO_bytes_accessed   / (chips * HBM_bw)
    collective = collective_bytes     / (chips * ICI link bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (per-partition
module, multiplied back to all chips).  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD HLO text and sum the result sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (all-reduce counted 2x: reduce-scatter + all-gather phases).

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), with N = active params —
the "useful compute" yardstick; HLO/MODEL ratio exposes remat & masked-FLOP
waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.types import HardwareSpec, TPU_V5E, ModelConfig, ShapeConfig

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every 'dtype[dims]' group in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind result bytes of collectives in post-partitioning HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.  %all-gather.3 = bf16[16,8192]{1,0} all-gather(...)
        m = re.match(r"%?[\w.\-]+ = (\(?[^)=]*\)?) ([\w\-]+)\(", s)
        if not m:
            continue
        typ, op = m.groups()
        # start variants: all-gather-start etc.
        base = op.replace("-start", "")
        if base in _COLLECTIVES:
            b = _shape_bytes(typ)
            if base == "all-reduce":
                b *= 2          # ring AR = reduce-scatter + all-gather phases
            out[base] += b
            counts[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-job FLOPs (all chips)
    hlo_bytes: float            # whole-job HBM bytes
    collective_bytes: float     # whole-job bytes through ICI
    model_flops: float          # analytic useful FLOPs
    compute_s: float
    memory_s: float                    # from XLA bytes-accessed (unfused UB)
    collective_s: float
    memory_s_est: float = 0.0          # fusion-aware analytic HBM estimate
    per_device_peak_memory: Optional[float] = None
    collective_detail: Optional[dict] = None

    @property
    def dominant(self) -> str:
        """Bottleneck using the fusion-aware memory estimate (the XLA
        bytes-accessed term is an unfused upper bound, see EXPERIMENTS.md)."""
        terms = {"compute": self.compute_s,
                 "memory": self.memory_s_est or self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_ratio"] = self.useful_ratio
        return d


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Fusion-aware whole-job HBM-traffic estimate.

    XLA:CPU ``bytes accessed`` counts every operand/result of the *unfused*
    HLO — an upper bound ~2 orders above real TPU HBM traffic where most
    intermediates stay in VMEM/registers.  This estimate counts what must
    cross HBM: parameter reads (per pass), activation writes+reads at layer
    granularity, optimizer state traffic, KV-cache traffic, logits.
    """
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    bpe = 2  # bf16
    if shape.kind == "decode":
        toks = shape.global_batch
        # weights read once; KV cache read fully per token; tiny writes
        n_attn = sum(1 for k in cfg.layer_pattern if k in ("attn", "swa"))
        n_attn = n_attn * cfg.n_groups + (L if cfg.enc_dec else 0)
        cache_len = shape.seq_len
        win = cfg.sliding_window or shape.seq_len
        cache = 0.0
        for kind in cfg.layer_pattern:
            if kind == "attn":
                cache += cfg.n_groups * 2 * cfg.n_kv_heads * cfg.head_dim * \
                    cache_len * bpe
            elif kind == "swa":
                cache += cfg.n_groups * 2 * cfg.n_kv_heads * cfg.head_dim * \
                    min(win, cache_len) * bpe
        cache *= shape.global_batch
        return p_active * bpe + cache + toks * v * bpe
    toks = shape.seq_len * shape.global_batch
    if shape.n_candidates:
        toks = (shape.seq_len + shape.n_candidates) * shape.global_batch
    act_per_layer = toks * (8 * d + 2 * f) * bpe      # w+r at layer granularity
    logits = toks * v * (bpe + 4)
    if shape.kind == "prefill":
        return p_active * bpe + L * act_per_layer + logits
    # train: fwd + bwd + remat fwd ~ 3 passes over weights; grads f32 w+r;
    # adam mu/nu r+w f32; master param r+w
    weight_traffic = p_total * (3 * bpe + 8 + 16 + 8)
    return weight_traffic + 3 * L * act_per_layer + 2 * logits


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D for training, 2*N_active*D for inference."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        if shape.n_candidates:
            tokens = (shape.seq_len + shape.n_candidates) * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyse(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, cfg: ModelConfig, shape: ShapeConfig,
            hw: HardwareSpec = TPU_V5E,
            per_device_peak_memory: Optional[float] = None,
            params_bytes_chip: Optional[float] = None,
            cache_bytes_chip: Optional[float] = None) -> RooflineReport:
    """cost = compiled.cost_analysis() (per-partition); scale to all chips.

    ``params_bytes_chip`` / ``cache_bytes_chip``: ACTUAL per-chip shard bytes
    (from the resolved shardings).  When given, the memory estimate charges
    each chip its real weight/cache reads — a TP-sharded model reads its 1/TP
    shard per step regardless of how many chips the job has.
    """
    flops = float(cost.get("flops", 0.0)) * chips
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    coll = collective_bytes_from_hlo(hlo_text)
    coll_total = coll["total"] * chips   # per-partition HLO -> whole job
    if params_bytes_chip is not None:
        w_factor = 19.0 if shape.kind == "train" else 1.0   # passes + opt f32
        est_chip = w_factor * params_bytes_chip + (cache_bytes_chip or 0.0) \
            + (analytic_act_bytes(cfg, shape) / chips)
        mem_est = est_chip / hw.hbm_bw
    else:
        mem_est = analytic_hbm_bytes(cfg, shape) / (chips * hw.hbm_bw)
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, collective_bytes=coll_total,
        model_flops=model_flops(cfg, shape),
        compute_s=flops / (chips * hw.peak_flops),
        memory_s=byts / (chips * hw.hbm_bw),
        collective_s=coll_total / (chips * hw.ici_bw),
        memory_s_est=mem_est,
        per_device_peak_memory=per_device_peak_memory,
        collective_detail={k: v for k, v in coll.items() if k != "counts"},
    )


def analytic_act_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Whole-job activation + logits HBM traffic (layer granularity)."""
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    bpe = 2
    if shape.kind == "decode":
        return shape.global_batch * v * bpe
    toks = shape.seq_len * shape.global_batch
    if shape.n_candidates:
        toks = (shape.seq_len + shape.n_candidates) * shape.global_batch
    act = toks * (8 * d + 2 * f) * bpe * L
    logits = toks * v * (bpe + 4)
    return (3 * act + 2 * logits) if shape.kind == "train" else (act + logits)
