"""Dynamic Stream Orchestrator (DSO) — explicit-shape executors + routing.

TPU/JAX mapping of the paper's §3.3 (see DESIGN.md):

  TensorRT profile w/ fixed batch shape  ->  AOT-compiled XLA executable
                                             (jit(f).lower(shapes).compile())
  preallocated I/O buffers               ->  persistent padded input buffers
  CUDA-graph capture                     ->  the AOT executable itself (one
                                             dispatch, no retrace)
  CUDA streams / executor index queue    ->  executor checkout queue +
                                             JAX async dispatch; worker
                                             threads interleave host work
  implicit-shape baseline                ->  plain jit re-traced/re-compiled
                                             for every novel candidate count

Routing: an upstream request with M candidates is split greedily into bucket
chunks in descending bucket order; the final partial chunk is padded up to
the smallest covering bucket (the paper's "split by batch size in descending
order").
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# bucket routing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Chunk:
    bucket: int       # executor shape this chunk runs on
    start: int        # offset into the request's candidate list
    valid: int        # number of real candidates (<= bucket; rest is padding)


def split_request(m: int, buckets: Sequence[int]) -> List[Chunk]:
    """Greedy descending-bucket split of M candidates."""
    bs = sorted(set(buckets), reverse=True)
    assert m >= 1 and bs, (m, buckets)
    plan: List[Chunk] = []
    off, rem = 0, m
    for b in bs:
        while rem >= b:
            plan.append(Chunk(b, off, b))
            off += b
            rem -= b
    if rem > 0:
        cover = min(x for x in bs if x >= rem)  # smallest covering bucket
        plan.append(Chunk(cover, off, rem))
    return plan


def padded_fraction(m: int, buckets: Sequence[int]) -> float:
    plan = split_request(m, buckets)
    padded = sum(c.bucket for c in plan)
    return 1.0 - m / padded


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class Executor:
    """One AOT-compiled executable for a fixed candidate bucket."""

    def __init__(self, bucket: int, compiled, eid: int):
        self.bucket = bucket
        self.compiled = compiled
        self.eid = eid
        self.calls = 0

    def __call__(self, *args):
        self.calls += 1
        return self.compiled(*args)


class ExecutorPool:
    """Per-bucket executor index queues (paper Fig 10).

    ``build_fn(bucket)`` must return an AOT-compiled callable for that
    bucket's shapes.  ``n_streams`` executors are built per bucket — the
    CUDA-stream analogue: that many chunks of the same bucket can be in
    flight concurrently (JAX async dispatch overlaps their host work).
    """

    def __init__(self, build_fn: Callable[[int], Callable],
                 buckets: Sequence[int], n_streams: int = 2):
        self.buckets = sorted(set(buckets), reverse=True)
        self.queues: Dict[int, "queue.Queue[Executor]"] = {}
        self.build_time_s = 0.0
        eid = 0
        t0 = time.perf_counter()
        for b in self.buckets:
            q: "queue.Queue[Executor]" = queue.Queue()
            compiled = build_fn(b)
            for _ in range(n_streams):
                q.put(Executor(b, compiled, eid))
                eid += 1
            self.queues[b] = q
        self.build_time_s = time.perf_counter() - t0

    def acquire(self, bucket: int) -> Executor:
        return self.queues[bucket].get()

    def release(self, ex: Executor):
        self.queues[ex.bucket].put(ex)


# ---------------------------------------------------------------------------
# the orchestrator
# ---------------------------------------------------------------------------

class DynamicStreamOrchestrator:
    """Routes requests with arbitrary candidate counts onto the executor pool.

    ``pad_slice_fn(request, chunk)`` -> executor args for one chunk (padded
    to ``chunk.bucket``); ``gather_fn(results, chunks, m)`` -> final output.
    """

    def __init__(self, pool: ExecutorPool,
                 pad_slice_fn: Callable, gather_fn: Callable,
                 max_workers: int = 8):
        self.pool = pool
        self.pad_slice = pad_slice_fn
        self.gather = gather_fn
        self._tp = ThreadPoolExecutor(max_workers=max_workers)
        self.chunk_count = 0
        self._lock = threading.Lock()

    def _run_chunk(self, request, chunk: Chunk):
        ex = self.pool.acquire(chunk.bucket)
        try:
            args = self.pad_slice(request, chunk)
            out = ex(*args)
            jax.block_until_ready(out)
            return out
        finally:
            self.pool.release(ex)

    def submit(self, request, m: int):
        """Non-blocking: returns a future resolving to the gathered output."""
        plan = split_request(m, self.pool.buckets)
        with self._lock:
            self.chunk_count += len(plan)
        futs = [self._tp.submit(self._run_chunk, request, c) for c in plan]

        def resolve():
            results = [f.result() for f in futs]
            return self.gather(results, plan, m)

        return _Lazy(resolve)

    def score(self, request, m: int):
        """Blocking convenience wrapper."""
        return self.submit(request, m).result()

    def shutdown(self):
        self._tp.shutdown(wait=True)


class _Lazy:
    def __init__(self, fn):
        self._fn = fn

    def result(self):
        return self._fn()


# ---------------------------------------------------------------------------
# cross-request chunk coalescing (API v2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoalescePolicy:
    """When/how same-bucket chunks from different requests share a dispatch.

    ``max_batch`` is both the fill target and the executors' compiled batch
    axis; ``window_s`` bounds how long the first chunk of a batch waits for
    co-riders before dispatching partially filled."""

    enabled: bool = True
    max_batch: int = 4
    window_s: float = 0.002

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {self.window_s}")

    @property
    def batch(self) -> int:
        """Compiled batch-axis size: coalescing off degrades to (1, bucket)."""
        return self.max_batch if self.enabled else 1


@dataclasses.dataclass
class _PendingChunk:
    args: Tuple[np.ndarray, ...]      # host arrays, each with leading axis 1
    future: "Future"                  # concurrent.futures.Future per chunk
    dedup_token: Optional[Hashable] = None   # stable identity of lead args


class CoalescingOrchestrator:
    """DSO whose executors carry a real batch axis ``(B, bucket)`` and whose
    dispatcher merges same-bucket chunks *from different in-flight requests*
    into one executor call.

    ``build_fn(bucket, batch)`` -> AOT-compiled callable over arrays whose
    leading axis is ``batch``; ``pad_slice_fn(request, chunk)`` -> host numpy
    args for one chunk (each shaped ``(1, ...)``, candidate axis padded to
    ``chunk.bucket``); ``gather_fn(rows, chunks, m)`` -> final output.

    ``families`` generalizes the executor key from ``bucket`` to
    ``(kind, bucket)`` — the history-cache serving path registers separate
    executor families for full-pass, candidate-only (pool hit) and
    history-encode (pool miss) dispatches, each with its own bucket list and
    coalescing queues.  With families, ``build_fn(kind, bucket, batch)``
    builds each executor, ``submit(..., kind=...)`` routes, and
    ``pad_slice_fn(request, chunk, kind)`` / ``gather_fn(rows, chunks, m,
    kind)`` slice and reassemble.  Executor outputs may be arbitrary pytrees
    (the encode family returns a HistoryKV dict); rows are scattered back
    leaf-wise.

    Per (kind, bucket) there are ``n_streams`` worker threads, each owning
    one executor (the CUDA-stream analogue).  A worker that pops the first
    pending chunk keeps collecting until ``max_batch`` rows are filled or
    ``window_s`` elapses, stacks the args along the batch axis (ONE
    device transfer per argument per dispatch — the PDA packed-transfer
    insight applied at dispatch granularity), runs the executor once, and
    scatters result rows back to the per-chunk futures.  Rows are
    independent under XLA, so coalesced scores are bitwise-identical to
    solo dispatches (asserted in tests).

    PDA v2 device-residency hooks:

    * **Device-aware stacking** — a chunk argument that is already a JAX
      device array (a device-resident pool entry) is stacked with
      ``jnp.concatenate`` on device instead of round-tripping through host
      numpy; host numpy args keep the v1 one-transfer-per-arg path.
    * **Device-resident outputs** — kinds listed in ``device_output_kinds``
      (the history encode/extend families) keep their outputs on device:
      rows are scattered as device slices, so an encoded entry flows
      dispatcher -> pool -> next dispatch without ever visiting host
      memory.
    * **KV-row dedup** — ``dedup_kinds`` maps a kind to the number of
      leading args that are identity-deduped per dispatch: chunks whose
      leading args are the *same objects* (the chunks of one multi-chunk
      request) or that carry the same ``dedup_token`` through ``submit``
      (co-batched requests hitting one pool entry — quantized pools
      dequantize to fresh arrays per lookup, so object identity alone
      would miss them) are stacked **once**, and the executor receives an
      extra ``[B] int32`` row-index argument (inserted after the deduped
      args) to gather each row's view.  The executor must be built for
      that signature; how it consumes the index is its business — the
      framework executors materialize ``kv[idx]`` inside the jit, while
      the FKE (``impl="fused"``) executors forward the index into the
      fused kernel's KV block reads, making the gather free.  Saved
      restacks are reported as ``dedup_rows_saved``."""

    _DEFAULT_KIND = "default"

    def __init__(self, build_fn: Callable,
                 buckets: Optional[Sequence[int]] = None,
                 pad_slice_fn: Callable = None, gather_fn: Callable = None,
                 policy: CoalescePolicy = CoalescePolicy(),
                 n_streams: int = 2,
                 families: Optional[Dict[str, Sequence[int]]] = None,
                 dedup_kinds: Optional[Dict[str, int]] = None,
                 device_output_kinds: Sequence[str] = ()):
        self._legacy = families is None
        if families is None:
            # adapt the single-family callbacks to the kinds signatures once
            # so the dispatch paths below stay uniform
            if buckets is None:
                raise ValueError("pass either buckets (legacy single-family)"
                                 " or families")
            families = {self._DEFAULT_KIND: buckets}
            _build, _pad, _gather = build_fn, pad_slice_fn, gather_fn
            build_fn = lambda kind, b, batch: _build(b, batch)  # noqa: E731
            pad_slice_fn = lambda req, c, kind: _pad(req, c)    # noqa: E731
            gather_fn = lambda rows, cs, m, kind: _gather(rows, cs, m)  # noqa: E731
        self.families: Dict[str, List[int]] = {
            kind: sorted(set(bs), reverse=True)
            for kind, bs in families.items()}
        # primary (first-registered) family drives the legacy .buckets view
        self.buckets = next(iter(self.families.values()))
        self.policy = policy
        self.pad_slice = pad_slice_fn
        self.gather = gather_fn

        self._dedup: Dict[str, int] = dict(dedup_kinds or {})
        self._device_output = frozenset(device_output_kinds)
        self.chunk_count = 0
        self.dispatch_count = 0
        self.rows_dispatched = 0       # real (non-padding) rows
        self.dedup_rows_saved = 0      # restacks avoided by KV-row dedup
        self.kind_chunks: Dict[str, int] = {k: 0 for k in self.families}
        self.kind_dispatches: Dict[str, int] = {k: 0 for k in self.families}
        self._stat_lock = threading.Lock()
        self._stop = False

        self._pending: Dict[Tuple[str, int],
                            "collections.deque[_PendingChunk]"] = {}
        self._cond: Dict[Tuple[str, int], threading.Condition] = {}
        self._threads: List[threading.Thread] = []
        self.build_time_s = 0.0

        t0 = time.perf_counter()
        for kind, bs in self.families.items():
            for b in bs:
                self._pending[(kind, b)] = collections.deque()
                self._cond[(kind, b)] = threading.Condition()
                compiled = build_fn(kind, b, policy.batch)
                for s in range(n_streams):
                    ex = Executor(b, compiled, eid=len(self._threads))
                    th = threading.Thread(
                        target=self._worker, args=(kind, b, ex),
                        name=f"dso-{kind}-b{b}-s{s}", daemon=True)
                    self._threads.append(th)
        self.build_time_s = time.perf_counter() - t0
        for th in self._threads:
            th.start()

    # ---- submission ----
    def submit(self, request, m: int, kind: Optional[str] = None,
               dedup_token: Optional[Hashable] = None):
        """Non-blocking: split into chunks, enqueue each onto its
        (kind, bucket) coalescing queue; returns a lazy future gathering the
        chunk rows.  ``dedup_token``, when given, is a stable identity for
        the chunk's dedupable leading args (see the class docstring)."""
        if kind is None:
            kind = next(iter(self.families))
        plan = split_request(m, self.families[kind])
        with self._stat_lock:
            self.chunk_count += len(plan)
            self.kind_chunks[kind] += len(plan)
        futs = []
        for c in plan:
            args = self.pad_slice(request, c, kind)
            f = Future()
            futs.append(f)
            cond = self._cond[(kind, c.bucket)]
            with cond:
                self._pending[(kind, c.bucket)].append(
                    _PendingChunk(args, f, dedup_token))
                cond.notify()

        def resolve():
            rows = [f.result() for f in futs]
            return self.gather(rows, plan, m, kind)

        return _Lazy(resolve)

    def score(self, request, m: int, kind: Optional[str] = None,
              dedup_token: Optional[Hashable] = None):
        return self.submit(request, m, kind, dedup_token).result()

    # ---- dispatcher ----
    def _worker(self, kind: str, bucket: int, ex: Executor):
        key = (kind, bucket)
        cond, pending = self._cond[key], self._pending[key]
        pol = self.policy
        while True:
            with cond:
                while not pending and not self._stop:
                    cond.wait()
                if not pending and self._stop:
                    return
                batch = [pending.popleft()]
                if pol.enabled and pol.max_batch > 1:
                    # window opens when collection starts, not at enqueue —
                    # a chunk that already sat in the queue past window_s
                    # would otherwise always dispatch solo
                    deadline = time.perf_counter() + pol.window_s
                    while len(batch) < pol.max_batch and not self._stop:
                        if pending:
                            batch.append(pending.popleft())
                            continue
                        left = deadline - time.perf_counter()
                        if left <= 0:
                            break
                        cond.wait(timeout=left)
            self._dispatch(kind, ex, batch)

    @staticmethod
    def _stack_rows(rows: List, batch: int):
        """Stack per-chunk rows (leading axis 1) along the batch axis, padded
        with zero rows to the compiled batch size.  Device arrays stack via
        jnp (no host round-trip); host numpy keeps the v1 single-transfer
        path."""
        xp = jnp if isinstance(rows[0], jax.Array) else np
        if len(rows) < batch:
            rows = list(rows) + [xp.zeros_like(rows[0])] * (batch - len(rows))
        return xp.concatenate(rows, axis=0)

    def _dispatch(self, kind: str, ex: Executor,
                  batch: List[_PendingChunk]):
        n = len(batch)
        try:
            B = self.policy.batch
            stacked = []
            n_lead = self._dedup.get(kind, 0)
            n_uniq = n
            if n_lead:
                # identity-dedup the leading args: chunks carrying the SAME
                # arg objects (one request split across chunks, or requests
                # sharing a pool entry) stack each unique row once; the
                # executor gathers per-row views through the idx argument
                slot_of: Dict[tuple, int] = {}
                uniq: List[tuple] = []
                idx = np.zeros(B, np.int32)
                for i, c in enumerate(batch):
                    ident = c.dedup_token if c.dedup_token is not None \
                        else tuple(id(a) for a in c.args[:n_lead])
                    slot = slot_of.get(ident)
                    if slot is None:
                        slot = len(uniq)
                        slot_of[ident] = slot
                        uniq.append(c.args[:n_lead])
                    idx[i] = slot
                n_uniq = len(uniq)
                for j in range(n_lead):
                    stacked.append(self._stack_rows([u[j] for u in uniq], B))
                stacked.append(idx)
                rests = [c.args[n_lead:] for c in batch]
            else:
                rests = [c.args for c in batch]
            for j in range(len(rests[0])):
                stacked.append(self._stack_rows([r[j] for r in rests], B))
            out = ex(*stacked)
            jax.block_until_ready(out)
            if kind in self._device_output:
                host = out        # stays device-resident (pool entries)
            else:
                host = jax.tree.map(np.asarray, out)   # pytree outputs OK
            with self._stat_lock:
                self.dispatch_count += 1
                self.kind_dispatches[kind] += 1
                self.rows_dispatched += n
                self.dedup_rows_saved += n - n_uniq
            for i, c in enumerate(batch):
                c.future.set_result(
                    jax.tree.map(lambda a: a[i:i + 1], host))
        except BaseException as e:  # noqa: BLE001 — fail every rider
            for c in batch:
                if not c.future.done():
                    c.future.set_exception(e)

    # ---- introspection / lifecycle ----
    def stats(self) -> Dict[str, float]:
        with self._stat_lock:
            d = max(self.dispatch_count, 1)
            out = {
                "chunks": self.chunk_count,
                "dispatches": self.dispatch_count,
                "rows_dispatched": self.rows_dispatched,
                "avg_fill": self.rows_dispatched / d,
                "batch_axis": self.policy.batch,
                "dedup_rows_saved": self.dedup_rows_saved,
            }
            if not self._legacy:
                for kind in self.families:
                    out[f"chunks_{kind}"] = self.kind_chunks[kind]
                    out[f"dispatches_{kind}"] = self.kind_dispatches[kind]
            return out

    def shutdown(self):
        self._stop = True
        for cond in self._cond.values():
            with cond:
                cond.notify_all()
        for th in self._threads:
            th.join(timeout=5.0)


# ---------------------------------------------------------------------------
# implicit-shape baseline (the paper's "Default" row in Table 5)
# ---------------------------------------------------------------------------

class ImplicitShapeEngine:
    """Plain jit: every novel candidate count triggers a fresh trace+compile,
    the XLA analogue of TensorRT implicit-shape dynamic (re)allocation."""

    def __init__(self, fn: Callable):
        self._fn = jax.jit(fn)
        self.compiles = 0
        self._seen: set = set()

    def score(self, request, m: int):
        if m not in self._seen:
            self._seen.add(m)
            self.compiles += 1
        out = self._fn(*request)
        jax.block_until_ready(out)
        return out
