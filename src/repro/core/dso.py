"""Dynamic Stream Orchestrator (DSO) — explicit-shape executors + routing.

TPU/JAX mapping of the paper's §3.3 (see DESIGN.md):

  TensorRT profile w/ fixed batch shape  ->  AOT-compiled XLA executable
                                             (jit(f).lower(shapes).compile())
  preallocated I/O buffers               ->  persistent padded input buffers
  CUDA-graph capture                     ->  the AOT executable itself (one
                                             dispatch, no retrace)
  CUDA streams / executor index queue    ->  executor checkout queue +
                                             JAX async dispatch; worker
                                             threads interleave host work
  implicit-shape baseline                ->  plain jit re-traced/re-compiled
                                             for every novel candidate count

Routing: an upstream request with M candidates is split greedily into bucket
chunks in descending bucket order; the final partial chunk is padded up to
the smallest covering bucket (the paper's "split by batch size in descending
order").

DSO v2 (segment packing + deadline-aware flushing)
--------------------------------------------------
Under non-uniform candidate traffic the greedy split leaves every request's
tail chunk partially filled, and the v1 dispatcher paid that padding on
every dispatch (``padded_fraction`` routinely 20-40% on zipf traffic).  Two
mechanisms reclaim it:

* **Segment packing** (:class:`SegmentPacker`): partial tail chunks from
  *different requests* are packed into one ``(1, bucket)`` row as
  independent segments.  Candidates never attend to each other under the
  SUMI mask, so a row may carry candidates of several users as long as each
  candidate scores against its own user's history KV — the executor
  receives a per-candidate ``[B, bucket]`` KV slot index (the per-q-block
  generalization of the per-row dedup ``row_index``) steering every segment
  to its user's pooled rows.  Packing is bitwise-clean by construction and
  subsumes KV-row dedup: same-user segments share one stacked KV slot.
* **Deadline-aware flushing**: pending chunks are ordered earliest-deadline
  -first (deadline-less chunks sort last; ties break on the request's
  remaining work, then FIFO), and the collect loop sizes its wait against a
  per-(kind, bucket) EWMA cost model — it flushes as soon as waiting any
  longer would make the earliest collected deadline unmeetable, instead of
  always sleeping the full flat window.

Generative decode families (ISSUE 8)
------------------------------------
The flame engine registers two more executor families when built with
``generate > 0``; the DSO needs no new machinery for either:

* ``decode`` — one vocab-scoring step for an in-flight beam: lead args are
  the beam's padded KV leaves plus its ``lengths`` row, the candidate axis
  carries the step's token universe, and the usual bucket ladder chunks
  ragged universes.  Under ``pack_tails`` the SegmentPacker packs tail
  chunks of *different beams'* decode steps into shared rows exactly like
  cached scoring — the per-candidate segment index steers each universe
  segment to its own beam's stacked KV slot, so per-step ragged decode
  batching falls out of the PR 5 contract unchanged.
* ``append`` — the single-token KV append growing a chosen hypothesis;
  rides the plain (unpacked) path at bucket 1 and returns device KV leaves
  (an engine-output kind, like ``encode``/``extend``).

Chunks from concurrent generative requests coalesce per step, so the
decode families inherit cross-request batching, deadline flushing, and the
fill/padding metrics (``dso_dispatches_decode`` etc.) for free.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# bucket routing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Chunk:
    bucket: int       # executor shape this chunk runs on
    start: int        # offset into the request's candidate list
    valid: int        # number of real candidates (<= bucket; rest is padding)


def split_request(m: int, buckets: Sequence[int]) -> List[Chunk]:
    """Greedy descending-bucket split of M candidates."""
    bs = sorted(set(buckets), reverse=True)
    assert m >= 1 and bs, (m, buckets)
    plan: List[Chunk] = []
    off, rem = 0, m
    for b in bs:
        while rem >= b:
            plan.append(Chunk(b, off, b))
            off += b
            rem -= b
    if rem > 0:
        cover = min(x for x in bs if x >= rem)  # smallest covering bucket
        plan.append(Chunk(cover, off, rem))
    return plan


def padded_fraction(m: int, buckets: Sequence[int]) -> float:
    plan = split_request(m, buckets)
    padded = sum(c.bucket for c in plan)
    return 1.0 - m / padded


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class Executor:
    """One AOT-compiled executable for a fixed candidate bucket."""

    def __init__(self, bucket: int, compiled, eid: int):
        self.bucket = bucket
        self.compiled = compiled
        self.eid = eid
        self.calls = 0

    def __call__(self, *args):
        self.calls += 1
        return self.compiled(*args)


class ExecutorPool:
    """Per-bucket executor index queues (paper Fig 10).

    ``build_fn(bucket)`` must return an AOT-compiled callable for that
    bucket's shapes.  ``n_streams`` executors are built per bucket — the
    CUDA-stream analogue: that many chunks of the same bucket can be in
    flight concurrently (JAX async dispatch overlaps their host work).
    """

    def __init__(self, build_fn: Callable[[int], Callable],
                 buckets: Sequence[int], n_streams: int = 2):
        self.buckets = sorted(set(buckets), reverse=True)
        self.queues: Dict[int, "queue.Queue[Executor]"] = {}
        self.build_time_s = 0.0
        eid = 0
        t0 = time.perf_counter()
        for b in self.buckets:
            q: "queue.Queue[Executor]" = queue.Queue()
            compiled = build_fn(b)
            for _ in range(n_streams):
                q.put(Executor(b, compiled, eid))
                eid += 1
            self.queues[b] = q
        self.build_time_s = time.perf_counter() - t0

    def acquire(self, bucket: int) -> Executor:
        return self.queues[bucket].get()

    def release(self, ex: Executor):
        self.queues[ex.bucket].put(ex)


# ---------------------------------------------------------------------------
# the orchestrator
# ---------------------------------------------------------------------------

class DynamicStreamOrchestrator:
    """Routes requests with arbitrary candidate counts onto the executor pool.

    ``pad_slice_fn(request, chunk)`` -> executor args for one chunk (padded
    to ``chunk.bucket``); ``gather_fn(results, chunks, m)`` -> final output.
    """

    def __init__(self, pool: ExecutorPool,
                 pad_slice_fn: Callable, gather_fn: Callable,
                 max_workers: int = 8):
        self.pool = pool
        self.pad_slice = pad_slice_fn
        self.gather = gather_fn
        self._tp = ThreadPoolExecutor(max_workers=max_workers)
        self.chunk_count = 0
        self._lock = threading.Lock()

    def _run_chunk(self, request, chunk: Chunk):
        ex = self.pool.acquire(chunk.bucket)
        try:
            args = self.pad_slice(request, chunk)
            out = ex(*args)
            jax.block_until_ready(out)
            return out
        finally:
            self.pool.release(ex)

    def submit(self, request, m: int):
        """Non-blocking: returns a future resolving to the gathered output."""
        plan = split_request(m, self.pool.buckets)
        with self._lock:
            self.chunk_count += len(plan)
        futs = [self._tp.submit(self._run_chunk, request, c) for c in plan]

        def resolve():
            results = [f.result() for f in futs]
            return self.gather(results, plan, m)

        return _Lazy(resolve)

    def score(self, request, m: int):
        """Blocking convenience wrapper."""
        return self.submit(request, m).result()

    def shutdown(self):
        self._tp.shutdown(wait=True)


class _Lazy:
    def __init__(self, fn):
        self._fn = fn

    def result(self):
        return self._fn()


# ---------------------------------------------------------------------------
# cross-request chunk coalescing (API v2) + segment packing (DSO v2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoalescePolicy:
    """When/how same-bucket chunks from different requests share a dispatch.

    ``max_batch`` is both the fill target and the executors' compiled batch
    axis; ``window_s`` bounds how long the first chunk of a batch waits for
    co-riders before dispatching partially filled.  Chunks that carry a
    deadline (DSO v2) may flush *earlier* than the window: the collect loop
    stops waiting once ``now + estimated_dispatch_cost`` would overrun the
    earliest collected deadline (per-(kind, bucket) EWMA cost model).

    ``pack_rows`` sizes the PACKED executors' row axis independently of
    ``max_batch`` (which still sizes the stacked unique-KV axis, i.e. how
    many distinct users one packed dispatch can steer to): packed rows are
    dense, so a fraction of the unpacked row capacity carries the same
    candidate throughput at a fraction of the executor cost.  ``None``
    defaults to ``max_batch``.

    ``data_ways`` (mesh-sharded serving) is the data-parallel width of the
    engine's device mesh.  ``max_batch`` / ``pack_rows`` are PER-DEVICE
    capacities: the compiled global batch/row axes scale by ``data_ways``
    so one coalesced flush feeds every data shard a full per-device batch
    without resharding — throughput scales with the mesh instead of each
    device serving a 1/ways sliver of a fixed batch.  Preserving the
    per-device (local) shape is also what makes sharded serving bitwise
    against a single-device engine on CPU CI: XLA's kernel selection (and
    hence FP reduction order) depends on the local batch shape, so equal
    local shapes mean identical per-row arithmetic.

    ``tier_windows`` (SLO-tiered serving, ISSUE 9) maps an SLO tier name to
    a multiplier on ``window_s`` — the per-tier pack/flush policy: an
    interactive chunk should flush almost immediately (scale ~0) while bulk
    work may wait longer than the default window for better packing.  The
    collect loop uses the MINIMUM scale across the chunks it has collected,
    so one interactive co-rider flushes the whole dispatch.  ``None``
    (and unknown tiers / tier-less chunks) means scale 1.0.

    ``pack_align`` rounds every packed segment's start offset up to a
    multiple of this many candidate slots (FKE v2): the fused kernel
    steers pooled-KV reads per ``bq``-sized q BLOCK through a scalar-
    prefetched index sampled at each block's first candidate, so packed
    rows feed ``path="kernel"`` only when no segment crosses a block
    boundary.  1 (the default) packs densely — the jnp formulation does
    not care; the engine raises it to the kernel ``bq`` under
    ``impl="fused"`` (`fused_score.ops.set_packed_alignment` declares the
    contract to the trace).  Alignment holes are dead slots: seg index 0 /
    candidate -1, exactly like row-tail padding."""

    enabled: bool = True
    max_batch: int = 4
    window_s: float = 0.002
    pack_rows: Optional[int] = None
    data_ways: int = 1
    tier_windows: Optional[Dict[str, float]] = None
    pack_align: int = 1

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {self.window_s}")
        if self.pack_rows is not None and self.pack_rows < 1:
            raise ValueError(f"pack_rows must be >= 1, got {self.pack_rows}")
        if self.data_ways < 1:
            raise ValueError(f"data_ways must be >= 1, got {self.data_ways}")
        if self.pack_align < 1:
            raise ValueError(
                f"pack_align must be >= 1, got {self.pack_align}")

    @property
    def batch(self) -> int:
        """Compiled (global) batch-axis size: coalescing off degrades to
        (1, bucket); mesh-sharded engines compile ``max_batch`` rows PER
        data shard."""
        return self.max_batch * self.data_ways if self.enabled else 1

    @property
    def rows(self) -> int:
        """Compiled (global) row-axis size of PACKED executors — scales by
        the data ways like ``batch`` does."""
        if not self.enabled:
            return 1
        per_dev = self.pack_rows if self.pack_rows is not None else \
            self.max_batch
        return per_dev * self.data_ways

    def tier_scale(self, tier: Optional[str]) -> float:
        """Flush-window multiplier for one chunk's SLO tier."""
        if self.tier_windows is None or tier is None:
            return 1.0
        return self.tier_windows.get(tier, 1.0)


_SEQ = itertools.count()


@dataclasses.dataclass
class _PendingChunk:
    args: Tuple[np.ndarray, ...]      # host arrays, each with leading axis 1
    future: "Future"                  # concurrent.futures.Future per chunk
    dedup_token: Optional[Hashable] = None   # stable identity of lead args
    valid: int = 0                    # real candidates in this chunk
    deadline: Optional[float] = None  # absolute perf_counter deadline
    remaining: int = 0                # request work left incl. this chunk
    tier: Optional[str] = None        # owning request's SLO tier (flush policy)
    seq: int = dataclasses.field(default_factory=lambda: next(_SEQ))
    enqueue_t: float = dataclasses.field(default_factory=time.perf_counter)

    def _key(self):
        # EDF first; deadline-less chunks sort last.  Ties break on the
        # owning request's remaining work (shortest-remaining-work), then
        # FIFO sequence for determinism.
        return (self.deadline if self.deadline is not None else math.inf,
                self.remaining, self.seq)

    def __lt__(self, other: "_PendingChunk") -> bool:
        return self._key() < other._key()


class SegmentPacker:
    """First-fit packer of tail-chunk segments into shared executor rows.

    One packer instance plans ONE packed dispatch: up to ``max_rows`` rows
    of ``bucket`` candidate slots, fed at most ``max_kv`` distinct KV
    identities (the compiled leading axis of the stacked unique-KV
    operands).  ``try_add(valid, ident)`` places a segment of ``valid``
    candidates belonging to KV identity ``ident`` into the first row with
    room (never splitting a segment across rows — a segment IS one
    request's chunk, so no segment ever crosses a request boundary by
    construction) and returns its ``(row, offset, kv_slot)`` placement, or
    ``None`` when the segment doesn't fit this dispatch.

    ``align`` > 1 (FKE v2 kernel-path packing) rounds every segment's
    start offset up to an ``align`` multiple before the fit check, so a
    segment occupies ``[off, off + valid)`` with ``off % align == 0`` —
    every ``align``-sized block a segment touches starts either at or
    inside that segment, which is exactly the fused kernel's per-q-block
    index-sampling contract (``bq == align``).  Alignment holes stay dead
    slots (seg 0 / candidate -1 planes in ``_dispatch_packed``), same as
    row-tail padding."""

    def __init__(self, bucket: int, max_rows: int, max_kv: int,
                 align: int = 1):
        assert bucket >= 1 and max_rows >= 1 and max_kv >= 1 and align >= 1
        self.bucket = bucket
        self.max_rows = max_rows
        self.max_kv = max_kv
        self.align = align
        self.fills: List[int] = []            # candidate slots used per row
        self.placements: List[Tuple[int, int, int]] = []  # (row, off, slot)
        self.slot_of: Dict[Hashable, int] = {}
        self.n_slots = 0

    def _aligned(self, fill: int) -> int:
        return -(-fill // self.align) * self.align

    def try_add(self, valid: int, ident: Hashable
                ) -> Optional[Tuple[int, int, int]]:
        if not 1 <= valid <= self.bucket:
            raise ValueError(f"segment of {valid} candidates does not fit a "
                             f"{self.bucket}-slot row")
        slot = self.slot_of.get(ident)
        if slot is None and self.n_slots >= self.max_kv:
            return None
        row = next((i for i, f in enumerate(self.fills)
                    if self._aligned(f) + valid <= self.bucket), None)
        if row is None:
            if len(self.fills) >= self.max_rows:
                return None
            row = len(self.fills)
            self.fills.append(0)
        if slot is None:
            slot = self.n_slots
            self.slot_of[ident] = slot
            self.n_slots += 1
        off = self._aligned(self.fills[row])
        self.fills[row] = off + valid
        place = (row, off, slot)
        self.placements.append(place)
        return place

    @property
    def n_rows(self) -> int:
        return len(self.fills)

    def is_full(self) -> bool:
        """No further segment (not even a 1-candidate one) can be placed."""
        return (len(self.fills) == self.max_rows
                and all(self._aligned(f) >= self.bucket
                        for f in self.fills))


class CoalescingOrchestrator:
    """DSO whose executors carry a real batch axis ``(B, bucket)`` and whose
    dispatcher merges same-bucket chunks *from different in-flight requests*
    into one executor call.

    ``build_fn(bucket, batch)`` -> AOT-compiled callable over arrays whose
    leading axis is ``batch``; ``pad_slice_fn(request, chunk)`` -> host numpy
    args for one chunk (each shaped ``(1, ...)``, candidate axis padded to
    ``chunk.bucket``); ``gather_fn(rows, chunks, m)`` -> final output.

    ``families`` generalizes the executor key from ``bucket`` to
    ``(kind, bucket)`` — the history-cache serving path registers separate
    executor families for full-pass, candidate-only (pool hit) and
    history-encode (pool miss) dispatches, each with its own bucket list and
    coalescing queues.  With families, ``build_fn(kind, bucket, batch)``
    builds each executor, ``submit(..., kind=...)`` routes, and
    ``pad_slice_fn(request, chunk, kind)`` / ``gather_fn(rows, chunks, m,
    kind)`` slice and reassemble.  Executor outputs may be arbitrary pytrees
    (the encode family returns a HistoryKV dict); rows are scattered back
    leaf-wise.

    Per (kind, bucket) there are ``n_streams`` worker threads, each owning
    one executor (the CUDA-stream analogue).  A worker that pops the first
    pending chunk keeps collecting until the dispatch is full, the
    ``window_s`` flush window elapses, or — when collected chunks carry
    deadlines — waiting longer would overrun the earliest deadline given
    the (kind, bucket) EWMA dispatch-cost estimate.  Pending chunks pop in
    EDF order (ties: shortest remaining work, then FIFO).  The collected
    args are stacked along the batch axis (ONE device transfer per argument
    per dispatch — the PDA packed-transfer insight applied at dispatch
    granularity), run through the executor once, and result rows scatter
    back to the per-chunk futures.  Rows are independent under XLA, so
    coalesced scores are bitwise-identical to solo dispatches (asserted in
    tests).

    PDA v2 device-residency hooks:

    * **Device-aware stacking** — a chunk argument that is already a JAX
      device array (a device-resident pool entry) is stacked with
      ``jnp.concatenate`` on device instead of round-tripping through host
      numpy; host numpy args keep the v1 one-transfer-per-arg path.
    * **Device-resident outputs** — kinds listed in ``device_output_kinds``
      (the history encode/extend families) keep their outputs on device:
      rows are scattered as device slices, so an encoded entry flows
      dispatcher -> pool -> next dispatch without ever visiting host
      memory.
    * **KV-row dedup** — ``dedup_kinds`` maps a kind to the number of
      leading args that are identity-deduped per dispatch: chunks whose
      leading args are the *same objects* (the chunks of one multi-chunk
      request) or that carry the same ``dedup_token`` through ``submit``
      (co-batched requests hitting one pool entry — quantized pools
      dequantize to fresh arrays per lookup, so object identity alone
      would miss them) are stacked **once**, and the executor receives an
      extra ``[B] int32`` row-index argument (inserted after the deduped
      args) to gather each row's view.  The executor must be built for
      that signature; how it consumes the index is its business — the
      framework executors materialize ``kv[idx]`` inside the jit, while
      the FKE (``impl="fused"``) executors forward the index into the
      fused kernel's KV block reads, making the gather free.  Saved
      restacks are reported as ``dedup_rows_saved``.

    DSO v2 segment packing:

    * ``packed_kinds`` maps a kind to its number of leading KV args, like
      ``dedup_kinds`` — but the dispatcher additionally packs partial
      chunks from different requests into shared rows: ``pad_slice_fn``
      must return the chunk's candidate slice UNPADDED (``(1, valid)``,
      last arg), and the executor signature becomes ``(*kv_rows,
      seg_index [B, bucket] int32, candidates [B, bucket] int32)`` where
      ``seg_index`` maps every candidate slot to its KV row (padding slots
      point at row 0 and carry the ``-1`` candidate sentinel).  Each
      chunk's future resolves to the exact ``[1, valid, ...]`` slice of
      its segment.  Packing subsumes dedup (same-identity chunks share a
      KV slot; savings still count into ``dedup_rows_saved``); a kind may
      not be registered in both maps."""

    _DEFAULT_KIND = "default"
    _COST_EWMA = 0.3          # per-(kind, bucket) dispatch-cost smoothing

    def __init__(self, build_fn: Callable,
                 buckets: Optional[Sequence[int]] = None,
                 pad_slice_fn: Callable = None, gather_fn: Callable = None,
                 policy: CoalescePolicy = CoalescePolicy(),
                 n_streams: int = 2,
                 families: Optional[Dict[str, Sequence[int]]] = None,
                 dedup_kinds: Optional[Dict[str, int]] = None,
                 device_output_kinds: Sequence[str] = (),
                 packed_kinds: Optional[Dict[str, int]] = None,
                 serialize_dispatch: bool = False,
                 fault_hook: Optional[Callable[[str, int], None]] = None,
                 dispatch_retries: int = 2,
                 retry_backoff_s: float = 0.001):
        self._legacy = families is None
        if families is None:
            # adapt the single-family callbacks to the kinds signatures once
            # so the dispatch paths below stay uniform
            if buckets is None:
                raise ValueError("pass either buckets (legacy single-family)"
                                 " or families")
            families = {self._DEFAULT_KIND: buckets}
            _build, _pad, _gather = build_fn, pad_slice_fn, gather_fn
            build_fn = lambda kind, b, batch: _build(b, batch)  # noqa: E731
            pad_slice_fn = lambda req, c, kind: _pad(req, c)    # noqa: E731
            gather_fn = lambda rows, cs, m, kind: _gather(rows, cs, m)  # noqa: E731
        self.families: Dict[str, List[int]] = {
            kind: sorted(set(bs), reverse=True)
            for kind, bs in families.items()}
        # primary (first-registered) family drives the legacy .buckets view
        self.buckets = next(iter(self.families.values()))
        self.policy = policy
        self.pad_slice = pad_slice_fn
        self.gather = gather_fn

        self._dedup: Dict[str, int] = dict(dedup_kinds or {})
        self._packed: Dict[str, int] = dict(packed_kinds or {})
        overlap = set(self._dedup) & set(self._packed)
        if overlap:
            raise ValueError(f"kinds {sorted(overlap)} registered as both "
                             f"dedup and packed — packing subsumes dedup")
        self._device_output = frozenset(device_output_kinds)
        self.chunk_count = 0
        self.dispatch_count = 0
        self.rows_dispatched = 0       # real (non-padding) rows
        self.dedup_rows_saved = 0      # restacks avoided by dedup/packing
        self.packed_rows = 0           # rows carrying >= 1 packed segment
        self.packed_segments = 0       # segments dispatched via packing
        self.queue_delay_total_s = 0.0
        self.queue_delay_count = 0
        self.kind_chunks: Dict[str, int] = {k: 0 for k in self.families}
        self.kind_dispatches: Dict[str, int] = {k: 0 for k in self.families}
        # fault tolerance (ISSUE 9): ``fault_hook(kind, bucket)`` runs just
        # before every executor launch (the chaos injection point); a raised
        # exception with a truthy ``.transient`` retries with exponential
        # backoff up to ``dispatch_retries`` times before failing the batch.
        self._fault_hook = fault_hook
        self._dispatch_retries = max(0, int(dispatch_retries))
        self._retry_backoff_s = float(retry_backoff_s)
        self.dispatch_retry_count = 0      # transient failures retried
        self.dispatch_failure_count = 0    # batches failed into futures
        # per-family deadline misses: chunks whose dispatch completed past
        # their absolute deadline (degradation decisions read these)
        self.deadline_miss_chunks: Dict[str, int] = {
            k: 0 for k in self.families}
        # graceful degradation: a non-None override caps the effective
        # flush window (level >= 1 sets 0.0 — flush immediately)
        self._window_override: Optional[float] = None
        # per-(kind, bucket) candidate-slot occupancy: slots dispatched vs
        # real candidates in them — 1 - valid/slots is the padded fraction
        self.slot_count: Dict[Tuple[str, int], int] = {}
        self.valid_count: Dict[Tuple[str, int], int] = {}
        self._cost: Dict[Tuple[str, int], float] = {}   # EWMA dispatch cost
        self._stat_lock = threading.Lock()
        # Mesh-sharded executables run one computation across EVERY device:
        # XLA's in-process collectives rendezvous per-computation with no
        # cross-computation ordering, so two dispatch threads whose
        # executions overlap on shared devices can interleave their
        # collectives and deadlock (observed on forced-host CPU meshes).
        # Engines serving a multi-device mesh set serialize_dispatch so the
        # launch+wait region runs under one process-wide lock; single-device
        # executables keep fully concurrent streams.
        self._dispatch_lock = threading.Lock() if serialize_dispatch \
            else None
        self._stop = False

        self._pending: Dict[Tuple[str, int], List[_PendingChunk]] = {}
        self._cond: Dict[Tuple[str, int], threading.Condition] = {}
        self._threads: List[threading.Thread] = []
        self.build_time_s = 0.0
        #: (kind, bucket) -> the AOT executable all streams share; exposed
        #: so tests/benches can inspect compiled HLO (e.g. assert the
        #: steady-state hot path carries no cross-shard reshard collectives)
        self.compiled: Dict[Tuple[str, int], object] = {}

        t0 = time.perf_counter()
        for kind, bs in self.families.items():
            for b in bs:
                self._pending[(kind, b)] = []
                self._cond[(kind, b)] = threading.Condition()
                self.slot_count[(kind, b)] = 0
                self.valid_count[(kind, b)] = 0
                compiled = build_fn(kind, b, policy.batch)
                self.compiled[(kind, b)] = compiled
                for s in range(n_streams):
                    ex = Executor(b, compiled, eid=len(self._threads))
                    th = threading.Thread(
                        target=self._worker, args=(kind, b, ex),
                        name=f"dso-{kind}-b{b}-s{s}", daemon=True)
                    self._threads.append(th)
        self.build_time_s = time.perf_counter() - t0
        for th in self._threads:
            th.start()

    # ---- submission ----
    def submit(self, request, m: int, kind: Optional[str] = None,
               dedup_token: Optional[Hashable] = None,
               deadline: Optional[float] = None,
               tier: Optional[str] = None):
        """Non-blocking: split into chunks, enqueue each onto its
        (kind, bucket) coalescing queue; returns a lazy future gathering the
        chunk rows.  ``dedup_token``, when given, is a stable identity for
        the chunk's dedupable/packable leading args (see the class
        docstring); ``deadline`` is an absolute ``time.perf_counter``
        instant the request's dispatch should start by — chunks carrying
        one pop earliest-deadline-first and flush early when the cost model
        says waiting longer would miss it.  ``tier`` (SLO tier name) scales
        the flush window per ``CoalescePolicy.tier_windows``."""
        if kind is None:
            kind = next(iter(self.families))
        plan = split_request(m, self.families[kind])
        with self._stat_lock:
            self.chunk_count += len(plan)
            self.kind_chunks[kind] += len(plan)
        futs = []
        for c in plan:
            args = self.pad_slice(request, c, kind)
            f = Future()
            futs.append(f)
            cond = self._cond[(kind, c.bucket)]
            with cond:
                heapq.heappush(
                    self._pending[(kind, c.bucket)],
                    _PendingChunk(args, f, dedup_token, valid=c.valid,
                                  deadline=deadline, remaining=m - c.start,
                                  tier=tier))
                cond.notify()

        def resolve():
            rows = [f.result() for f in futs]
            return self.gather(rows, plan, m, kind)

        return _Lazy(resolve)

    def score(self, request, m: int, kind: Optional[str] = None,
              dedup_token: Optional[Hashable] = None,
              deadline: Optional[float] = None,
              tier: Optional[str] = None):
        return self.submit(request, m, kind, dedup_token, deadline,
                           tier).result()

    def set_window_override(self, window_s: Optional[float]):
        """Degradation hook: cap the effective flush window at ``window_s``
        (0.0 == flush immediately); ``None`` restores the policy window."""
        with self._stat_lock:
            self._window_override = window_s

    # ---- dispatcher ----
    @staticmethod
    def _ident(c: _PendingChunk, n_lead: int) -> Hashable:
        return c.dedup_token if c.dedup_token is not None \
            else tuple(id(a) for a in c.args[:n_lead])

    def _collect(self, kind: str, bucket: int,
                 pending: List[_PendingChunk], cond: threading.Condition,
                 batch: List[_PendingChunk]) -> Optional[SegmentPacker]:
        """Pop the first chunk and keep collecting co-riders into the
        CALLER-OWNED ``batch`` list (caller holds ``cond``; filling the
        caller's list means a mid-collect exception can never strand the
        already-popped chunks — the worker fails exactly what was taken).
        The flush decision is deadline/cost-aware: with no deadlines in the
        collected set this is the v1 window policy (the window opens when
        collection starts, not at enqueue — a chunk that already sat in the
        queue past ``window_s`` would otherwise always dispatch solo); once
        any collected chunk carries a deadline, the wait is additionally
        capped at ``earliest_deadline - est_cost``.  The window itself is
        scaled by the minimum SLO-tier scale of the collected chunks
        (``CoalescePolicy.tier_windows``) and capped by the degradation
        override (``set_window_override``)."""
        pol = self.policy
        n_lead = self._packed.get(kind)
        packer = SegmentPacker(bucket, pol.rows, pol.batch,
                               align=pol.pack_align) \
            if n_lead is not None else None

        def take() -> bool:
            """Place the earliest-deadline pending chunk that FITS this
            dispatch.  For packed kinds a large head segment may not fit
            the remaining row space while smaller later chunks still do —
            skipping it costs the head nothing (it couldn't ride this
            dispatch anyway and leads the next one), and packing the
            smaller co-riders is exactly what reclaims the padding."""
            if packer is None:
                if len(batch) >= pol.batch or not pending:
                    return False
                batch.append(heapq.heappop(pending))
                return True
            skipped: List[_PendingChunk] = []
            got = False
            while pending:
                c = heapq.heappop(pending)
                if packer.try_add(c.valid, self._ident(c, n_lead)) \
                        is not None:
                    batch.append(c)
                    got = True
                    break
                skipped.append(c)
            for c in skipped:
                heapq.heappush(pending, c)
            return got

        took = take()
        assert took, "first chunk must always fit an empty dispatch"
        if pol.enabled and (pol.max_batch > 1 or packer is not None):
            with self._stat_lock:
                override = self._window_override
            base_window = pol.window_s if override is None \
                else min(pol.window_s, override)
            t_open = time.perf_counter()
            while not self._stop:
                full = packer.is_full() if packer is not None \
                    else len(batch) >= pol.max_batch
                if full:
                    break
                if pending:
                    if take():
                        continue
                    break        # nothing pending fits: flush what we have
                if packer is not None and len(batch) >= pol.max_batch:
                    # the dispatch already carries the v1 fill target's
                    # worth of chunks in fewer (denser) rows — waiting for
                    # MORE co-riders would trade latency (and, by Little's
                    # law, throughput at fixed concurrency) for slot
                    # capacity the in-flight population can't fill anyway.
                    # Deeper queues still pack up to the slot capacity
                    # through the take() loop above without ever waiting.
                    break
                now = time.perf_counter()
                scale = min(pol.tier_scale(c.tier) for c in batch)
                target = t_open + base_window * scale
                dls = [c.deadline for c in batch if c.deadline is not None]
                if dls:
                    with self._stat_lock:
                        est = self._cost.get((kind, bucket), 0.0)
                    target = min(target, min(dls) - est)
                left = target - now
                if left <= 0:
                    break
                cond.wait(timeout=left)
        now = time.perf_counter()
        delay = sum(now - c.enqueue_t for c in batch)
        with self._stat_lock:
            self.queue_delay_total_s += delay
            self.queue_delay_count += len(batch)
        return packer

    def _worker(self, kind: str, bucket: int, ex: Executor):
        key = (kind, bucket)
        cond, pending = (self._cond[key], self._pending[key]
                         )  # flamecheck: unguarded-ok(dicts frozen after __init__; the heap is only touched under cond)
        while True:
            batch: List[_PendingChunk] = []
            with cond:
                while not pending and not self._stop:
                    cond.wait()
                if not pending and self._stop:
                    return
                try:
                    packer = self._collect(kind, bucket, pending, cond,
                                           batch)
                except BaseException as e:  # noqa: BLE001 — never strand
                    # a mid-collect failure (e.g. a poisoned packer state)
                    # must fail exactly the chunks already popped off the
                    # heap and keep the stream thread alive; anything still
                    # pending stays queued for the next round
                    for c in batch:
                        if not c.future.done():
                            c.future.set_exception(e)
                    continue
            if packer is not None:
                self._dispatch_packed(kind, bucket, ex, batch, packer)
            else:
                self._dispatch(kind, bucket, ex, batch)

    @staticmethod
    def _stack_rows(rows: List, batch: int):
        """Stack per-chunk rows (leading axis 1) along the batch axis, padded
        with zero rows to the compiled batch size.  Device arrays stack via
        jnp (no host round-trip); host numpy keeps the v1 single-transfer
        path."""
        xp = jnp if isinstance(rows[0], jax.Array) else np
        if len(rows) < batch:
            rows = list(rows) + [xp.zeros_like(rows[0])] * (batch - len(rows))
        return xp.concatenate(rows, axis=0)

    def _note_dispatch(self, kind: str, bucket: int, n_chunks: int,
                       rows_used: int, valid: int, saved: int,
                       cost_s: float, packed: bool, missed: int = 0):
        key = (kind, bucket)
        with self._stat_lock:
            self.dispatch_count += 1
            self.kind_dispatches[kind] += 1
            self.rows_dispatched += n_chunks
            self.dedup_rows_saved += saved
            self.slot_count[key] += rows_used * bucket
            self.valid_count[key] += valid
            self.deadline_miss_chunks[kind] += missed
            if packed:
                self.packed_rows += rows_used
                self.packed_segments += n_chunks
            old = self._cost.get(key)
            self._cost[key] = cost_s if old is None else \
                (1 - self._COST_EWMA) * old + self._COST_EWMA * cost_s

    @staticmethod
    def _count_missed(batch: List[_PendingChunk]) -> int:
        """Chunks whose dispatch completed past their absolute deadline."""
        now = time.perf_counter()
        return sum(1 for c in batch
                   if c.deadline is not None and now > c.deadline)

    def _run_executor(self, ex: Executor, stacked) -> Tuple[object, float]:  # flamecheck: host-sync-ok(dispatch boundary: the wait must happen inside the timed region — and inside the dispatch lock when executables are multi-device)
        """Launch + wait, timed; serialized under the dispatch lock when the
        executables are multi-device (see ``serialize_dispatch``)."""
        if self._dispatch_lock is not None:
            with self._dispatch_lock:
                t0 = time.perf_counter()
                out = ex(*stacked)
                jax.block_until_ready(out)
                return out, time.perf_counter() - t0
        t0 = time.perf_counter()
        out = ex(*stacked)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    def _run_attempts(self, kind: str, bucket: int, ex: Executor, stacked
                      ) -> Tuple[object, float]:
        """Fault-tolerant executor run: fire the chaos hook, then the
        executor; an exception with a truthy ``.transient`` attribute (the
        :class:`serving.faults.FaultInjected` contract — real transient
        infra errors can adopt it) retries with exponential backoff up to
        ``dispatch_retries`` times.  Anything else — or an exhausted
        budget — propagates to the caller, which fails every rider's
        future with the ORIGINAL traceback."""
        attempt = 0
        while True:
            try:
                if self._fault_hook is not None:
                    self._fault_hook(kind, bucket)
                return self._run_executor(ex, stacked)
            except BaseException as e:  # noqa: BLE001 — classified below
                if not getattr(e, "transient", False) \
                        or attempt >= self._dispatch_retries:
                    raise
                attempt += 1
                with self._stat_lock:
                    self.dispatch_retry_count += 1
                time.sleep(self._retry_backoff_s * (2 ** (attempt - 1)))

    def _dispatch(self, kind: str, bucket: int, ex: Executor,
                  batch: List[_PendingChunk]
                  ):  # flamecheck: host-sync-ok(dispatch boundary: results must land on host to fan back out to per-chunk futures)
        n = len(batch)
        try:
            B = self.policy.batch
            stacked = []
            n_lead = self._dedup.get(kind, 0)
            n_uniq = n
            if n_lead:
                # identity-dedup the leading args: chunks carrying the SAME
                # arg objects (one request split across chunks, or requests
                # sharing a pool entry) stack each unique row once; the
                # executor gathers per-row views through the idx argument
                slot_of: Dict[tuple, int] = {}
                uniq: List[tuple] = []
                idx = np.zeros(B, np.int32)
                for i, c in enumerate(batch):
                    ident = self._ident(c, n_lead)
                    slot = slot_of.get(ident)
                    if slot is None:
                        slot = len(uniq)
                        slot_of[ident] = slot
                        uniq.append(c.args[:n_lead])
                    idx[i] = slot
                n_uniq = len(uniq)
                for j in range(n_lead):
                    stacked.append(self._stack_rows([u[j] for u in uniq], B))
                stacked.append(idx)
                rests = [c.args[n_lead:] for c in batch]
            else:
                rests = [c.args for c in batch]
            for j in range(len(rests[0])):
                stacked.append(self._stack_rows([r[j] for r in rests], B))
            out, dt = self._run_attempts(kind, bucket, ex, stacked)
            if kind in self._device_output:
                host = out        # stays device-resident (pool entries)
            else:
                host = jax.tree.map(np.asarray, out)   # pytree outputs OK
            self._note_dispatch(kind, bucket, n, rows_used=n,
                                valid=sum(c.valid for c in batch),
                                saved=n - n_uniq, cost_s=dt, packed=False,
                                missed=self._count_missed(batch))
            for i, c in enumerate(batch):
                c.future.set_result(
                    jax.tree.map(lambda a: a[i:i + 1], host))
        except BaseException as e:  # noqa: BLE001 — fail every rider
            with self._stat_lock:
                self.dispatch_failure_count += 1
            for c in batch:
                if not c.future.done():
                    c.future.set_exception(e)

    def _dispatch_packed(self, kind: str, bucket: int, ex: Executor,
                         batch: List[_PendingChunk], packer: SegmentPacker
                         ):  # flamecheck: host-sync-ok(dispatch boundary: seg-index planes are built host-side and results fan back out to futures)
        """One packed dispatch: stack each unique KV identity once, build
        the ``[B, bucket]`` seg-index and candidate planes from the packer's
        placements, run the executor, and scatter each segment's exact
        ``[1, valid, ...]`` output slice back to its chunk future."""
        n = len(batch)
        try:
            B = self.policy.batch
            n_lead = self._packed[kind]
            # stack each unique KV identity once, in slot order
            uniq_args: List[Optional[tuple]] = [None] * packer.n_slots
            for c in batch:
                slot = packer.slot_of[self._ident(c, n_lead)]
                if uniq_args[slot] is None:
                    uniq_args[slot] = c.args[:n_lead]
            stacked = [self._stack_rows([u[j] for u in uniq_args], B)
                       for j in range(n_lead)]
            rows = self.policy.rows
            seg_idx = np.zeros((rows, bucket), np.int32)
            cands = np.full((rows, bucket), -1, np.int32)
            for c, (row, off, slot) in zip(batch, packer.placements):
                cands[row, off:off + c.valid] = np.asarray(c.args[n_lead])[0]
                seg_idx[row, off:off + c.valid] = slot
            stacked += [seg_idx, cands]
            out, dt = self._run_attempts(kind, bucket, ex, stacked)
            host = jax.tree.map(np.asarray, out)
            self._note_dispatch(kind, bucket, n, rows_used=packer.n_rows,
                                valid=sum(c.valid for c in batch),
                                saved=n - packer.n_slots, cost_s=dt,
                                packed=True,
                                missed=self._count_missed(batch))
            for c, (row, off, _) in zip(batch, packer.placements):
                c.future.set_result(jax.tree.map(
                    lambda a: a[row:row + 1, off:off + c.valid], host))
        except BaseException as e:  # noqa: BLE001 — fail every rider
            with self._stat_lock:
                self.dispatch_failure_count += 1
            for c in batch:
                if not c.future.done():
                    c.future.set_exception(e)

    # ---- introspection / lifecycle ----
    def stats(self) -> Dict[str, float]:
        with self._stat_lock:
            d = max(self.dispatch_count, 1)
            slots = sum(self.slot_count.values())
            valid = sum(self.valid_count.values())
            out = {
                "chunks": self.chunk_count,
                "dispatches": self.dispatch_count,
                "rows_dispatched": self.rows_dispatched,
                "avg_fill": self.rows_dispatched / d,
                "batch_axis": self.policy.batch,
                "dedup_rows_saved": self.dedup_rows_saved,
                "packed_rows": self.packed_rows,
                "packed_segments": self.packed_segments,
                "cand_slots": slots,
                "cand_valid": valid,
                "padded_fraction": 1.0 - valid / slots if slots else 0.0,
                "queue_delay_ms": (1e3 * self.queue_delay_total_s
                                   / max(self.queue_delay_count, 1)),
                "dispatch_retries": self.dispatch_retry_count,
                "dispatch_failures": self.dispatch_failure_count,
                "deadline_miss_chunks": sum(
                    self.deadline_miss_chunks.values()),
            }
            if not self._legacy:
                for kind in self.families:
                    out[f"chunks_{kind}"] = self.kind_chunks[kind]
                    out[f"dispatches_{kind}"] = self.kind_dispatches[kind]
                    out[f"deadline_miss_chunks_{kind}"] = \
                        self.deadline_miss_chunks[kind]
                    out[f"cand_slots_{kind}"] = sum(
                        s for (k, _), s in self.slot_count.items()
                        if k == kind)
                    out[f"cand_valid_{kind}"] = sum(
                        v for (k, _), v in self.valid_count.items()
                        if k == kind)
                for (kind, b), s in self.slot_count.items():
                    if s:
                        out[f"fill_{kind}_b{b}"] = \
                            self.valid_count[(kind, b)] / s
            return out

    def shutdown(self):
        self._stop = True
        for cond in self._cond.values():
            with cond:
                cond.notify_all()
        for th in self._threads:
            th.join(timeout=5.0)


# ---------------------------------------------------------------------------
# implicit-shape baseline (the paper's "Default" row in Table 5)
# ---------------------------------------------------------------------------

class ImplicitShapeEngine:
    """Plain jit: every novel candidate count triggers a fresh trace+compile,
    the XLA analogue of TensorRT implicit-shape dynamic (re)allocation."""

    def __init__(self, fn: Callable):
        self._fn = jax.jit(fn)
        self.compiles = 0
        self._seen: set = set()

    def score(self, request, m: int
              ):  # flamecheck: host-sync-ok(implicit-shape baseline engine: the per-request sync IS the modeled cost)
        if m not in self._seen:
            self._seen.add(m)
            self.compiles += 1
        out = self._fn(*request)
        jax.block_until_ready(out)
        return out
