"""Dynamic Stream Orchestrator (DSO) — explicit-shape executors + routing.

TPU/JAX mapping of the paper's §3.3 (see DESIGN.md):

  TensorRT profile w/ fixed batch shape  ->  AOT-compiled XLA executable
                                             (jit(f).lower(shapes).compile())
  preallocated I/O buffers               ->  persistent padded input buffers
  CUDA-graph capture                     ->  the AOT executable itself (one
                                             dispatch, no retrace)
  CUDA streams / executor index queue    ->  executor checkout queue +
                                             JAX async dispatch; worker
                                             threads interleave host work
  implicit-shape baseline                ->  plain jit re-traced/re-compiled
                                             for every novel candidate count

Routing: an upstream request with M candidates is split greedily into bucket
chunks in descending bucket order; the final partial chunk is padded up to
the smallest covering bucket (the paper's "split by batch size in descending
order").
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


# ---------------------------------------------------------------------------
# bucket routing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Chunk:
    bucket: int       # executor shape this chunk runs on
    start: int        # offset into the request's candidate list
    valid: int        # number of real candidates (<= bucket; rest is padding)


def split_request(m: int, buckets: Sequence[int]) -> List[Chunk]:
    """Greedy descending-bucket split of M candidates."""
    bs = sorted(set(buckets), reverse=True)
    assert m >= 1 and bs, (m, buckets)
    plan: List[Chunk] = []
    off, rem = 0, m
    for b in bs:
        while rem >= b:
            plan.append(Chunk(b, off, b))
            off += b
            rem -= b
    if rem > 0:
        cover = min(x for x in bs if x >= rem)  # smallest covering bucket
        plan.append(Chunk(cover, off, rem))
    return plan


def padded_fraction(m: int, buckets: Sequence[int]) -> float:
    plan = split_request(m, buckets)
    padded = sum(c.bucket for c in plan)
    return 1.0 - m / padded


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------

class Executor:
    """One AOT-compiled executable for a fixed candidate bucket."""

    def __init__(self, bucket: int, compiled, eid: int):
        self.bucket = bucket
        self.compiled = compiled
        self.eid = eid
        self.calls = 0

    def __call__(self, *args):
        self.calls += 1
        return self.compiled(*args)


class ExecutorPool:
    """Per-bucket executor index queues (paper Fig 10).

    ``build_fn(bucket)`` must return an AOT-compiled callable for that
    bucket's shapes.  ``n_streams`` executors are built per bucket — the
    CUDA-stream analogue: that many chunks of the same bucket can be in
    flight concurrently (JAX async dispatch overlaps their host work).
    """

    def __init__(self, build_fn: Callable[[int], Callable],
                 buckets: Sequence[int], n_streams: int = 2):
        self.buckets = sorted(set(buckets), reverse=True)
        self.queues: Dict[int, "queue.Queue[Executor]"] = {}
        self.build_time_s = 0.0
        eid = 0
        t0 = time.perf_counter()
        for b in self.buckets:
            q: "queue.Queue[Executor]" = queue.Queue()
            compiled = build_fn(b)
            for _ in range(n_streams):
                q.put(Executor(b, compiled, eid))
                eid += 1
            self.queues[b] = q
        self.build_time_s = time.perf_counter() - t0

    def acquire(self, bucket: int) -> Executor:
        return self.queues[bucket].get()

    def release(self, ex: Executor):
        self.queues[ex.bucket].put(ex)


# ---------------------------------------------------------------------------
# the orchestrator
# ---------------------------------------------------------------------------

class DynamicStreamOrchestrator:
    """Routes requests with arbitrary candidate counts onto the executor pool.

    ``pad_slice_fn(request, chunk)`` -> executor args for one chunk (padded
    to ``chunk.bucket``); ``gather_fn(results, chunks, m)`` -> final output.
    """

    def __init__(self, pool: ExecutorPool,
                 pad_slice_fn: Callable, gather_fn: Callable,
                 max_workers: int = 8):
        self.pool = pool
        self.pad_slice = pad_slice_fn
        self.gather = gather_fn
        self._tp = ThreadPoolExecutor(max_workers=max_workers)
        self.chunk_count = 0
        self._lock = threading.Lock()

    def _run_chunk(self, request, chunk: Chunk):
        ex = self.pool.acquire(chunk.bucket)
        try:
            args = self.pad_slice(request, chunk)
            out = ex(*args)
            jax.block_until_ready(out)
            return out
        finally:
            self.pool.release(ex)

    def submit(self, request, m: int):
        """Non-blocking: returns a future resolving to the gathered output."""
        plan = split_request(m, self.pool.buckets)
        with self._lock:
            self.chunk_count += len(plan)
        futs = [self._tp.submit(self._run_chunk, request, c) for c in plan]

        def resolve():
            results = [f.result() for f in futs]
            return self.gather(results, plan, m)

        return _Lazy(resolve)

    def score(self, request, m: int):
        """Blocking convenience wrapper."""
        return self.submit(request, m).result()

    def shutdown(self):
        self._tp.shutdown(wait=True)


class _Lazy:
    def __init__(self, fn):
        self._fn = fn

    def result(self):
        return self._fn()


# ---------------------------------------------------------------------------
# implicit-shape baseline (the paper's "Default" row in Table 5)
# ---------------------------------------------------------------------------

class ImplicitShapeEngine:
    """Plain jit: every novel candidate count triggers a fresh trace+compile,
    the XLA analogue of TensorRT implicit-shape dynamic (re)allocation."""

    def __init__(self, fn: Callable):
        self._fn = jax.jit(fn)
        self.compiles = 0
        self._seen: set = set()

    def score(self, request, m: int):
        if m not in self._seen:
            self._seen.add(m)
            self.compiles += 1
        out = self._fn(*request)
        jax.block_until_ready(out)
        return out
