"""Climber — the GR model FLAME serves (paper §2.1, Fig 2).

Architecture (faithful to the paper's description):
  * the user behavior sequence is reorganized into ``N_b`` sub-sequences,
    each processed by an independent transformer block (``layers_per_block``
    layers) — attention complexity drops from O(n^2 d) to O(n^2 d / N_b);
  * an adaptive temperature coefficient is applied before softmax in every
    attention (learned per block+layer, softplus-positive);
  * the M candidate items are concatenated after each block's sub-sequence
    and scored in parallel under the SUMI mask;
  * per-candidate block outputs are fused with bit-wise (per-dimension)
    gating across blocks;
  * a multi-task expert head (MMoE-style) produces ``num_tasks`` scores.

Training objective: multi-task binary cross-entropy against per-candidate
labels (click/like/finish-style engagement tasks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.core import sumi
from repro.models import attention as A
from repro.models import layers as L
from repro.models.ffn import ffn_init, ffn_apply
from repro.models.model import ModelBundle
from repro.types import ModelConfig, ShapeConfig

N_SIDE_FEATURES = 12   # "a dozen pieces of side information" (paper §4.1)


def _block_init(key, cfg, n_layers: int):
    """One transformer block's stacked params (+ adaptive temperature)."""
    ks = jax.random.split(key, 2)
    return {
        "norm1": L.norm_init(cfg, cfg.d_model, stacked=n_layers),
        "attn": A.qkv_init(ks[0], cfg, stacked=n_layers),
        "norm2": L.norm_init(cfg, cfg.d_model, stacked=n_layers),
        "ffn": ffn_init(ks[1], cfg, stacked=n_layers),
        # adaptive temperature, one per layer: tau = softplus(t) + 0.5
        "temp": L.zeros_init((1,), (None,), stacked=n_layers, fill=0.55,
                             dtype=jnp.float32),
    }


def climber_init(key, cfg: ModelConfig):
    c = cfg.climber
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    blocks = {f"b{i}": _block_init(jax.random.fold_in(ks[0], i), cfg,
                                   c.layers_per_block)
              for i in range(c.num_blocks)}
    dh = d  # expert hidden dim
    params = {
        "embed": {"embedding": L.dense_init(
            ks[1], (cfg.vocab_size, d), ("vocab", "embed"), scale=0.02)},
        "pos_embed": L.dense_init(ks[2], (8192, d), (None, "embed"), scale=0.02),
        "side_proj": L.dense_init(ks[3], (N_SIDE_FEATURES, d), (None, "embed")),
        "blocks": blocks,
        # bit-wise gating fusion across blocks
        "gate_w": L.dense_init(ks[4], (c.num_blocks, d), (None, "embed"),
                               scale=0.02),
        "gate_b": L.zeros_init((c.num_blocks, d), (None, "embed")),
        "out_norm": L.norm_init(cfg, d),
        # MMoE head: experts, per-task gates, per-task towers
        "experts_w1": L.dense_init(ks[5], (c.num_experts_head, d, dh),
                                   (None, "embed", "mlp"), fan_in_axes=(1,)),
        "experts_w2": L.dense_init(ks[6], (c.num_experts_head, dh, dh),
                                   (None, "mlp", "embed"), fan_in_axes=(1,)),
        "task_gates": L.dense_init(ks[7], (c.num_tasks, d, c.num_experts_head),
                                   (None, "embed", None), fan_in_axes=(1,)),
        "task_towers": L.dense_init(jax.random.fold_in(key, 9),
                                    (c.num_tasks, dh), (None, "embed"),
                                    fan_in_axes=(1,)),
    }
    return L.split_params(params)


def _tau(p):
    """Adaptive temperature for one layer's params."""
    return jax.nn.softplus(p["temp"][0]) + 0.5


def _history_block_inputs(params, batch: Dict, cfg) -> list:
    """Embed the history and reorganize it into per-block input sequences:
    [sub-sequence + positional embeddings, context side token].

    The side token rides at the END of each block's history prefix (not the
    front): under the causal prefix mask the history items then never attend
    to it, so their per-layer K/V depend *only* on the item ids — the
    property PDA v2's incremental extension exploits (a side-feature-only
    change re-encodes one token per block instead of the whole prefix).
    The side token itself still sees every history item, and candidates see
    history + side + self, so side information reaches every score."""
    hist = jnp.take(params["embed"]["embedding"], batch["history"], axis=0)
    b, n, d = hist.shape
    side = jnp.einsum("bf,fd->bd", batch["side"].astype(hist.dtype),
                      params["side_proj"])[:, None]
    nb = cfg.climber.num_blocks
    sub = hist.reshape(b, nb, n // nb, d)
    return [jnp.concatenate([sub[:, i] + params["pos_embed"][None, :n // nb],
                             side], axis=1)
            for i in range(nb)]


def _fuse_and_head(params, h, cfg):
    """Per-candidate block outputs h [B,M,Nb,d] -> task logits [B,M,T]."""
    # bit-wise gating fusion: per-dimension softmax over blocks
    gate_logits = h.astype(jnp.float32) * params["gate_w"].astype(jnp.float32) \
        + params["gate_b"].astype(jnp.float32)
    gates = jax.nn.softmax(gate_logits, axis=2)
    fused = (gates * h.astype(jnp.float32)).sum(axis=2)  # [B,M,d]
    fused = shd.constrain_ctx(fused, "batch", None, None)
    fused = L.apply_norm(cfg, params["out_norm"], fused)

    # MMoE expert head
    e1 = jnp.einsum("bmd,edh->bmeh", fused, params["experts_w1"].astype(jnp.float32))
    e1 = jax.nn.gelu(e1)
    e2 = jnp.einsum("bmeh,ehg->bmeg", e1, params["experts_w2"].astype(jnp.float32))
    tg = jax.nn.softmax(jnp.einsum("bmd,tde->bmte", fused,
                                   params["task_gates"].astype(jnp.float32)),
                        axis=-1)
    mix = jnp.einsum("bmte,bmeg->bmtg", tg, e2)
    logits = jnp.einsum("bmtg,tg->bmt", mix, params["task_towers"].astype(jnp.float32))
    return logits


def _layer_tail(p, x, o, cfg, impl: str):
    """Everything after attention in one layer step: out-projection +
    residual + norm + FFN + residual.  ``impl="fused"`` routes the FKE
    epilogue (reuses the ``kernels/fused_ffn`` Pallas kernel on TPU; the
    identical framework composition elsewhere)."""
    if impl == "fused":
        from repro.kernels.fused_score import ops as fs_ops
        return fs_ops.block_epilogue(x, o, p["attn"], p["norm2"],
                                     p["ffn"], cfg)
    x = x + A.project_out(p["attn"], o)
    h2 = L.apply_norm(cfg, p["norm2"], x)
    return x + ffn_apply(p["ffn"], h2, cfg, impl=impl)


def _block_forward(bp, x, n_history: int, cfg, impl: str):
    """x [B,S,d] through one stacked transformer block under the SUMI mask.

    All candidates share position ``n_history`` (each is a hypothetical
    "next item"), which makes scoring permutation-invariant across the
    candidate set — required for DSO chunk-splitting to be exact."""
    b, s, d = x.shape
    pos = jnp.concatenate([jnp.arange(n_history),
                           jnp.full((s - n_history,), n_history)])
    positions = jnp.broadcast_to(pos, (b, s))

    def layer(x, p):
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions)
        # mesh-sharded serving: batch over data, heads tensor-parallel
        # (no-op without an active mesh_rules context)
        q = shd.constrain_ctx(q, "batch", None, "heads", None)
        o = sumi.sumi_attention(q, k, v, n_history, impl=impl,
                                temperature=_tau(p))
        return _layer_tail(p, x, o, cfg, impl), None

    from repro.models.transformer import scan_or_unroll
    x, _ = scan_or_unroll(layer, x, bp)
    return x


def _block_encode_kv(bp, x, cfg, impl: str):
    """History-only causal pass over one block; returns per-layer K/V.

    Under the SUMI mask the history prefix is self-contained (causal among
    itself, blind to candidates), so the K/V recorded here are exactly the
    history rows the monolithic pass would compute."""
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def layer(x, p):
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions)
        q = shd.constrain_ctx(q, "batch", None, "heads", None)
        # n_history == s: the SUMI mask degenerates to causal here
        o = sumi.sumi_attention(q, k, v, s, impl=impl, temperature=_tau(p))
        return _layer_tail(p, x, o, cfg, impl), (k, v)

    from repro.models.transformer import scan_or_unroll
    _, kv = scan_or_unroll(layer, x, bp)
    return kv                                  # (k, v), each [L,B,s,Hkv,D]


def _block_score(bp, cand, k_hist, v_hist, cfg, impl: str, *,
                 k_scale=None, v_scale=None, row_index=None):
    """Candidate-only pass for one block against cached history K/V.

    ``cand`` [B,M,d]; ``k_hist``/``v_hist`` [L,U,n_hist,Hkv,D].  Candidates
    all sit at RoPE position ``n_hist`` exactly as in the monolithic pass.

    FKE operands: the history K/V may arrive in the pool's stored
    precision with per-(layer, row, head) ``k_scale``/``v_scale``
    ([L,U,1,Hkv,1]) and a ``row_index`` [B] mapping batch rows onto the
    ``U`` unique pool rows (KV-row dedup) — or [B, M] mapping every
    CANDIDATE onto its own pool row (DSO v2 segment packing: one row may
    carry segments of several users).  ``impl="fused"`` consumes them
    in-kernel; other impls materialize the dequantized gather first (see
    ``sumi.cached_candidate_attention``)."""
    b, m, d = cand.shape
    n_hist = k_hist.shape[2]
    positions = jnp.broadcast_to(jnp.asarray(n_hist), (b, m))
    has_scale = k_scale is not None

    def layer(x, inp):
        if has_scale:
            p, kh, vh, khs, vhs = inp
        else:
            (p, kh, vh), khs, vhs = inp, None, None
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions)
        # mesh-sharded serving: candidate queries shard batch-over-data and
        # heads-over-model; the stacked pool rows kh/vh [U,S,Hkv,D] keep
        # their user axis replicated (so the per-candidate row gather never
        # crosses shards) with heads — or, CP fallback, the history
        # length — on the model axis
        q = shd.constrain_ctx(q, "batch", None, "heads", None)
        kh = shd.constrain_ctx(kh, None, "cache_seq_shard", "cache_heads",
                               None)
        vh = shd.constrain_ctx(vh, None, "cache_seq_shard", "cache_heads",
                               None)
        o = sumi.cached_candidate_attention(
            q, kh, vh, k, v, impl=impl, temperature=_tau(p),
            k_scale=khs, v_scale=vhs, row_index=row_index)
        return _layer_tail(p, x, o, cfg, impl), None

    from repro.models.transformer import scan_or_unroll
    inp = (bp, k_hist, v_hist)
    if has_scale:
        inp = inp + (k_scale, v_scale)
    x, _ = scan_or_unroll(layer, cand, inp)
    return x


def encode_history(params, batch: Dict, cfg: ModelConfig, *,
                   impl: str = "reference"):
    """batch: history [B,n] ids, side [B,F] -> HistoryKV pytree.

    Per block ``b{i}``: {"k", "v"} with shape [B, L, n_hist_block, Hkv, D]
    (batch axis leading, so serving can stack pool entries from different
    requests along axis 0).  n_hist_block = n // num_blocks + 1: positions
    ``0..w-1`` are the block's history items (K/V depending only on the
    item ids) and position ``w`` is the context side token folding the side
    features in (see :func:`_history_block_inputs`); ``w = n //
    num_blocks``.  :func:`extend_history` can therefore refresh a cached
    entry by re-encoding only the suffix that actually changed."""
    kv = {}
    for i, xb in enumerate(_history_block_inputs(params, batch, cfg)):
        k, v = _block_encode_kv(params["blocks"][f"b{i}"], xb, cfg, impl)
        kv[f"b{i}"] = {"k": jnp.moveaxis(k, 1, 0), "v": jnp.moveaxis(v, 1, 0)}
    return kv


def _block_extend_kv(bp, x_suf, k_pref, v_pref, cfg, impl: str):
    """Suffix-only causal pass for one block against cached prefix K/V.

    ``x_suf`` [B,S_suf,d] holds the block inputs from position ``P``
    onward (changed history items + the side token); ``k_pref``/``v_pref``
    [L,B,P,Hkv,D] are the trusted rows of a cached encode.  Returns the
    per-layer K/V of the suffix positions — bitwise what a full
    :func:`_block_encode_kv` would produce for those rows (reference
    impl), because causal attention at position >= P sees exactly
    ``concat(prefix, suffix)``."""
    b, s_suf, d = x_suf.shape
    p0 = k_pref.shape[2]
    positions = jnp.broadcast_to(p0 + jnp.arange(s_suf), (b, s_suf))

    def layer(x, inp):
        p, kh, vh = inp
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions)
        o = sumi.extend_attention(q, kh, vh, k, v, impl=impl,
                                  temperature=_tau(p))
        return _layer_tail(p, x, o, cfg, impl), (k, v)

    from repro.models.transformer import scan_or_unroll
    _, kv = scan_or_unroll(layer, x_suf, (bp, k_pref, v_pref))
    return kv                                  # (k, v), each [L,B,S_suf,Hkv,D]


def _dequant_stored_entry(entry, dtype):
    """A pool entry leaf is either a plain array or a raw ``(values,
    scale)`` view in the pool's stored precision (``scale is None`` marks
    a plain bf16 cast — see ``serving/kv_cache.py::raw_kv_view``).
    Dequantize IN-GRAPH to the compute dtype: the fused extend executors
    are compiled against raw pool specs, so the stale entry ships to the
    device in its stored (int8: 4x smaller) representation and this is
    the only dequantization it ever sees — same formula as the pool's
    host-side ``dequantize_leaf``, so the result is bitwise identical."""
    if isinstance(entry, tuple):
        from repro.kernels.fused_score.ref import dequantize_values
        values, scale = entry
        return dequantize_values(values, scale, dtype)
    return entry


def extend_history(params, history_kv, batch: Dict, cfg: ModelConfig, *,
                   prefix_len: int, impl: str = "reference"):
    """Incremental suffix extension of a cached HistoryKV (PDA v2).

    Trusts the first ``prefix_len`` positions of the model's history window
    to be unchanged since ``history_kv`` was encoded, and re-encodes only
    the remainder: per block, the history items at window positions >=
    ``prefix_len`` plus the side token (which always re-encodes — side
    features average the *full* upstream history, so any history change
    moves them).  ``prefix_len == n`` is the dominant serving case: a
    tail-append beyond the model window re-encodes exactly one token per
    block instead of ``n/N_b + 1``.

    Returns a full HistoryKV pytree (cached prefix rows + fresh suffix
    rows), bitwise-identical to ``encode_history(params, batch)`` under the
    reference/chunked impls whenever the trust assumption holds.

    ``history_kv`` leaves may be raw ``(values, scale)`` pool views in the
    pool's stored precision — the quantized-extend-basis path: the stale
    entry is dequantized here, inside the compiled executor, instead of on
    the host before dispatch."""
    n = batch["history"].shape[1]
    nb = cfg.climber.num_blocks
    w = n // nb
    if not 0 <= prefix_len <= n:
        raise ValueError(f"prefix_len must be in [0, {n}], got {prefix_len}")
    kv = {}
    for i, xb in enumerate(_history_block_inputs(params, batch, cfg)):
        p_i = min(max(prefix_len - i * w, 0), w)
        old = history_kv[f"b{i}"]
        k_all = jnp.moveaxis(_dequant_stored_entry(old["k"], xb.dtype), 1, 0)
        v_all = jnp.moveaxis(_dequant_stored_entry(old["v"], xb.dtype), 1, 0)
        k_new, v_new = _block_extend_kv(
            params["blocks"][f"b{i}"], xb[:, p_i:],
            k_all[:, :, :p_i], v_all[:, :, :p_i], cfg, impl)
        k_full = jnp.concatenate([k_all[:, :, :p_i], k_new], axis=2)
        v_full = jnp.concatenate([v_all[:, :, :p_i], v_new], axis=2)
        kv[f"b{i}"] = {"k": jnp.moveaxis(k_full, 1, 0),
                       "v": jnp.moveaxis(v_full, 1, 0)}
    return kv


def _split_stored(entry):
    """A HistoryKV leaf is either a plain [B,L,S,Hkv,D] array or a
    ``(values, scale)`` raw pool view (``serving/kv_cache.py::
    raw_kv_view``); returns (values, scale-or-None) in [L,B,...] layout."""
    values, scale = entry if isinstance(entry, tuple) else (entry, None)
    values = jnp.moveaxis(values, 1, 0)
    if scale is not None:
        scale = jnp.moveaxis(scale, 1, 0)
    return values, scale


def score_candidates(params, history_kv, candidates, cfg: ModelConfig, *,
                     impl: str = "reference", row_index=None):
    """Candidate-only forward against cached history K/V.

    ``candidates`` [B,M] ids; ``history_kv`` from :func:`encode_history` —
    either dequantized arrays or raw pool views (``(values, scale)``
    tuples in the pool's stored precision), with an optional ``row_index``
    [B] mapping batch rows onto unique pool rows (KV-row dedup).  Returns
    task logits [B,M,T] — numerically identical to the candidate slice of
    :func:`climber_forward` (bitwise under the reference impl on
    dequantized operands)."""
    cand = jnp.take(params["embed"]["embedding"], candidates, axis=0)
    if row_index is not None:
        row_index = jnp.asarray(row_index, jnp.int32)
    block_outs = []
    for i in range(cfg.climber.num_blocks):
        kv = history_kv[f"b{i}"]
        kh, khs = _split_stored(kv["k"])
        vh, vhs = _split_stored(kv["v"])
        block_outs.append(_block_score(
            params["blocks"][f"b{i}"], cand, kh, vh, cfg, impl,
            k_scale=khs, v_scale=vhs, row_index=row_index))
    h = jnp.stack(block_outs, axis=2)                   # [B,M,Nb,d]
    return _fuse_and_head(params, h, cfg)


def _block_decode(bp, cand, k_hist, v_hist, lengths, cfg, impl: str, *,
                  k_scale=None, v_scale=None, row_index=None,
                  collect_kv: bool = False):
    """Generative-decode pass for one block against a PADDED beam cache.

    Like :func:`_block_score` but the cached history is a growing beam
    cache whose valid prefix per row is ``lengths`` [B] (or [U] with a
    packed ``row_index`` [B,M] steering every candidate to its own beam
    row).  Each candidate sits at RoPE position ``lengths`` — the next
    slot of ITS OWN sequence — so a decode step over the vocab is
    `score_candidates(M=V)` at the beam's current length.  With
    ``collect_kv`` the per-layer candidate K/V are returned too (the
    append path: the chosen token's K/V are exactly what this pass
    computed for it)."""
    b, m, d = cand.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    if row_index is not None:
        positions = jnp.take(lengths, row_index)
    else:
        positions = jnp.broadcast_to(lengths[:, None], (b, m))
    has_scale = k_scale is not None

    def layer(x, inp):
        if has_scale:
            p, kh, vh, khs, vhs = inp
        else:
            (p, kh, vh), khs, vhs = inp, None, None
        h = L.apply_norm(cfg, p["norm1"], x)
        q, k, v = A.project_qkv(p["attn"], h, cfg, positions)
        q = shd.constrain_ctx(q, "batch", None, "heads", None)
        o = sumi.decode_candidate_attention(
            q, kh, vh, k, v, lengths, impl=impl, temperature=_tau(p),
            k_scale=khs, v_scale=vhs, row_index=row_index)
        return _layer_tail(p, x, o, cfg, impl), \
            ((k, v) if collect_kv else None)

    from repro.models.transformer import scan_or_unroll
    inp = (bp, k_hist, v_hist)
    if has_scale:
        inp = inp + (k_scale, v_scale)
    x, kv = scan_or_unroll(layer, cand, inp)
    return x, kv


def decode_logits(params, history_kv, candidates, lengths, cfg: ModelConfig,
                  *, impl: str = "reference", row_index=None):
    """One generative-decode scoring step: task logits [B,M,T] for M
    next-token candidates against padded beam caches.

    ``history_kv`` leaves are [B,L,S_pad,Hkv,D] (or [U,...] packed) with
    valid prefix ``lengths`` per row; every candidate scores as the
    hypothetical next item of its beam.  At ``lengths == S_pad`` (no
    padding) this is bitwise :func:`score_candidates` — one decode step
    IS `score_candidates(M=V)` + argmax, the oracle identity the decode
    test suite pins down."""
    cand = jnp.take(params["embed"]["embedding"], candidates, axis=0)
    if row_index is not None:
        row_index = jnp.asarray(row_index, jnp.int32)
    block_outs = []
    for i in range(cfg.climber.num_blocks):
        kv = history_kv[f"b{i}"]
        kh, khs = _split_stored(kv["k"])
        vh, vhs = _split_stored(kv["v"])
        x, _ = _block_decode(
            params["blocks"][f"b{i}"], cand, kh, vh, lengths, cfg, impl,
            k_scale=khs, v_scale=vhs, row_index=row_index)
        block_outs.append(x)
    h = jnp.stack(block_outs, axis=2)                   # [B,M,Nb,d]
    return _fuse_and_head(params, h, cfg)


def append_token(params, history_kv, tokens, lengths, cfg: ModelConfig, *,
                 impl: str = "reference"):
    """Write one chosen token's per-layer K/V into every block's padded
    beam cache at position ``lengths`` (the beam's next free slot).

    ``tokens`` [B,1] ids; ``history_kv`` leaves are [B,L,S_pad,Hkv,D] —
    plain (dequantized) arrays under the chunked engine, or raw
    ``(values, scale)`` pool views under ``impl="fused"`` (FKE v2: the
    appended token is quantized IN-GRAPH against the entry's fixed
    per-(row, layer, head) scale and scattered straight into the stored
    int8 values, so the beam cache never leaves the pool's stored
    precision).  ``lengths < S_pad`` is the caller's contract (the engine
    pads caches by the generation budget up front; `dynamic_update_slice`
    clamps, so an unpadded full cache would silently overwrite its last
    history row).  The written K/V are computed by the same decode-pass
    layer chain that scored the token, so an incrementally-grown cache is
    bitwise the cache a monolithic re-encode of history+tokens would
    produce (reference impl) — asserted in tests/test_decode_serving.py."""
    tok = jnp.take(params["embed"]["embedding"], tokens, axis=0)  # [B,1,d]
    lengths = jnp.asarray(lengths, jnp.int32)
    new_kv = {}
    for i in range(cfg.climber.num_blocks):
        kv = history_kv[f"b{i}"]
        kh, khs = _split_stored(kv["k"])
        vh, vhs = _split_stored(kv["v"])
        _, (k_new, v_new) = _block_decode(
            params["blocks"][f"b{i}"], tok, kh, vh, lengths, cfg, impl,
            k_scale=khs, v_scale=vhs, collect_kv=True)

        def scatter(entry, new):
            values, scale = entry if isinstance(entry, tuple) \
                else (entry, None)
            new = jnp.moveaxis(new, 1, 0)               # [B,L,1,Hkv,D]
            if scale is not None:
                # quantize against the entry's FIXED absmax scale
                # ([B,L,1,Hkv,1]) — the stored rows keep their original
                # codes, so only the appended slot rounds (and clips, if
                # the token's K/V exceed the row's absmax)
                new = jnp.clip(
                    jnp.round(new.astype(jnp.float32) / scale * 127.0),
                    -127, 127)
            out = jax.vmap(
                lambda c, t, n: jax.lax.dynamic_update_slice(
                    c, t.astype(c.dtype), (0, n, 0, 0)))(
                values, new, lengths)
            return out if not isinstance(entry, tuple) else (out, scale)
        new_kv[f"b{i}"] = {"k": scatter(kv["k"], k_new),
                           "v": scatter(kv["v"], v_new)}
    return new_kv


def history_kv_specs(params, cfg: ModelConfig, n_history: int,
                     batch: int = 1):
    """ShapeDtypeStruct pytree of the HistoryKV for AOT executor builds."""
    batch_spec = {
        "history": jax.ShapeDtypeStruct((batch, n_history), jnp.int32),
        "side": jax.ShapeDtypeStruct((batch, N_SIDE_FEATURES), jnp.float32),
    }
    return jax.eval_shape(lambda p, b: encode_history(p, b, cfg),
                          params, batch_spec)


def climber_forward(params, batch: Dict, cfg: ModelConfig, *,
                    impl: str = "reference"):
    """batch: history [B,n] ids, candidates [B,M] ids, side [B,F].
    Returns task logits [B, M, num_tasks]."""
    cand = jnp.take(params["embed"]["embedding"], batch["candidates"], axis=0)
    block_outs = []
    for i, xb in enumerate(_history_block_inputs(params, batch, cfg)):
        seq, n_hist = sumi.assemble(xb, cand)
        out = _block_forward(params["blocks"][f"b{i}"], seq, n_hist, cfg, impl)
        block_outs.append(sumi.split_candidates(out, n_hist))
    h = jnp.stack(block_outs, axis=2)                   # [B,M,Nb,d]
    return _fuse_and_head(params, h, cfg)


def build_climber(cfg: ModelConfig) -> ModelBundle:
    c = cfg.climber

    def init(key):
        return climber_init(key, cfg)

    def loss_fn(params, batch, impl: str = "reference"):
        logits = climber_forward(params, batch, cfg, impl=impl)
        labels = batch["labels"].astype(jnp.float32)
        ls = jnp.mean(
            jnp.maximum(logits, 0) - logits * labels
            + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        return ls, {"bce_loss": ls}

    def prefill(params, batch, impl: str = "reference", caches=None):
        """Serving entry: per-candidate multi-task probabilities [B,M,T]."""
        return jax.nn.sigmoid(climber_forward(params, batch, cfg, impl=impl))

    def encode_history_fn(params, batch, impl: str = "reference"):
        """Serving entry: history-only pass -> cacheable HistoryKV pytree."""
        return encode_history(params, batch, cfg, impl=impl)

    def score_candidates_fn(params, history_kv, candidates,
                            impl: str = "reference", row_index=None):
        """Serving entry: candidate-only probabilities [B,M,T] against a
        cached HistoryKV — prefill == score_candidates(encode_history).
        ``history_kv`` may be a raw pool view (stored-precision values +
        scales) and ``row_index`` a [B] KV-row dedup gather; see
        :func:`score_candidates`."""
        return jax.nn.sigmoid(
            score_candidates(params, history_kv, candidates, cfg, impl=impl,
                             row_index=row_index))

    def extend_history_fn(params, history_kv, batch, *, prefix_len: int,
                          impl: str = "reference"):
        """Serving entry: suffix-only re-encode of a cached HistoryKV whose
        first ``prefix_len`` window positions are unchanged."""
        return extend_history(params, history_kv, batch, cfg,
                              prefix_len=prefix_len, impl=impl)

    def history_kv_specs_fn(params, n_history: int, batch: int = 1):
        return history_kv_specs(params, cfg, n_history, batch)

    def decode_logits_fn(params, history_kv, candidates, lengths,
                         impl: str = "reference", row_index=None):
        """Serving entry: one generative-decode step -> per-candidate
        probabilities [B,M,T] (same sigmoid as score_candidates_fn, so a
        decode step at full length is bitwise a score_candidates call)."""
        return jax.nn.sigmoid(
            decode_logits(params, history_kv, candidates, lengths, cfg,
                          impl=impl, row_index=row_index))

    def append_token_fn(params, history_kv, tokens, lengths,
                        impl: str = "reference"):
        """Serving entry: grow every block's padded beam cache by the
        chosen token's K/V at position ``lengths``."""
        return append_token(params, history_kv, tokens, lengths, cfg,
                            impl=impl)

    def decode_step(params, caches, batch, impl: str = "reference"):
        raise NotImplementedError(
            "Climber scores all candidates in one SUMI pass; no decode step.")

    def cache_init(batch, max_len, dtype=jnp.bfloat16):
        raise NotImplementedError("Climber serving is single-pass (no KV cache).")

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        n, m = shape.seq_len, shape.n_candidates
        specs = {
            "history": jax.ShapeDtypeStruct((b, n), jnp.int32),
            "candidates": jax.ShapeDtypeStruct((b, m), jnp.int32),
            "side": jax.ShapeDtypeStruct((b, N_SIDE_FEATURES), jnp.float32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, m, c.num_tasks),
                                                   jnp.float32)
        return specs

    def input_logical(shape: ShapeConfig):
        lg = {"history": ("batch", None), "candidates": ("batch", None),
              "side": ("batch", None)}
        if shape.kind == "train":
            lg["labels"] = ("batch", None, None)
        return lg

    return ModelBundle(cfg, init, loss_fn, prefill, decode_step,
                       input_specs, input_logical, cache_init,
                       encode_history=encode_history_fn,
                       score_candidates=score_candidates_fn,
                       history_kv_specs=history_kv_specs_fn,
                       extend_history=extend_history_fn,
                       decode_logits=decode_logits_fn,
                       append_token=append_token_fn)
