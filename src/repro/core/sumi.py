"""SUMI — single user, multiple items: FLAME's request paradigm.

A GR ranking request carries one user history (length n) and M candidate
items.  All M candidates are scored in ONE forward pass by concatenating them
after the history and applying the SUMI mask (candidates attend to history
and themselves, never to each other) — the HSTU-style parallel-prediction
trick the paper bakes into its mask-aware flash-attention plug-in.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A


def assemble(history_emb: jnp.ndarray, cand_emb: jnp.ndarray
             ) -> Tuple[jnp.ndarray, int]:
    """[B,n,d] + [B,M,d] -> ([B,n+M,d], n_history)."""
    return jnp.concatenate([history_emb, cand_emb], axis=1), history_emb.shape[1]


def split_candidates(x: jnp.ndarray, n_history: int) -> jnp.ndarray:
    """[B,n+M,d] -> candidate outputs [B,M,d]."""
    return x[:, n_history:]


def sumi_attention(q, k, v, n_history: int, *, impl: str = "reference",
                   temperature=None):
    """Mask-aware attention under the SUMI mask.  q/k/v [B,S,H,D]."""
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    return A.attention(q, k, v, "sumi", impl=impl, n_history=n_history)


def _dequant_gather(k, v, k_scale, v_scale, row_index, dtype):
    """Materialize pool-stored operands for the framework (non-fused)
    impls: dequantize + per-row gather — the exact sequence the FKE
    oracle defines (one implementation, so the framework impls can never
    drift from what the fused paths are gated against)."""
    from repro.kernels.fused_score.ref import _prep
    return _prep(k, v, k_scale, v_scale, row_index, dtype)


def _segment_packed_attention(q, k_hist, v_hist, k_cand, v_cand, seg):
    """Cached-candidate SUMI attention for a segment-packed row (framework
    impls; ``impl="fused"`` handles the 2-D index natively in ops.py).

    ``seg`` [B, M] maps every candidate to its user's (dequantized) pool
    row in ``k_hist``/``v_hist`` [U, S, Hkv, D].  The computation mirrors
    ``models/attention.py::reference_attention`` op for op — einsum scores
    scaled by 1/sqrt(D), -1e30 mask fill, softmax over the [M, S+M] axis,
    one output reduction over S+M — with the history operands gathered per
    CANDIDATE instead of shared per row.  Masked positions (other
    segments' candidates) contribute exact zeros, and every reduction has
    the same length and per-element operand values as the unpacked
    shared-KV row, so packed scores are bitwise-identical to unpacked
    dispatches wherever the framework impl routes to the reference path
    (all serving-scale cached executors do; asserted in
    tests/test_dso_v2.py)."""
    b, m, h, d = q.shape
    hkv = k_cand.shape[2]
    g = h // hkv
    s = k_hist.shape[1]
    kh = jnp.take(k_hist, seg, axis=0)             # [B, M, S, Hkv, D]
    vh = jnp.take(v_hist, seg, axis=0)
    qf = q.astype(jnp.float32).reshape(b, m, hkv, g, d)
    s_hist = jnp.einsum("bmhgd,bmshd->bhgms", qf,
                        kh.astype(jnp.float32)) / np.sqrt(d)
    s_cand = jnp.einsum("bmhgd,bkhd->bhgmk", qf,
                        k_cand.astype(jnp.float32)) / np.sqrt(d)
    scores = jnp.concatenate([s_hist, s_cand], axis=-1)   # [b,hkv,g,m,S+M]
    mask = A.make_mask(m, s + m, "sumi", n_history=s, q_offset=s)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    vc = jnp.broadcast_to(v_cand.astype(jnp.float32)[:, None],
                          (b, m, m, hkv, d))
    v_all = jnp.concatenate([vh.astype(jnp.float32), vc], axis=2)
    o = jnp.einsum("bhgmk,bmkhd->bmhgd", w, v_all)
    return o.reshape(b, m, h, d).astype(q.dtype)


def cached_candidate_attention(q, k_hist, v_hist, k_cand, v_cand, *,
                               impl: str = "reference", temperature=None,
                               k_scale=None, v_scale=None, row_index=None):
    """Candidate-only SUMI attention against cached per-layer history K/V.

    The SUMI mask makes the history prefix self-contained (history rows are
    causal among themselves and never see candidates), so the history-side
    K/V depend only on the user history and can be reused across requests.
    Here ``q``/``k_cand``/``v_cand`` are [B,M,...] candidate projections and
    ``k_hist``/``v_hist`` [B,n_history,...] come from a cached
    ``encode_history`` pass; query row i sits at absolute KV position
    ``n_history + i`` (its own key), which every impl honors via
    ``q_offset``.  Output is bit-for-bit the candidate slice of the
    monolithic SUMI pass under the reference impl (allclose for the
    block-reordered chunked/pallas/fused impls).

    FKE operand extensions: ``k_hist``/``v_hist`` may arrive in the
    history pool's *stored* precision (int8/bf16) with per-(row, head)
    ``k_scale``/``v_scale``, and ``row_index`` [B] selects each batch
    row's pool row (the DSO's KV-row dedup).  ``impl="fused"`` consumes
    them in-kernel (no dequant / gather / concat materialization); every
    other impl materializes the framework operands first.

    DSO v2 segment packing: ``row_index`` may instead be ``[B, M]`` — a
    per-CANDIDATE pool-row index, so one batch row can carry candidate
    segments of *different* users (each candidate attends to its own
    user's history + itself; candidates never see each other under SUMI,
    so packing is exact by construction).  ``impl="fused"`` gathers the
    stored rows per candidate (jnp path) or steers per-q-block KV reads
    through scalar prefetch (kernel path); the framework impls run
    :func:`_segment_packed_attention` — the reference computation with
    per-candidate gathered history, bitwise-identical to the unpacked
    shared-KV dispatch."""
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    if impl == "fused":
        from repro.kernels.fused_score import ops as fs_ops
        return fs_ops.fused_cached_attention(
            q, k_hist, v_hist, k_cand, v_cand, k_scale=k_scale,
            v_scale=v_scale, row_index=row_index)
    if row_index is not None and jnp.ndim(row_index) == 2:
        k_hist, v_hist = _dequant_gather(k_hist, v_hist, k_scale, v_scale,
                                         None, q.dtype)
        return _segment_packed_attention(q, k_hist, v_hist, k_cand, v_cand,
                                         jnp.asarray(row_index, jnp.int32))
    if k_scale is not None or v_scale is not None or row_index is not None \
            or k_hist.dtype != q.dtype:
        k_hist, v_hist = _dequant_gather(k_hist, v_hist, k_scale, v_scale,
                                         row_index, q.dtype)
    n_history = k_hist.shape[1]
    k = jnp.concatenate([k_hist, k_cand], axis=1)
    v = jnp.concatenate([v_hist, v_cand], axis=1)
    return A.attention(q, k, v, "sumi", impl=impl, n_history=n_history,
                       q_offset=n_history)


def _kernel_decode_attention(q, k_hist, v_hist, k_cand, v_cand, lengths):
    """Generative-decode scoring via the seed's flash-decode kernel
    (``kernels/flash_decode``): each candidate's own K/V is written into a
    private copy of its cache row at position ``lengths`` and the kernel
    runs single-token decode attention with ``lengths + 1`` — the
    "decode step = score_candidates(M=1) + KV append" identity made
    literal.  ``k_hist``/``v_hist`` arrive PRE-GATHERED per candidate
    ([B,M,S,Hkv,D]) with ``lengths`` [B,M]."""
    from repro.kernels.flash_decode.ops import flash_decode
    b, m, h, d = q.shape
    s = k_hist.shape[2]
    hkv = k_cand.shape[2]
    # one spare column so a full (unpadded) cache still has a self slot
    kh = jnp.pad(k_hist, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))
                 ).reshape(b * m, s + 1, hkv, d)
    vh = jnp.pad(v_hist, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0))
                 ).reshape(b * m, s + 1, hkv, d)
    lens = lengths.reshape(b * m).astype(jnp.int32)
    put = jax.vmap(lambda c, t, i: jax.lax.dynamic_update_slice(
        c, t, (i, 0, 0)))
    kh = put(kh, k_cand.reshape(b * m, 1, hkv, d), lens)
    vh = put(vh, v_cand.reshape(b * m, 1, hkv, d), lens)
    o = flash_decode(q.reshape(b * m, h, d), kh, vh, lens + 1)
    return o.reshape(b, m, h, d)


def decode_candidate_attention(q, k_hist, v_hist, k_cand, v_cand, lengths, *,
                               impl: str = "reference", temperature=None,
                               k_scale=None, v_scale=None, row_index=None):
    """Generative-decode SUMI attention against a padded, growing cache.

    Same contract as :func:`cached_candidate_attention` except the history
    operands are PRE-PADDED beam caches whose valid prefix per row is
    ``lengths`` (int32): candidate m attends to cache positions ``<
    lengths`` plus itself, never to other candidates or cache padding.
    Because masked positions contribute exact softmax zeros, a padded
    cache scores bitwise-identically to the tight cache — and at
    ``lengths == S`` with no padding this is op-for-op
    :func:`cached_candidate_attention` (asserted in
    tests/test_decode_serving.py), so one greedy decode step IS
    ``score_candidates`` over the vocab.

    ``row_index`` [B, M] is the DSO v2 packed-decode steer: ``k_hist`` /
    ``v_hist`` are then [U,S,Hkv,D] stacked beam caches with ``lengths``
    [U], and every candidate gathers its own beam's cache + valid length
    (same placement-invariance argument as
    :func:`_segment_packed_attention`).  ``impl="pallas"`` routes the
    flash-decode kernel (self K/V written into the cache row, ``lengths +
    1``); ``impl="fused"`` routes the FKE v2 lengths-masked two-segment
    kernel (``fused_score.ops.fused_decode_attention``), which consumes
    the STORED int8/bf16 cache plus scales directly — dequant folded into
    the score/accumulator multiplies, no gather/concat materialization;
    every other impl runs the reference-structured jnp formulation below
    (exact at serving scale: the chunked scoring path routes to reference
    for decode-sized shapes)."""
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    if impl == "fused":
        from repro.kernels.fused_score import ops as fs_ops
        return fs_ops.fused_decode_attention(
            q, k_hist, v_hist, k_cand, v_cand, lengths, k_scale=k_scale,
            v_scale=v_scale, row_index=row_index)
    if k_scale is not None or v_scale is not None \
            or k_hist.dtype != q.dtype:
        k_hist, v_hist = _dequant_gather(k_hist, v_hist, k_scale, v_scale,
                                         None, q.dtype)
    b, m, h, d = q.shape
    s = k_hist.shape[1]
    hkv = k_cand.shape[2]
    g = h // hkv
    lengths = jnp.asarray(lengths, jnp.int32)
    if row_index is not None:
        if jnp.ndim(row_index) != 2:
            raise ValueError("decode attention row_index must be [B, M] "
                             "(per-candidate beam steer) when given")
        seg = jnp.asarray(row_index, jnp.int32)
        kh = jnp.take(k_hist, seg, axis=0)         # [B, M, S, Hkv, D]
        vh = jnp.take(v_hist, seg, axis=0)
        lens = jnp.take(lengths, seg)              # [B, M]
        if impl == "pallas":
            return _kernel_decode_attention(q, kh, vh, k_cand, v_cand, lens)
        qf = q.astype(jnp.float32).reshape(b, m, hkv, g, d)
        s_hist = jnp.einsum("bmhgd,bmshd->bhgms", qf,
                            kh.astype(jnp.float32)) / np.sqrt(d)
        s_cand = jnp.einsum("bmhgd,bkhd->bhgmk", qf,
                            k_cand.astype(jnp.float32)) / np.sqrt(d)
        scores = jnp.concatenate([s_hist, s_cand], axis=-1)
        base = A.make_mask(m, s + m, "sumi", n_history=s, q_offset=s)
        hist_ok = jnp.arange(s)[None, None, :] < lens[:, :, None]  # [B,M,S]
        ok = jnp.concatenate(
            [hist_ok, jnp.ones((b, m, m), bool)], axis=-1)
        mask = base[None, None, None] & ok[:, None, None]
        scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        vc = jnp.broadcast_to(v_cand.astype(jnp.float32)[:, None],
                              (b, m, m, hkv, d))
        v_all = jnp.concatenate([vh.astype(jnp.float32), vc], axis=2)
        o = jnp.einsum("bhgmk,bmkhd->bmhgd", w, v_all)
        return o.reshape(b, m, h, d).astype(q.dtype)
    if impl == "pallas":
        kh = jnp.broadcast_to(k_hist[:, None], (b, m) + k_hist.shape[1:])
        vh = jnp.broadcast_to(v_hist[:, None], (b, m) + v_hist.shape[1:])
        lens = jnp.broadcast_to(lengths[:, None], (b, m))
        return _kernel_decode_attention(q, kh, vh, k_cand, v_cand, lens)
    # per-row cache: mirror cached_candidate_attention's reference route
    # (concat + reference_attention ops) with the valid-length mask folded
    # into the SUMI mask — at lengths == S the fold is the identity, so
    # this is bitwise the score_candidates attention
    k = jnp.concatenate([k_hist, k_cand], axis=1)
    v = jnp.concatenate([v_hist, v_cand], axis=1)
    qf = q.astype(jnp.float32).reshape(b, m, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                        k.astype(jnp.float32)) / np.sqrt(d)
    base = A.make_mask(m, s + m, "sumi", n_history=s, q_offset=s)
    ok = jnp.concatenate(
        [jnp.arange(s)[None, :] < lengths[:, None],
         jnp.ones((b, m), bool)], axis=-1)                      # [B, S+M]
    mask = base[None, None, None] & ok[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(b, m, h, d).astype(q.dtype)


def extend_attention(q, k_prefix, v_prefix, k_suffix, v_suffix, *,
                     impl: str = "reference", temperature=None,
                     k_scale=None, v_scale=None, row_index=None):
    """Causal suffix attention against cached prefix K/V (incremental
    history extension, the MTServe "extend a cached prefix" step).

    ``q``/``k_suffix``/``v_suffix`` are [B,S_suf,...] projections of the
    history positions being (re-)encoded; ``k_prefix``/``v_prefix``
    [B,P,...] come from a cached ``encode_history`` pass whose first ``P``
    positions are trusted unchanged.  Query row i sits at absolute position
    ``P + i`` and attends causally over the concatenated KV axis — exactly
    the rows a full re-encode would attend to, so the output is bit-for-bit
    the suffix slice of a full history encode under the reference impl
    (chunked routes there at serving scales).  The FKE operand extensions
    (``k_scale``/``v_scale``/``row_index``) follow
    :func:`cached_candidate_attention`; a zero-length prefix degenerates
    to plain causal attention and routes to the framework impls.  Suffix
    positions are causally ordered, so segment packing does not apply —
    a per-candidate (2-D) ``row_index`` is rejected."""
    if row_index is not None and jnp.ndim(row_index) == 2:
        raise ValueError("extend attention is causal within the suffix — "
                         "segment-packed (per-candidate) row_index only "
                         "applies to cached candidate scoring")
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    if impl == "fused" and k_prefix.shape[1] > 0:
        from repro.kernels.fused_score import ops as fs_ops
        return fs_ops.fused_extend_attention(
            q, k_prefix, v_prefix, k_suffix, v_suffix, k_scale=k_scale,
            v_scale=v_scale, row_index=row_index)
    if k_scale is not None or v_scale is not None or row_index is not None \
            or k_prefix.dtype != q.dtype:
        k_prefix, v_prefix = _dequant_gather(k_prefix, v_prefix, k_scale,
                                             v_scale, row_index, q.dtype)
    if impl == "fused":
        impl = "chunked"                     # empty prefix: plain causal
    p0 = k_prefix.shape[1]
    k = jnp.concatenate([k_prefix, k_suffix], axis=1)
    v = jnp.concatenate([v_prefix, v_suffix], axis=1)
    return A.attention(q, k, v, "causal", impl=impl, q_offset=p0)


def sumi_mask(n_history: int, n_candidates: int) -> jnp.ndarray:
    """Dense boolean mask (for tests / the unfused baseline)."""
    s = n_history + n_candidates
    return A.make_mask(s, s, "sumi", n_history=n_history)


def flops_per_request(n_history: int, n_candidates: int, n_blocks: int,
                      layers_per_block: int, d_model: int, d_ff: int) -> float:
    """Analytic FLOPs of one SUMI forward (paper Table 2 reproduction)."""
    s_block = n_history // n_blocks + n_candidates
    per_tok_proj = 2 * (4 * d_model * d_model + 2 * d_model * d_ff)
    # attention scores+values; SUMI mask: candidates only see history+self
    n_hist_b = n_history // n_blocks
    attn_pairs = n_hist_b * (n_hist_b + 1) / 2 + n_candidates * (n_hist_b + 1)
    per_layer = s_block * per_tok_proj + 2 * 2 * attn_pairs * d_model
    return n_blocks * layers_per_block * per_layer


def cached_flops_per_request(n_history: int, n_candidates: int, n_blocks: int,
                             layers_per_block: int, d_model: int,
                             d_ff: int) -> float:
    """Analytic FLOPs of a candidate-only pass against cached history K/V:
    projections/FFN run over M tokens instead of n_history + M, and the
    attention pairs lose the causal history-history triangle."""
    per_tok_proj = 2 * (4 * d_model * d_model + 2 * d_model * d_ff)
    n_hist_b = n_history // n_blocks
    attn_pairs = n_candidates * (n_hist_b + 1)
    per_layer = n_candidates * per_tok_proj + 2 * 2 * attn_pairs * d_model
    return n_blocks * layers_per_block * per_layer
