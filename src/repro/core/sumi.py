"""SUMI — single user, multiple items: FLAME's request paradigm.

A GR ranking request carries one user history (length n) and M candidate
items.  All M candidates are scored in ONE forward pass by concatenating them
after the history and applying the SUMI mask (candidates attend to history
and themselves, never to each other) — the HSTU-style parallel-prediction
trick the paper bakes into its mask-aware flash-attention plug-in.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A


def assemble(history_emb: jnp.ndarray, cand_emb: jnp.ndarray
             ) -> Tuple[jnp.ndarray, int]:
    """[B,n,d] + [B,M,d] -> ([B,n+M,d], n_history)."""
    return jnp.concatenate([history_emb, cand_emb], axis=1), history_emb.shape[1]


def split_candidates(x: jnp.ndarray, n_history: int) -> jnp.ndarray:
    """[B,n+M,d] -> candidate outputs [B,M,d]."""
    return x[:, n_history:]


def sumi_attention(q, k, v, n_history: int, *, impl: str = "reference",
                   temperature=None):
    """Mask-aware attention under the SUMI mask.  q/k/v [B,S,H,D]."""
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    return A.attention(q, k, v, "sumi", impl=impl, n_history=n_history)


def sumi_mask(n_history: int, n_candidates: int) -> jnp.ndarray:
    """Dense boolean mask (for tests / the unfused baseline)."""
    s = n_history + n_candidates
    return A.make_mask(s, s, "sumi", n_history=n_history)


def flops_per_request(n_history: int, n_candidates: int, n_blocks: int,
                      layers_per_block: int, d_model: int, d_ff: int) -> float:
    """Analytic FLOPs of one SUMI forward (paper Table 2 reproduction)."""
    s_block = n_history // n_blocks + n_candidates
    per_tok_proj = 2 * (4 * d_model * d_model + 2 * d_model * d_ff)
    # attention scores+values; SUMI mask: candidates only see history+self
    n_hist_b = n_history // n_blocks
    attn_pairs = n_hist_b * (n_hist_b + 1) / 2 + n_candidates * (n_hist_b + 1)
    per_layer = s_block * per_tok_proj + 2 * 2 * attn_pairs * d_model
    return n_blocks * layers_per_block * per_layer
