"""FLAME's core contribution, reimplemented for JAX/TPU.

  sumi.py     single-user-multi-item sequence assembly + candidate scoring
  climber.py  the Climber GR model (the paper's serving workload)
  pda.py      Proximal Data Accelerator — feature cache + packed transfer
  dso.py      Dynamic Stream Orchestrator — bucket routing over AOT executors
"""
