"""Proximal Data Accelerator (PDA) — feature pipeline memory optimizations.

Faithful host-side reimplementation of the paper's §3.1:

  * item-side feature cache: bucketed LRU with TTL, lock striping to reduce
    write-lock collisions (the paper's multi-bucket design);
  * asynchronous query mode: cache hit -> return; expired hit -> return the
    stale value immediately and refresh in the background; miss -> return
    empty and refresh in the background (never blocks on the network);
  * synchronous query mode: miss/expired -> blocking fetch (accuracy first);
  * packed transfer: all per-request feature arrays are packed into ONE
    contiguous host buffer moved with a single device_put (the pinned-memory
    "batch many small transfers into one" insight — page-locking itself is a
    CUDA mechanism with no JAX-visible TPU analogue, see DESIGN.md);
  * NUMA core binding is an OS-level deployment concern (numactl); the code
    keeps the *contention* insight via lock striping and exposes worker
    sharding hooks.

Metrics mirror the paper's Table 3 columns: throughput, latency, network
bytes.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


# ---------------------------------------------------------------------------
# simulated remote feature store (the "network" side of Table 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RemoteFeatureStore:
    """Deterministic synthetic feature server with simulated network cost."""

    feature_dim: int = 64
    latency_s: float = 0.0008          # per-RPC latency
    per_item_s: float = 0.00001        # serialization cost per item
    seed: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()
        self.bytes_sent = 0
        self.requests = 0

    def query(self, item_ids: Sequence[int]) -> Dict[int, np.ndarray]:
        if self.latency_s:
            time.sleep(self.latency_s + self.per_item_s * len(item_ids))
        out = {}
        for i in item_ids:
            rng = np.random.default_rng((self.seed * 1_000_003 + i) & 0x7FFFFFFF)
            out[i] = rng.standard_normal(self.feature_dim, dtype=np.float32)
        with self._lock:
            self.bytes_sent += len(item_ids) * self.feature_dim * 4
            self.requests += 1
        return out


# ---------------------------------------------------------------------------
# bucketed LRU-TTL cache
# ---------------------------------------------------------------------------

class _Bucket:
    __slots__ = ("lock", "data")

    def __init__(self):
        self.lock = threading.Lock()
        self.data: "collections.OrderedDict[int, Tuple[float, np.ndarray]]" = \
            collections.OrderedDict()


class BucketedLRUCache:
    """LRU with TTL, striped into ``n_buckets`` independently-locked shards."""

    def __init__(self, capacity: int = 100_000, ttl_s: float = 30.0,
                 n_buckets: int = 16):
        assert n_buckets > 0 and capacity >= n_buckets
        self.capacity_per_bucket = max(1, capacity // n_buckets)
        self.ttl_s = ttl_s
        self.buckets = [_Bucket() for _ in range(n_buckets)]

    def _bucket(self, key: int) -> _Bucket:
        return self.buckets[hash(key) % len(self.buckets)]

    def get(self, key: int, now: Optional[float] = None):
        """Returns (value | None, fresh: bool)."""
        now = time.monotonic() if now is None else now
        b = self._bucket(key)
        with b.lock:
            hit = b.data.get(key)
            if hit is None:
                return None, False
            ts, val = hit
            b.data.move_to_end(key)
            return val, (now - ts) <= self.ttl_s

    def put(self, key: int, value, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        b = self._bucket(key)
        with b.lock:
            b.data[key] = (now, value)
            b.data.move_to_end(key)
            while len(b.data) > self.capacity_per_bucket:
                b.data.popitem(last=False)

    def __len__(self):
        return sum(len(b.data) for b in self.buckets)


# ---------------------------------------------------------------------------
# feature query engine (async / sync / uncached)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryStats:
    hits: int = 0
    stale_hits: int = 0
    misses: int = 0
    sync_fetches: int = 0
    async_refreshes: int = 0
    prefetches: int = 0


class FeatureQueryEngine:
    """The PDA feature query front-end.

    mode: "off"   — always hit the remote store (the −Cache baseline)
          "sync"  — cache, blocking fetch on miss/expiry (accuracy first)
          "async" — cache, stale-or-empty returned instantly, background
                    refresh (throughput first; may serve missing features)
    """

    def __init__(self, store: RemoteFeatureStore, cache: Optional[BucketedLRUCache],
                 mode: str = "sync", max_workers: int = 8):
        assert mode in ("off", "sync", "async")
        self.store = store
        self.cache = cache
        self.mode = mode
        self.stats = QueryStats()
        self._max_workers = max_workers
        self._pool = ThreadPoolExecutor(max_workers=max_workers) \
            if mode == "async" else None
        self._pool_lock = threading.Lock()
        self._closed = False
        self._stats_lock = threading.Lock()
        self._inflight: set = set()
        self._inflight_lock = threading.Lock()
        # signalled whenever a background refresh retires its ids, so sync
        # queries can wait for an in-flight prefetch instead of re-fetching
        self._inflight_cv = threading.Condition(self._inflight_lock)

    def _ensure_pool(self) -> Optional[ThreadPoolExecutor]:
        """Lazily create the background pool (sync engines only need one
        once ``prefetch`` is used).  Returns None once shut down so a
        racing prefetch cannot resurrect a pool."""
        with self._pool_lock:
            if self._closed:
                return None
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._max_workers)
            return self._pool

    def _refresh_async(self, item_ids: List[int]):
        with self._inflight_lock:
            todo = [i for i in item_ids if i not in self._inflight]
            self._inflight.update(todo)
        if not todo:
            return

        def work():
            try:
                res = self.store.query(todo)
                for k, v in res.items():
                    self.cache.put(k, v)
            finally:
                with self._inflight_cv:
                    self._inflight.difference_update(todo)
                    self._inflight_cv.notify_all()

        pool = self._ensure_pool()
        if pool is None:                 # engine shut down — undo reservation
            with self._inflight_cv:
                self._inflight.difference_update(todo)
                self._inflight_cv.notify_all()
            return
        with self._stats_lock:
            self.stats.async_refreshes += 1
        pool.submit(work)

    def prefetch(self, item_ids: Sequence[int]):
        """Serving-pipeline hook (API v2 stage 2): warm the cache for
        ``item_ids`` in the background without blocking the caller, so the
        later synchronous ``query`` on the worker thread hits cache.  No-op
        when caching is disabled; in-flight de-dup via ``_refresh_async``."""
        if self.mode == "off" or self.cache is None:
            return
        need = [i for i in item_ids if not self.cache.get(i)[1]]
        if not need:
            return
        with self._stats_lock:
            self.stats.prefetches += 1
        self._refresh_async(need)

    def query(self, item_ids: Sequence[int]) -> Dict[int, Optional[np.ndarray]]:
        if self.mode == "off" or self.cache is None:
            res = self.store.query(list(item_ids))
            with self._stats_lock:
                self.stats.misses += len(item_ids)
            return dict(res)

        out: Dict[int, Optional[np.ndarray]] = {}
        need: List[int] = []
        hits = stale = misses = 0
        for i in item_ids:
            val, fresh = self.cache.get(i)
            if val is not None and fresh:
                hits += 1
                out[i] = val
            elif val is not None:           # expired
                stale += 1
                out[i] = val                # async: serve stale
                need.append(i)
            else:
                misses += 1
                out[i] = None
                need.append(i)
        with self._stats_lock:
            self.stats.hits += hits
            self.stats.stale_hits += stale
            self.stats.misses += misses

        if need:
            if self.mode == "sync":
                self._sync_fill(need, out)
            else:
                self._refresh_async(need)
        return out

    def _sync_fill(self, need: List[int], out: Dict[int, Optional[np.ndarray]]):
        """Blocking fill for sync mode.  Ids already being fetched by a
        background prefetch are awaited (instead of re-fetched, which would
        double the network cost of the exact cold path prefetch exists
        for); everything else is fetched in one blocking RPC."""
        with self._inflight_lock:
            awaited = [i for i in need if i in self._inflight]
        fetch = [i for i in need if i not in set(awaited)]
        if fetch:
            with self._stats_lock:
                self.stats.sync_fetches += 1
            res = self.store.query(fetch)
            for k, v in res.items():
                self.cache.put(k, v)
                out[k] = v
        if awaited:
            deadline = time.monotonic() + 5.0
            with self._inflight_cv:
                while any(i in self._inflight for i in awaited) \
                        and time.monotonic() < deadline:
                    self._inflight_cv.wait(timeout=0.05)
            missing = []
            for i in awaited:
                val, fresh = self.cache.get(i)
                if val is not None and fresh:
                    out[i] = val
                else:   # prefetch failed, timed out, or landed expired —
                    missing.append(i)   # sync mode never serves stale
            if missing:
                with self._stats_lock:
                    self.stats.sync_fetches += 1
                res = self.store.query(missing)
                for k, v in res.items():
                    self.cache.put(k, v)
                    out[k] = v

    def shutdown(self):
        with self._pool_lock:
            self._closed = True
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)


# ---------------------------------------------------------------------------
# packed transfer (pinned-memory analogue)
# ---------------------------------------------------------------------------

def pack_features(arrays: Sequence[np.ndarray]) -> Tuple[np.ndarray, List[Tuple[int, Tuple[int, ...]]]]:
    """Concatenate many small f32 arrays into one contiguous buffer.

    Returns (buffer, layout) where layout = [(offset, shape), ...]."""
    layout = []
    total = 0
    for a in arrays:
        layout.append((total, a.shape))
        total += int(np.prod(a.shape))
    buf = np.empty((total,), np.float32)
    for (off, shape), a in zip(layout, arrays):
        n = int(np.prod(shape))
        buf[off:off + n] = np.asarray(a, np.float32).ravel()
    return buf, layout


import functools


@functools.lru_cache(maxsize=256)
def _unpacker(layout_key):
    """One jitted call slicing the packed buffer into all feature arrays
    (a single dispatch instead of len(layout) eager ops)."""
    def unpack(buf):
        out = []
        for off, shape in layout_key:
            n = int(np.prod(shape))
            out.append(jax.lax.dynamic_slice_in_dim(buf, off, n).reshape(shape))
        return out
    return jax.jit(unpack)


def unpack_on_device(dev_buf, layout):
    """Static slices on-device (cheap; no host round trip)."""
    key = tuple((off, tuple(shape)) for off, shape in layout)
    return _unpacker(key)(dev_buf)


def packed_transfer(arrays: Sequence[np.ndarray], device=None):
    """ONE device_put for the whole request instead of len(arrays) transfers."""
    buf, layout = pack_features(arrays)
    dev_buf = jax.device_put(buf, device)
    return unpack_on_device(dev_buf, layout)


def unpacked_transfer(arrays: Sequence[np.ndarray], device=None):
    """Baseline: one device_put per array (the pageable/many-small-copies path)."""
    return [jax.device_put(np.asarray(a, np.float32), device) for a in arrays]
