"""Public jit'd wrapper for the flash-decode kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_decode.kernel import flash_decode_kernel


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, window: int = 0,
                 bk: int = 256, interpret: bool | None = None):
    """q [B,H,D] (one new token per sequence); caches [B,S,Hkv,D];
    lengths [B].  Returns [B,H,D]."""
    if interpret is None:
        interpret = default_interpret()
    b, h, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    bk = min(bk, max(8, 1 << (s - 1).bit_length()))
    pad_s = (-s) % bk
    pad_d = (-d) % 128
    if pad_s or pad_d:
        widths = ((0, 0), (0, pad_s), (0, 0), (0, pad_d))
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    # fold the softmax scale here: the kernel must not divide by the PADDED d
    qq = (q * (1.0 / (d ** 0.5))).astype(q.dtype).reshape(b, hkv, g, d)
    if pad_d:
        qq = jnp.pad(qq, ((0, 0), (0, 0), (0, 0), (0, pad_d)))
    out = flash_decode_kernel(qq, k_cache, v_cache,
                              lengths.reshape(b, 1).astype(jnp.int32),
                              window=window, bk=bk, interpret=interpret)
    return out[..., :d].reshape(b, h, d)
