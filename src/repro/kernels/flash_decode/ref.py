"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def reference(q, k_cache, v_cache, lengths, *, window: int = 0):
    """q [B,H,D]; caches [B,S,Hkv,D]; lengths [B] (valid prefix per seq).

    Returns [B,H,D].  ``window``>0 additionally masks positions older than
    ``lengths-window`` (sliding-window decode on a non-ring cache)."""
    b, h, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qf,
                        k_cache.astype(jnp.float32)) / np.sqrt(d)
    pos = jnp.arange(s)[None, :]
    valid = pos < lengths[:, None]
    if window:
        valid = valid & (pos >= lengths[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
