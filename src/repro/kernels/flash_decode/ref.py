"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_with_self(q, k_cache, v_cache, lengths, k_self, v_self):
    """fp32 ground truth for one generative-decode scoring step.

    ``q``/``k_self``/``v_self`` [B,M,H(kv),D] are M candidate next-token
    projections per row, each hypothetically extending the row's cache at
    position ``lengths[b]``; ``k_cache``/``v_cache`` [B,S,Hkv,D] hold the
    row's valid prefix in positions ``< lengths[b]`` (padding beyond is
    ignored).  Every candidate attends to the valid prefix plus itself and
    never to the other candidates — the SUMI mask specialized to a
    per-row valid length.  This is what ``sumi.decode_candidate_attention``
    must compute; the kernel route realizes it by writing each candidate's
    own K/V into its cache row and calling :func:`reference` /
    ``flash_decode`` with ``lengths + 1``.  The FKE v2 fused decode route
    (``kernels/fused_score``) computes the same function directly against
    the pool's STORED int8/bf16 operand — its oracle,
    ``fused_score.ref.decode_reference``, is this computation generalized
    with in-front dequantization and 1-D/2-D ``row_index`` gathers, and
    collapses to this function bitwise on plain operands."""
    b, m, h, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, m, hkv, g, d)
    s_hist = jnp.einsum("bmhgd,bkhd->bhgmk", qf,
                        k_cache.astype(jnp.float32)) / np.sqrt(d)
    s_self = jnp.einsum("bmhgd,bmhd->bhgm", qf,
                        k_self.astype(jnp.float32))[..., None] / np.sqrt(d)
    valid = (jnp.arange(s)[None, :] < lengths[:, None])[:, None, None, None]
    scores = jnp.concatenate(
        [jnp.where(valid, s_hist, -1e30), s_self], axis=-1)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgmk,bkhd->bmhgd", w[..., :s],
                   v_cache.astype(jnp.float32)) \
        + w[..., s:].transpose(0, 3, 1, 2, 4) \
        * v_self.astype(jnp.float32).reshape(b, m, hkv, 1, d)
    return o.reshape(b, m, h, d).astype(q.dtype)


def reference(q, k_cache, v_cache, lengths, *, window: int = 0):
    """q [B,H,D]; caches [B,S,Hkv,D]; lengths [B] (valid prefix per seq).

    Returns [B,H,D].  ``window``>0 additionally masks positions older than
    ``lengths-window`` (sliding-window decode on a non-ring cache)."""
    b, h, d = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qf,
                        k_cache.astype(jnp.float32)) / np.sqrt(d)
    pos = jnp.arange(s)[None, :]
    valid = pos < lengths[:, None]
    if window:
        valid = valid & (pos >= lengths[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
