"""Flash-decode — Pallas TPU kernel for single-token attention over a long
KV cache (the serving-side FKE hot spot).

Decode attention is memory-bound: the whole job is streaming the valid
cache prefix HBM->VMEM once.  The kernel tiles the cache sequence axis;
blocks entirely past ``length`` (or before ``length-window``) are skipped
via pl.when, so HBM traffic scales with the *valid* prefix, not the cache
allocation.  All G q-heads of one KV head are processed together, giving
the MXU a [G, D] x [D, bk] matmul per block.

Grid = (B * Hkv, S/bk) with online-softmax scratch carried across the
sequential cache axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bk: int, nk: int, window: int, scale: float):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    lo = (length - window) if window else 0
    # block [kj*bk, kj*bk+bk) intersects the valid range [lo, length)?
    guard = (kj * bk < length) & (kj * bk + bk > lo)

    @pl.when(guard)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)                # [bk, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, bk]
        pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        ok = pos < length
        if window:
            ok = ok & (pos >= length - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)) \
            .astype(o_ref.dtype)


def flash_decode_kernel(q, k_cache, v_cache, lengths, *, window: int = 0,
                        bk: int = 256, interpret: bool = True):
    """q [B,Hkv,G,D]; caches [B,S,Hkv,D]; lengths [B,1] i32.

    Returns [B,Hkv,G,D].  S must be a multiple of bk (ops.py pads)."""
    b, hkv, g, d = q.shape
    s = k_cache.shape[1]
    nk = s // bk
    # softmax scale is folded into q by ops.py (d here may be lane-padded)
    kernel = functools.partial(_fd_kernel, bk=bk, nk=nk, window=window,
                               scale=1.0)

    def q_map(bh, kj):
        return (bh // hkv, bh % hkv, 0, 0)

    def kv_map(bh, kj):
        return (bh // hkv, kj, bh % hkv, 0)

    return pl.pallas_call(
        kernel,
        grid=(b * hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bh, kj: (bh // hkv, 0)),   # lengths
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, bk, 1, d), kv_map),
            pl.BlockSpec((1, bk, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
