"""Pure-jnp oracle for the mask-aware flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mask_array(s_q: int, s_k: int, mode: str, *, window: int = 0,
               n_history: int = 0) -> jnp.ndarray:
    q = jnp.arange(s_q)[:, None]
    k = jnp.arange(s_k)[None, :]
    if mode == "full":
        return jnp.ones((s_q, s_k), bool)
    if mode == "causal":
        return k <= q
    if mode == "sliding":
        return (k <= q) & (q - k < window)
    if mode == "sumi":
        q_is_hist = q < n_history
        hist = k <= q
        cand = (k < n_history) | (k == q)
        return jnp.where(q_is_hist, hist, cand)
    raise ValueError(mode)


def reference(q, k, v, mode: str, *, window: int = 0, n_history: int = 0):
    """q [B,H,Sq,D]; k,v [B,Hkv,Sk,D] -> [B,H,Sq,D] (f32 math, input dtype out)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) / np.sqrt(d)
    m = mask_array(sq, k.shape[2], mode, window=window, n_history=n_history)
    s = jnp.where(m[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can happen with window=0 edge cases) -> zeros
    w = jnp.where(m.any(-1)[None, None, None, :, None], w, 0.0)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)
