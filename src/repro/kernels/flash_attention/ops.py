"""Public jit'd wrapper for the mask-aware flash attention kernel.

Handles layout ([B,S,H,D] model layout -> [B,H,S,D] kernel layout), padding
of S to block multiples and D to the 128-lane width, softmax scaling, and the
interpret-mode fallback on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("mode", "window", "n_history",
                                             "bq", "bk", "interpret",
                                             "q_offset"))
def flash_attention_bhsd(q, k, v, mode: str = "causal", *, window: int = 0,
                         n_history: int = 0, bq: int = 128, bk: int = 128,
                         interpret: bool | None = None, q_offset: int = 0):
    """q [B,H,Sq,D]; k,v [B,Hkv,Sk,D] -> [B,H,Sq,D]."""
    if interpret is None:
        interpret = default_interpret()
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(bq, max(8, 1 << (sq - 1).bit_length()))
    bk = min(bk, max(8, 1 << (sk - 1).bit_length()))
    bq = bk = min(bq, bk)  # kernel index math assumes square blocks
    scale = 1.0 / np.sqrt(d)
    qp = _pad_to(_pad_to(q * scale, 2, bq), 3, 128)
    kp = _pad_to(_pad_to(k, 2, bk), 3, 128)
    vp = _pad_to(_pad_to(v, 2, bk), 3, 128)
    out = flash_attention_kernel(qp.astype(q.dtype), kp, vp, mode=mode,
                                 window=window, n_history=n_history,
                                 sq=sq, sk=sk, bq=bq, bk=bk,
                                 interpret=interpret, q_offset=q_offset)
    return out[:, :, :sq, :d]


def flash_attention(q, k, v, mode: str = "causal", *, window: int = 0,
                    n_history: int = 0, interpret: bool | None = None,
                    q_offset: int = 0):
    """Model-layout entry point: q [B,Sq,H,D]; k,v [B,Sk,Hkv,D]."""
    o = flash_attention_bhsd(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                             jnp.swapaxes(v, 1, 2), mode, window=window,
                             n_history=n_history, interpret=interpret,
                             q_offset=q_offset)
    return jnp.swapaxes(o, 1, 2)
