"""Mask-aware flash attention — Pallas TPU kernel (the FKE attention plug-in).

Online-softmax flash attention with GQA and four mask modes.  The mask
structure is *static*, so whole KV blocks outside the mask are never visited:

  full     grid kv steps = nk (all blocks)
  causal   grid kv steps = nk, blocks with kj > qi skipped via pl.when
           (no FLOPs; the DMA for a skipped block is hidden by the pipeline)
  sliding  grid kv steps = ceil((window+bq)/bk)+1 — the index_map slides the
           KV window with the q block: compute AND bandwidth scale with
           S*window instead of S^2 (true block skipping)
  sumi     grid kv steps = ceil(n_history/bk)+1 — candidates only ever see
           history blocks plus their own diagonal block, the TPU analogue of
           the paper's HSTU-style mask-aware kernel: per-candidate compute is
           O(n_history + bq), independent of the number of candidates

Accumulators (m, l, acc) live in VMEM scratch and persist across the
sequential innermost grid axis; the MXU sees [bq, D] x [D, bk] matmuls with
D padded to a multiple of 128 (lane width) by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kv_steps(mode: str, nk: int, bq: int, bk: int, window: int,
              n_history: int, q_offset: int = 0) -> int:
    if mode == "sliding":
        return min(nk, (window + bq + bk - 1) // bk + 1)
    if mode == "sumi":
        nhb = min(nk, (n_history + bk - 1) // bk)
        # q_offset > 0 (cached-history path): every query is a candidate, so
        # all history blocks are visited plus the block(s) holding its own
        # key — the offset need not be bk-aligned, so the bq-wide self range
        # can straddle two KV blocks
        return nhb + (2 if q_offset else 1)
    return nk


def _k_index(mode: str, qi, kj, *, nk: int, bq: int, bk: int, window: int,
             n_history: int, steps: int, q_offset: int = 0):
    """Map (q block, kv step) -> kv block index (may be clamped; guard masks
    duplicates)."""
    diag = (q_offset + qi * bq + bq - 1) // bk  # block holding the diagonal
    if mode == "sliding":
        raw = diag + kj - (steps - 1)          # last step = diagonal block
        return jnp.clip(raw, 0, nk - 1)
    if mode == "sumi":
        if q_offset:
            nhb = steps - 2
            d0 = (q_offset + qi * bq) // bk    # first block of the self range
            return jnp.where(kj < nhb, jnp.minimum(kj, nk - 1),
                             jnp.clip(jnp.where(kj == nhb, d0, diag),
                                      0, nk - 1))
        nhb = steps - 1
        return jnp.where(kj < nhb, jnp.minimum(kj, nk - 1),
                         jnp.minimum(diag, nk - 1))
    return kj


def _guard(mode: str, qi, kj, *, nk: int, bq: int, bk: int, window: int,
           n_history: int, steps: int, q_offset: int = 0):
    """True when this (q block, kv step) must be computed (fresh + visible)."""
    if mode == "full":
        return jnp.bool_(True)
    diag = (q_offset + qi * bq + bq - 1) // bk
    if mode == "causal":
        return kj <= diag
    if mode == "sliding":
        raw = diag + kj - (steps - 1)
        return (raw >= 0) & (raw <= diag)
    if mode == "sumi":
        if q_offset:
            # cached-history path: all queries are candidates.  History
            # blocks [0, nhb) are always visited; the two trailing steps
            # cover the (possibly straddling) self range, skipping blocks
            # the history sweep already produced and pure-padding blocks.
            nhb = steps - 2
            d0 = (q_offset + qi * bq) // bk
            d1 = diag
            self0 = (kj == nhb) & (d0 >= nhb) & (d0 < nk)
            self1 = (kj == nhb + 1) & (d1 >= nhb) & (d1 < nk) & (d1 > d0)
            return (kj < nhb) | self0 | self1
        nhb = steps - 1
        hist_step = (kj < nhb) & (kj <= diag)
        # diagonal step only needed when this q block extends past the
        # history blocks already visited
        diag_step = (kj == nhb) & (diag >= nhb)
        return hist_step | diag_step
    raise ValueError(mode)


def _element_mask(mode: str, rows, cols, *, window: int, n_history: int,
                  sq: int, sk: int, q_offset: int = 0):
    ok = (rows < sq) & (cols < sk)          # trim padding (rows are local)
    if mode == "full":
        return ok
    if mode == "causal":
        # q_offset > 0 (incremental history extension): suffix query row i
        # sits at absolute KV position q_offset + i
        return ok & (cols <= rows + q_offset)
    if mode == "sliding":
        return ok & (cols <= rows) & (rows - cols < window)
    if mode == "sumi":
        abs_rows = rows + q_offset          # absolute position in the KV axis
        hist = cols <= abs_rows
        cand = (cols < n_history) | (cols == abs_rows)
        return ok & jnp.where(abs_rows < n_history, hist, cand)
    raise ValueError(mode)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               mode: str, bq: int, bk: int, window: int, n_history: int,
               sq: int, sk: int, nk: int, steps: int, scale: float,
               q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    guard = _guard(mode, qi, kj, nk=nk, bq=bq, bk=bk, window=window,
                   n_history=n_history, steps=steps, q_offset=q_offset)

    @pl.when(guard)
    def _compute():
        kidx = _k_index(mode, qi, kj, nk=nk, bq=bq, bk=bk, window=window,
                        n_history=n_history, steps=steps, q_offset=q_offset)
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kidx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        msk = _element_mask(mode, rows, cols, window=window,
                            n_history=n_history, sq=sq, sk=sk,
                            q_offset=q_offset)
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]                                  # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                       # [bq, 1]
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(kj == steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, mode: str, window: int = 0,
                           n_history: int = 0, sq: int, sk: int,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True, q_offset: int = 0):
    """q [B,H,Sqp,D], k/v [B,Hkv,Skp,D] (pre-padded to block/lane multiples).

    ``sq``/``sk`` are the *unpadded* lengths (padding is masked out).
    ``q_offset`` shifts query positions against KV positions (sumi only):
    the cached-history path runs M candidate queries against n_history
    cached K/V rows followed by the candidates' own K/V, so query row i
    sits at absolute position ``q_offset + i``.
    Softmax scale must be folded by the caller via ``scale``-preserving
    convention: this kernel applies 1/sqrt(D_real) via the ``scale`` closure
    in ops.py — here q is scaled already, so scale=1.
    """
    if q_offset and mode not in ("sumi", "causal"):
        # block selection honors the offset for every mode, but the
        # sliding element mask still uses local row positions — fail
        # loudly rather than return silently-masked zeros
        raise NotImplementedError(
            f"q_offset is only supported for mode in ('sumi', 'causal'), "
            f"got {mode!r}")
    if q_offset and bq > bk:
        # the offset self range of a q block spans <= 2 KV blocks only for
        # bq <= bk (ops.py always passes square blocks); wider q blocks
        # would silently drop candidates' own keys
        raise NotImplementedError(
            f"q_offset needs bq <= bk, got bq={bq} bk={bk}")
    b, h, sqp, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    skp = k.shape[2]
    nq = sqp // bq
    nk = skp // bk
    steps = _kv_steps(mode, nk, bq, bk, window, n_history, q_offset)

    kernel = functools.partial(
        _fa_kernel, mode=mode, bq=bq, bk=bk, window=window,
        n_history=n_history, sq=sq, sk=sk, nk=nk, steps=steps, scale=1.0,
        q_offset=q_offset)

    grid = (b * h, nq, steps)

    def q_map(bh, qi, kj):
        return (bh // h, bh % h, qi, 0)

    def kv_map(bh, qi, kj):
        kidx = _k_index(mode, qi, kj, nk=nk, bq=bq, bk=bk, window=window,
                        n_history=n_history, steps=steps, q_offset=q_offset)
        return (bh // h, (bh % h) // g, kidx, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),    # l (running denom)
            pltpu.VMEM((bq, d), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
