from repro.kernels.fused_score.ops import (block_epilogue,  # noqa: F401
                                           fused_cached_attention,
                                           fused_extend_attention)
