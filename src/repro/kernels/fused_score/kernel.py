"""Fused candidate-scoring attention — Pallas TPU kernel (FKE core).

One kernel computes the serving hot path that the framework previously
composed from four dispatches (host dequantize → ``kv[idx]`` row gather →
``concat(hist, cand)`` → masked attention):

  * **two-segment online softmax** — the query block streams over the
    pooled *history* KV blocks and then its own *candidate* (or suffix)
    KV block; the concatenation never materializes;
  * **in-kernel dequantization** — history K/V arrive in the pool's
    stored precision (int8 / bf16 / native) plus a per-(row, head) absmax
    scale; tiles are cast on the MXU input path and the scale is folded
    into the score / accumulator multiplies, so the dequantized history
    never touches HBM;
  * **index-folded dedup gather** — a scalar-prefetched per-q-block
    ``row_index [B, nq]`` drives the KV BlockSpec index map: q block
    ``qi`` of batch row ``b`` reads the blocks of pool row
    ``row_index[b, qi]`` directly, making the DSO's KV-row dedup free on
    every backend (no gathered copy, just redirected DMAs).  The per-q-
    block granularity is what DSO v2 segment packing rides on: one packed
    row carries candidate segments of several users, each q block steered
    to its own user's pooled history (segments aligned to ``bq`` on this
    path; ops.py samples the index at each block's first candidate).

Two masking modes share the machinery:

  ``cached``   SUMI candidate scoring: every query row sees the whole
               history plus exactly its own key (diagonal self block);
               ``steps = hist_steps + 1``.
  ``extend``   incremental history extension: suffix queries at absolute
               position ``prefix_len + i`` see the whole prefix plus the
               causal triangle of the suffix; ``steps = hist_steps + nq``
               with above-diagonal suffix blocks skipped via ``pl.when``.

A third serving workload — **generative decode** (FKE v2) — is cached
mode with a per-row ``lengths`` bound on the history segment: the pooled
operand is a PADDED, growing beam cache whose valid prefix per pool row
is ``lengths[row]``, so the history mask tightens from the static
``cols < s_hist`` to the prefetched ``cols < lens_ref[row]``.  Masked
positions contribute exact zeros to the online softmax (the ``where``
after ``exp`` is load-bearing for fully-masked blocks), so a padded
cache scores bitwise-identically to a tight one — cached/extend callers
pass ``lengths`` filled with ``s_hist``, making the bound a no-op.

The per-(row, head) scales and the per-row lengths ride in scalar
prefetch (SMEM) next to the ``row_index``; accumulators (m, l, acc) live
in VMEM scratch across the sequential innermost grid axis, exactly like
``kernels/flash_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fused_kernel(idx_ref, lens_ref, ks_ref, vs_ref, q_ref, kh_ref, vh_ref,
                  kc_ref, vc_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  mode: str, h: int, g: int, bq: int, bk: int, sq: int,
                  s_hist: int, hist_steps: int, steps: int):
    bh = pl.program_id(0)
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    row = idx_ref[bh // h, qi]               # pool row of this q block
    kvh = (bh % h) // g                      # kv head of this q head

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _online_update(s, msk, v, v_scale):
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]                                  # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        if v_scale is not None:
            pv = pv * v_scale
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(kj < hist_steps)
    def _history_step():
        # pooled-history block: dequantize the tile in registers; the
        # per-(row, head) scale is constant over (S, D), so it folds into
        # the score and accumulator multiplies
        q = q_ref[0, 0].astype(jnp.float32)                  # [bq, D]
        k = kh_ref[0, 0].astype(jnp.float32)                 # [bk, D]
        v = vh_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s * ks_ref[row, kvh]
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        # per-row valid-prefix bound (decode: growing padded beam caches);
        # cached/extend callers prefetch lens == s_hist, keeping this the
        # static history mask bitwise
        _online_update(s, (rows < sq) & (cols < lens_ref[row]), v,
                       vs_ref[row, kvh])

    if mode == "cached":
        self_guard = kj == hist_steps
    else:                                    # extend: causal suffix blocks
        self_guard = (kj >= hist_steps) & (kj - hist_steps <= qi)

    @pl.when(self_guard)
    def _self_step():
        # fresh candidate / suffix block, full precision, no scale
        cj = qi if mode == "cached" else kj - hist_steps
        q = q_ref[0, 0].astype(jnp.float32)
        k = kc_ref[0, 0].astype(jnp.float32)                 # [bq, D]
        v = vc_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 0)
        cols = cj * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 1)
        ok = (rows < sq) & (cols < sq)
        if mode == "cached":
            msk = ok & (rows == cols)        # self key only (SUMI)
        else:
            msk = ok & (cols <= rows)        # causal within the suffix
        _online_update(s, msk, v, None)

    @pl.when(kj == steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def fused_score_kernel(row_index, lengths, k_scale, v_scale, q, k_hist,
                       v_hist, k_cand, v_cand, *, mode: str, sq: int,
                       s_hist: int, bq: int = 128, bk: int = 128,
                       interpret: bool = True):
    """q [B,H,Mp,D] (pre-scaled); k_hist/v_hist [U,Hkv,Sp,D] stored dtype;
    lengths [U] int32 per-pool-row valid history prefix (<= s_hist; pass
    ``full(s_hist)`` for the static cached/extend masks);
    k_scale/v_scale [U,Hkv] f32 multipliers (1.0 for unquantized);
    k_cand/v_cand [B,Hkv,Mp,D]; row_index [B, Mp//bq] int32 per-q-block
    pool-row gather (constant per row for plain dedup; per-segment for
    DSO v2 packed rows).

    ``sq``/``s_hist`` are the unpadded query/history lengths; Mp/Sp/D are
    pre-padded to block and 128-lane multiples by ops.py (``s_hist >= 1``
    — an empty history segment is the caller's degenerate case).
    """
    if mode not in ("cached", "extend"):
        raise ValueError(mode)
    b, h, mp, d = q.shape
    hkv = k_hist.shape[1]
    g = h // hkv
    sp = k_hist.shape[2]
    nq = mp // bq
    hist_steps = sp // bk
    self_steps = 1 if mode == "cached" else nq
    steps = hist_steps + self_steps

    kernel = functools.partial(
        _fused_kernel, mode=mode, h=h, g=g, bq=bq, bk=bk, sq=sq,
        s_hist=s_hist, hist_steps=hist_steps, steps=steps)

    grid = (b * h, nq, steps)

    def q_map(bh, qi, kj, idx_ref, lens_ref, ks_ref, vs_ref):
        return (bh // h, bh % h, qi, 0)

    def kh_map(bh, qi, kj, idx_ref, lens_ref, ks_ref, vs_ref):
        # the dedup/packing gather, folded into the block read: q block qi
        # of batch row b pulls the blocks of pool row idx_ref[b, qi]
        # (clamped for self steps, whose loaded block is unused)
        return (idx_ref[bh // h, qi], (bh % h) // g,
                jnp.minimum(kj, hist_steps - 1),
                0)  # flamecheck: kernel-ok(pure scalar clamp of a grid index; Python min fails on the traced kj)

    def kc_map(bh, qi, kj, idx_ref, lens_ref, ks_ref, vs_ref):
        if mode == "cached":
            cj = qi
        else:
            cj = jnp.clip(kj - hist_steps, 0,
                          nq - 1)  # flamecheck: kernel-ok(pure scalar clamp of a grid index; Python min/max fail on the traced kj)
        return (bh // h, (bh % h) // g, cj, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,       # row_index, lengths, k_scale, v_scale
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bk, d), kh_map),
            pl.BlockSpec((1, 1, bk, d), kh_map),
            pl.BlockSpec((1, 1, bq, d), kc_map),
            pl.BlockSpec((1, 1, bq, d), kc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),    # l (running denom)
            pltpu.VMEM((bq, d), jnp.float32),    # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(row_index, lengths, k_scale, v_scale, q, k_hist, v_hist, k_cand,
      v_cand)
