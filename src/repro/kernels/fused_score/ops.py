"""Public wrappers for the fused candidate-scoring engine (FKE).

Entry points (model layout, [B,S,H,D]):

  ``fused_cached_attention``  candidate-only SUMI scoring against pooled
                              history K/V (quantized operands + dedup
                              ``row_index`` welcome)
  ``fused_extend_attention``  causal suffix extension against pooled
                              prefix K/V
  ``fused_decode_attention``  generative-decode candidate scoring: cached
                              mode against PADDED growing beam caches,
                              bounded per pool row by ``lengths`` (FKE v2)
  ``block_epilogue``          out-projection + residual + norm + FFN for
                              one transformer-block layer step, reusing
                              ``kernels/fused_ffn`` on TPU

Each attention op has two execution paths behind one signature:

  ``path="kernel"``  the Pallas kernel (``kernel.py``): real TPU target,
                     interpret-mode on CPU for the parity suite;
  ``path="jnp"``     an XLA-fused two-segment formulation of the *same*
                     restructured computation — no ``concat(hist, cand)``
                     materialization, no dense SUMI mask (the history
                     segment is fully visible and the self segment is the
                     diagonal, so masking disappears algebraically), the
                     dequant scale folded into the score/accumulator
                     multiplies, and the dedup gather applied to the
                     *stored* (int8/bf16) values rather than dequantized
                     f32 rows.  This is what makes ``impl="fused"`` a real
                     speedup on the CPU backend, where interpret-mode
                     Pallas would be pure overhead;
  ``path="auto"``    kernel on TPU, jnp elsewhere.

Both paths are gated against ``ref.py`` (the dequantize → gather → concat
→ reference-attention oracle) in ``tests/test_fke.py``.
"""
from __future__ import annotations

import functools
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels.fused_score.kernel import fused_score_kernel
from repro.kernels.fused_score.ref import dequantize_values


def _auto_path() -> str:
    return "kernel" if jax.default_backend() == "tpu" else "jnp"


# Observability for the 2-D (segment-packed) row_index auto-reroute below:
# rerouting to the jnp formulation is correct but must not be silent — on
# TPU it forfeits the kernel path the packer was built to feed.  The count
# ticks once per traced call (i.e. once per AOT executor family built with
# packed indices, not per request) and is surfaced by FlameEngine as the
# ``packed_kernel_reroutes`` ServeMetrics counter.
_reroute_lock = threading.Lock()
_packed_reroutes = 0
_reroute_warned = False


def _note_packed_reroute():
    global _packed_reroutes, _reroute_warned
    with _reroute_lock:
        _packed_reroutes += 1
        first = not _reroute_warned
        _reroute_warned = True
    if first:
        warnings.warn(
            "fused_score: 2-D (segment-packed) row_index rerouted from the "
            "Pallas kernel to the jnp formulation — packed segments are not "
            "bq-aligned yet (ROADMAP: packer `align` knob). Pass "
            "path='kernel' only with block-aligned segments.",
            RuntimeWarning, stacklevel=4)


def packed_reroute_count() -> int:
    """Total 2-D row_index kernel->jnp auto-reroutes this process."""
    with _reroute_lock:
        return _packed_reroutes


# Packed-dispatch alignment contract (process-wide, set by the engine
# before its executors trace): a nonzero value declares that every
# segment in a 2-D packed ``row_index`` starts at a multiple of that many
# candidates — the SegmentPacker's ``align`` knob (core/dso.py) is the
# producer.  With the contract declared, ``path="auto"`` keeps packed
# calls on the kernel path (bq = the declared alignment, so sampling the
# index at each q block's first candidate can never read across a
# segment boundary) instead of rerouting to the jnp formulation.
_packed_align = 0


def set_packed_alignment(n: int) -> int:
    """Declare the packed-segment alignment (0 clears). Returns the
    previous value so callers can restore it."""
    global _packed_align
    n = int(n)
    if n and (n < 8 or n % 8):
        raise ValueError("packed alignment must be 0 or a multiple of 8 "
                         f"(the f32 sublane tile), got {n}")
    with _reroute_lock:
        prev = _packed_align
        _packed_align = n
    return prev


def packed_alignment() -> int:
    with _reroute_lock:
        return _packed_align


# Segment-packed (2-D row_index) histories at/above this length skip the
# per-candidate [B,M,S,Hkv,D] value gather and score via a dense all-rows
# GEMM + exact one-hot selection instead.  The gather turns the score
# contraction into M independent [1,D]x[D,S] GEMVs per batch row (poor
# arithmetic intensity) and materializes an M-times-replicated history
# operand; the dense form keeps the stored [U,S,Hkv,D] pool operand in
# place and contracts it with ALL candidates in one [B*M*G, D]x[D, U*S]
# GEMM, then selects each candidate's own row with a 0/1 one-hot einsum.
# The selection itself is exact (multiply by 1.0 / add 0.0 are lossless
# in IEEE-754); only the usual contraction-order reassociation separates
# the two forms.  The U-fold extra FLOPs only pay off once S is long
# enough for GEMM efficiency to dominate — short histories keep the
# gather.
_SEG_GEMM_MIN_S = 128


def _norm_scale(scale, u: int, hkv: int):
    """Pool scales arrive [U,1,Hkv,1] (per-layer slice of the per-(layer,
    head) absmax); normalize to [U,Hkv] with the int8 /127 folded in."""
    if scale is None:
        return None
    return (jnp.asarray(scale, jnp.float32) / 127.0).reshape(u, hkv)


# ---------------------------------------------------------------------------
# fused jnp fast path
# ---------------------------------------------------------------------------

def _segment_scores(qf, k_seg, scale):
    """qf [B,M,Hkv,g,D] (f32, pre-scaled) x k_seg [B,S,Hkv,D] (stored
    dtype) -> scores [B,Hkv,g,M,S] with the dequant scale folded in."""
    s = jnp.einsum("bmhgd,bshd->bhgms", qf, k_seg.astype(jnp.float32))
    if scale is not None:
        s = s * scale[:, :, None, None, None]        # [B,Hkv] broadcast
    return s


@functools.partial(jax.jit, static_argnames=("mode",))
def _fused_jnp(q, k_hist, v_hist, k_cand, v_cand, k_scale, v_scale,
               row_index, lengths, mode: str):
    """Two-segment online-merged attention, no concat / no dense mask.

    ``cached``: history segment fully visible, self segment = one key per
    query (an O(M·D) einsum instead of the O(M²·D) masked block).
    ``extend``: prefix segment fully visible, suffix segment causal.

    ``lengths`` (decode): per-pool-row valid history prefix over a padded
    stored operand.  Masked columns are forced to exact -inf before the
    segment max and exact 0 after the exp — both rewrites are bitwise
    no-ops for fully-valid rows (``where(True, x, ·) == x``), which is
    what keeps fused decode at zero generated tokens bitwise equal to
    fused candidate scoring, and the post-exp zero is what keeps a fully
    masked row (lengths == 0) exact rather than NaN.
    """
    b, m, h, d = q.shape
    hkv = k_cand.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, m, hkv, g, d) / np.sqrt(d)
    seg = row_index is not None and row_index.ndim == 2
    seg_gemm = seg and k_hist.shape[1] >= _SEG_GEMM_MIN_S
    onehot = None
    hist_ok = None
    if lengths is not None:
        lens = jnp.asarray(lengths, jnp.int32)
        if row_index is not None:
            lens = jnp.take(lens, row_index, axis=0)     # [B] or [B,M]
        pos = jnp.arange(k_hist.shape[1])
        if lens.ndim == 2:
            hist_ok = (pos[None, None, :] <
                       lens[:, :, None])[:, None, None]  # [b,1,1,m,S]
        else:
            hist_ok = (pos[None, :] <
                       lens[:, None])[:, None, None, None]   # [b,1,1,1,S]
    if row_index is not None:
        # the dedup gather runs on the STORED values (int8: 4x fewer
        # bytes than the dequantized rows the framework path gathered).
        # A 2-D (per-candidate) index — DSO v2 segment packing — gathers
        # each candidate's own pool row: [B,M,S,Hkv,D] history operands
        # and [B,M,Hkv] scales.  At long histories (seg_gemm) the VALUE
        # gathers are skipped — scoring contracts the stored pool rows
        # directly (see _SEG_GEMM_MIN_S) — but the tiny scale gathers
        # stay, so the dequant multiply is per-candidate in both forms.
        if not seg_gemm:
            k_hist = jnp.take(k_hist, row_index, axis=0)
            v_hist = jnp.take(v_hist, row_index, axis=0)
        if k_scale is not None:
            k_scale = jnp.take(k_scale, row_index, axis=0)
        if v_scale is not None:
            v_scale = jnp.take(v_scale, row_index, axis=0)
    if seg_gemm:
        # dense all-rows GEMM + exact one-hot selection: restores a real
        # [B*M*G, D] x [D, U*S] GEMM shape where the gathered form is M
        # independent GEMVs per batch row
        u = k_hist.shape[0]
        onehot = (row_index[..., None] == jnp.arange(u)) \
            .astype(jnp.float32)                         # [b,m,U]
        s_all = jnp.einsum("bmhgd,ushd->bhgmus", qf,
                           k_hist.astype(jnp.float32))
        s_hist = jnp.einsum("bhgmus,bmu->bhgms", s_all, onehot)
        if k_scale is not None:
            s_hist = s_hist * jnp.moveaxis(
                k_scale, 2, 1)[:, :, None, :, None]      # [b,hkv,1,m,1]
    elif seg:
        # per-candidate history segment: same per-(m, s) dot products as
        # the shared-history einsum, just indexed per candidate
        s_hist = jnp.einsum("bmhgd,bmshd->bhgms", qf,
                            k_hist.astype(jnp.float32))
        if k_scale is not None:
            s_hist = s_hist * jnp.moveaxis(
                k_scale, 2, 1)[:, :, None, :, None]      # [b,hkv,1,m,1]
    else:
        s_hist = _segment_scores(qf, k_hist, k_scale)    # [b,hkv,g,m,S]
    if hist_ok is not None:
        s_hist = jnp.where(hist_ok, s_hist, -1e30)

    if mode == "cached":
        # self segment: query i sees exactly key i — the diagonal einsum
        s_self = jnp.einsum("bmhgd,bmhd->bhgm", qf,
                            k_cand.astype(jnp.float32))
        m_all = jnp.maximum(s_hist.max(axis=-1), s_self)
        p_hist = jnp.exp(s_hist - m_all[..., None])
        if hist_ok is not None:
            p_hist = jnp.where(hist_ok, p_hist, 0.0)
        p_self = jnp.exp(s_self - m_all)
        l = p_hist.sum(axis=-1) + p_self
        if seg_gemm:
            # scatter each candidate's probabilities back to its own pool
            # row (one nonzero u per (b, m) — exact), then contract the
            # stored values in place: one [B*M*G, U*S] x [U*S, D] GEMM
            weighted = jnp.einsum("bhgms,bmu->bhgums", p_hist, onehot)
            o = jnp.einsum("bhgums,ushd->bmhgd", weighted,
                           v_hist.astype(jnp.float32))
            if v_scale is not None:
                o = o * v_scale[:, :, :, None, None]     # [b,m,hkv,1,1]
        elif seg:
            o = jnp.einsum("bhgms,bmshd->bmhgd", p_hist,
                           v_hist.astype(jnp.float32))
            if v_scale is not None:
                o = o * v_scale[:, :, :, None, None]     # [b,m,hkv,1,1]
        else:
            o = jnp.einsum("bhgms,bshd->bmhgd", p_hist,
                           v_hist.astype(jnp.float32))
            if v_scale is not None:
                o = o * v_scale[:, None, :, None, None]
        o = o + jnp.einsum("bhgm,bmhd->bmhgd", p_self,
                           v_cand.astype(jnp.float32))
    else:                                            # extend (causal)
        s_suf = jnp.einsum("bmhgd,bshd->bhgms", qf,
                           k_cand.astype(jnp.float32))
        causal = (jnp.arange(m)[None, :] <= jnp.arange(m)[:, None])
        s_suf = jnp.where(causal[None, None, None], s_suf, -1e30)
        m_all = jnp.maximum(s_hist.max(axis=-1), s_suf.max(axis=-1))
        p_hist = jnp.exp(s_hist - m_all[..., None])
        if hist_ok is not None:
            p_hist = jnp.where(hist_ok, p_hist, 0.0)
        p_suf = jnp.exp(s_suf - m_all[..., None])
        p_suf = jnp.where(causal[None, None, None], p_suf, 0.0)
        l = p_hist.sum(axis=-1) + p_suf.sum(axis=-1)
        o = jnp.einsum("bhgms,bshd->bmhgd", p_hist,
                       v_hist.astype(jnp.float32))
        if v_scale is not None:
            o = o * v_scale[:, None, :, None, None]
        o = o + jnp.einsum("bhgms,bshd->bmhgd", p_suf,
                           v_cand.astype(jnp.float32))
    l = jnp.moveaxis(jnp.maximum(l, 1e-30), 3, 1)    # [b,m,hkv,g]
    return (o / l[..., None]).reshape(b, m, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# kernel path plumbing
# ---------------------------------------------------------------------------

def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("mode", "bq", "bk",
                                             "interpret"))
def _fused_kernel_call(q, k_hist, v_hist, k_cand, v_cand, k_scale, v_scale,
                       row_index, lengths, mode: str, bq: int, bk: int,
                       interpret: bool):
    b, m, h, d = q.shape
    u, s_hist, hkv, _ = k_hist.shape
    bq = min(bq, max(8, 1 << (m - 1).bit_length()))
    bk = min(bk, max(8, 1 << (s_hist - 1).bit_length()))
    scale = 1.0 / np.sqrt(d)
    # model layout [B,S,H,D] -> kernel layout [B,H,S,D], pad S to block
    # multiples and D to the 128-lane width
    qp = _pad_to(_pad_to(jnp.swapaxes(q * scale, 1, 2), 2, bq), 3, 128)
    khp = _pad_to(_pad_to(jnp.swapaxes(k_hist, 1, 2), 2, bk), 3, 128)
    vhp = _pad_to(_pad_to(jnp.swapaxes(v_hist, 1, 2), 2, bk), 3, 128)
    kcp = _pad_to(_pad_to(jnp.swapaxes(k_cand, 1, 2), 2, bq), 3, 128)
    vcp = _pad_to(_pad_to(jnp.swapaxes(v_cand, 1, 2), 2, bq), 3, 128)
    ones = jnp.ones((u, hkv), jnp.float32)
    ks = ones if k_scale is None else k_scale
    vs = ones if v_scale is None else v_scale
    # per-q-block KV row index [B, nq] in scalar prefetch: the DSO v2
    # generalization of the per-row dedup index — every q block of every
    # batch row reads its own pool row's KV blocks, so a segment-packed
    # row steers each candidate segment to its own user's history.  A
    # per-candidate (2-D) index requires segments aligned to ``bq``
    # boundaries (the packer's kernel-path contract); it is sampled at
    # each q block's first candidate.
    nq = qp.shape[2] // bq
    if row_index is None:
        idx = jnp.tile(jnp.arange(b, dtype=jnp.int32)[:, None], (1, nq))
    elif row_index.ndim == 1:
        idx = jnp.tile(row_index.astype(jnp.int32)[:, None], (1, nq))
    else:
        full = jnp.pad(row_index.astype(jnp.int32),
                       ((0, 0), (0, nq * bq - m)), mode="edge")
        idx = full[:, ::bq]
    lens = (jnp.full((u,), s_hist, jnp.int32) if lengths is None
            else jnp.asarray(lengths, jnp.int32))
    out = fused_score_kernel(idx, lens, ks, vs, qp.astype(q.dtype), khp,
                             vhp, kcp, vcp, mode=mode, sq=m, s_hist=s_hist,
                             bq=bq, bk=bk, interpret=interpret)
    return jnp.swapaxes(out[:, :, :m, :d], 1, 2)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _fused_attention(q, k_hist, v_hist, k_cand, v_cand, *, mode: str,
                     k_scale=None, v_scale=None, row_index=None,
                     lengths=None, temperature=None, path: str = "auto",
                     interpret=None):
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    u, hkv = k_hist.shape[0], k_hist.shape[2]
    ks = _norm_scale(k_scale, u, hkv)
    vs = _norm_scale(v_scale, u, hkv)
    bq = 128
    if row_index is not None and row_index.ndim == 2:
        if mode != "cached":
            raise ValueError("per-candidate (segment-packed) row_index only "
                             "applies to cached candidate scoring")
        if row_index.shape != q.shape[:2]:
            raise ValueError(f"2-D row_index must be [B, M] = {q.shape[:2]}, "
                             f"got {row_index.shape}")
        align = packed_alignment()
        if path == "auto":
            if align:
                # the packer declared bq-aligned segments (SegmentPacker
                # align knob), so per-q-block index sampling is safe: take
                # the kernel path on TPU like any other fused call
                path = _auto_path()
            else:
                # the kernel path steers KV per q BLOCK, so packed
                # segments must be bq-aligned.  Sampling an unaligned
                # index at block starts would silently score candidates
                # against the wrong user's history, so auto routes
                # per-candidate indices to the jnp formulation on every
                # backend; explicit path="kernel" remains the tested
                # aligned-segment contract.
                path = "jnp"
                _note_packed_reroute()
        if align:
            bq = align      # q blocks == declared segment alignment
    if k_hist.shape[1] == 0:
        raise ValueError("fused attention needs a non-empty history/prefix "
                         "segment (degenerate cases route to the framework "
                         "impls in core/sumi.py)")
    if path == "auto":
        path = _auto_path()
    if path == "kernel":
        if interpret is None:
            interpret = default_interpret()
        return _fused_kernel_call(q, k_hist, v_hist, k_cand, v_cand,
                                  ks, vs, row_index, lengths, mode, bq,
                                  128, interpret)
    if path != "jnp":
        raise ValueError(f"path must be auto|kernel|jnp, got {path!r}")
    return _fused_jnp(q, k_hist, v_hist, k_cand, v_cand, ks, vs,
                      row_index, lengths, mode)


def fused_cached_attention(q, k_hist, v_hist, k_cand, v_cand, *,
                           k_scale=None, v_scale=None, row_index=None,
                           temperature=None, path: str = "auto",
                           interpret=None):
    """Candidate-only SUMI attention against pooled history K/V.

    ``q``/``k_cand``/``v_cand`` [B,M,H(kv),D] fresh candidate projections;
    ``k_hist``/``v_hist`` [U,S,Hkv,D] pool-stored values (int8/bf16/
    native) with optional [U,1,Hkv,1] ``k_scale``/``v_scale`` and a [B]
    ``row_index`` selecting each batch row's pool row (KV-row dedup)."""
    return _fused_attention(q, k_hist, v_hist, k_cand, v_cand,
                            mode="cached", k_scale=k_scale, v_scale=v_scale,
                            row_index=row_index, temperature=temperature,
                            path=path, interpret=interpret)


def fused_decode_attention(q, k_hist, v_hist, k_cand, v_cand, lengths, *,
                           k_scale=None, v_scale=None, row_index=None,
                           temperature=None, path: str = "auto",
                           interpret=None):
    """Generative-decode candidate scoring against padded beam caches.

    Cached-mode fused attention with a per-pool-row valid-prefix bound:
    ``k_hist``/``v_hist`` [U,S,Hkv,D] are PADDED growing caches (history
    + appended generated tokens, S = s0 + max_steps) in the pool's stored
    precision, and ``lengths`` [U] int32 bounds each row's valid prefix.
    Candidates/queries follow :func:`fused_cached_attention` conventions,
    including 1-D dedup and 2-D segment-packed ``row_index`` steering
    (lengths are gathered per candidate through the same index).  At
    ``lengths == S`` this is bitwise :func:`fused_cached_attention`."""
    return _fused_attention(q, k_hist, v_hist, k_cand, v_cand,
                            mode="cached", k_scale=k_scale, v_scale=v_scale,
                            row_index=row_index, lengths=lengths,
                            temperature=temperature, path=path,
                            interpret=interpret)


def fused_extend_attention(q, k_prefix, v_prefix, k_suffix, v_suffix, *,
                           k_scale=None, v_scale=None, row_index=None,
                           temperature=None, path: str = "auto",
                           interpret=None):
    """Causal suffix attention against pooled prefix K/V (incremental
    history extension).  Same operand conventions as
    :func:`fused_cached_attention`; query row i sits at absolute position
    ``P + i`` and the suffix segment is causal within itself."""
    return _fused_attention(q, k_prefix, v_prefix, k_suffix, v_suffix,
                            mode="extend", k_scale=k_scale, v_scale=v_scale,
                            row_index=row_index, temperature=temperature,
                            path=path, interpret=interpret)


def block_epilogue(x, o, attn_params, norm_params, ffn_params, cfg, *,
                   path: str = "auto", interpret=None):
    """Per-layer epilogue of one fused block step: out-projection +
    residual + norm + FFN + residual.

    On TPU (``path="auto"``) the norm + FFN chain reuses the
    ``kernels/fused_ffn`` Pallas kernel (norm folded into the first
    matmul, f32 VMEM accumulator); elsewhere it is the exact framework
    composition, so the jnp fused path stays bitwise-aligned with the
    chunked impl's epilogue."""
    from repro.models import layers as L
    from repro.models.ffn import ffn_apply

    x = x + jnp.einsum("bshk,hkd->bsd", o, attn_params["wo"])
    if path == "auto":
        path = _auto_path()
    if path == "kernel" and cfg.norm == "rmsnorm":
        from repro.kernels.fused_ffn import ops as ffn_ops
        return x + ffn_ops.fused_ffn(x, ffn_params,
                                     activation=cfg.activation,
                                     norm_scale=norm_params["scale"],
                                     interpret=interpret)
    h2 = L.apply_norm(cfg, norm_params, x)
    return x + ffn_apply(ffn_params, h2, cfg, impl="xla")
