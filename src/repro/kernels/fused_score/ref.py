"""Pure-jnp fp32 oracle for the fused candidate-scoring kernel (FKE).

The oracle spells out exactly what the fused paths must compute, as the
framework-composed chain the engine ran before the FKE existed:

  1. dequantize the pooled history K/V (same formula as
     ``serving/kv_cache.py::dequantize_leaf`` — a quantized operand is
     ``values * scale / 127`` cast back to the compute dtype);
  2. gather each batch row's KV view through the dedup ``row_index``;
  3. concatenate history and candidate K/V along the position axis;
  4. run materialized-score reference attention (SUMI with
     ``q_offset = n_history`` for cached-candidate scoring, causal with
     ``q_offset = prefix_len`` for incremental suffix extension).

Because steps 1–4 literally reuse the framework reference ops, the oracle
is **bitwise-identical** to ``sumi.cached_candidate_attention`` /
``sumi.extend_attention`` under ``impl="reference"`` on dequantized
operands; the Pallas kernel and the fused jnp fast path are gated against
it at bf16-style tolerances (they reassociate the scale multiply and skip
intermediate roundings).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import attention as A


def dequantize_values(values, scale, dtype):
    """Invert pool quantization on a raw (values, scale) operand pair.

    Mirrors ``serving/kv_cache.py::dequantize_leaf`` bitwise: ``scale is
    None`` marks a plain cast (bf16 storage or a no-op for native), int8
    values dequantize through the per-(layer, head) absmax scale."""
    if scale is None:
        return jnp.asarray(values).astype(dtype)
    return (jnp.asarray(values, jnp.float32)
            * (jnp.asarray(scale) / 127.0)).astype(dtype)


def _prep(k, v, k_scale, v_scale, row_index, dtype):
    """Steps 1–2: dequantize + gather the per-row KV views."""
    k = dequantize_values(k, k_scale, dtype)
    v = dequantize_values(v, v_scale, dtype)
    if row_index is not None:
        k = jnp.take(k, row_index, axis=0)
        v = jnp.take(v, row_index, axis=0)
    return k, v


def cached_reference(q, k_hist, v_hist, k_cand, v_cand, *,
                     k_scale=None, v_scale=None, row_index=None,
                     kv_dtype=None, temperature=None):
    """Cached-candidate SUMI oracle.  ``q``/``k_cand``/``v_cand``
    [B,M,H(kv),D]; ``k_hist``/``v_hist`` [U,S,Hkv,D] stored values with
    optional [U,1,Hkv,1] scales and a [B] ``row_index`` gather."""
    dtype = kv_dtype or q.dtype
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    kh, vh = _prep(k_hist, v_hist, k_scale, v_scale, row_index, dtype)
    n_history = kh.shape[1]
    k = jnp.concatenate([kh, k_cand.astype(dtype)], axis=1)
    v = jnp.concatenate([vh, v_cand.astype(dtype)], axis=1)
    return A.reference_attention(q, k, v, "sumi", n_history=n_history,
                                 q_offset=n_history)


def decode_reference(q, k_hist, v_hist, k_cand, v_cand, lengths, *,
                     k_scale=None, v_scale=None, row_index=None,
                     kv_dtype=None, temperature=None):
    """Generative-decode oracle: cached-candidate SUMI scoring over a
    PADDED beam-cache operand whose valid prefix per pool row is
    ``lengths[u]`` (<= S).  Dequantize -> gather (1-D per batch row or
    2-D per candidate, lengths riding the same index) -> materialized
    masked softmax: candidate i sees its row's valid history prefix plus
    exactly its own key.  ``lengths == 0`` rows degenerate to a softmax
    over the self key alone."""
    dtype = kv_dtype or q.dtype
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    kh = dequantize_values(k_hist, k_scale, dtype)
    vh = dequantize_values(v_hist, v_scale, dtype)
    lens = jnp.asarray(lengths, jnp.int32)
    if row_index is not None:
        kh = jnp.take(kh, row_index, axis=0)
        vh = jnp.take(vh, row_index, axis=0)
        lens = jnp.take(lens, row_index, axis=0)
    b, m, h, d = q.shape
    hkv = k_cand.shape[2]
    g = h // hkv
    s = kh.shape[-3]
    if kh.ndim == 4:                    # shared per batch row -> [B,M,...]
        kh = jnp.broadcast_to(kh[:, None], (b, m) + kh.shape[1:])
        vh = jnp.broadcast_to(vh[:, None], (b, m) + vh.shape[1:])
    if lens.ndim == 1:
        lens = jnp.broadcast_to(lens[:, None], (b, m))
    qf = q.astype(jnp.float32).reshape(b, m, hkv, g, d) / math.sqrt(d)
    s_hist = jnp.einsum("bmhgd,bmshd->bmhgs", qf, kh.astype(jnp.float32))
    s_self = jnp.einsum("bmhgd,bmhd->bmhg", qf, k_cand.astype(jnp.float32))
    ok = (jnp.arange(s)[None, None, :] < lens[:, :, None])
    s_hist = jnp.where(ok[:, :, None, None, :], s_hist, -1e30)
    logits = jnp.concatenate([s_hist, s_self[..., None]], axis=-1)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bmhgs,bmshd->bmhgd", p[..., :s], vh.astype(jnp.float32))
    o = o + p[..., s][..., None] * v_cand.astype(jnp.float32)[:, :, :, None, :]
    return o.reshape(b, m, h, d).astype(q.dtype)


def extend_reference(q, k_prefix, v_prefix, k_suffix, v_suffix, *,
                     k_scale=None, v_scale=None, row_index=None,
                     kv_dtype=None, temperature=None):
    """Incremental-extension (causal) oracle: suffix queries at absolute
    position ``prefix_len + i`` over ``concat(prefix, suffix)`` KV."""
    dtype = kv_dtype or q.dtype
    if temperature is not None:
        q = q / jnp.asarray(temperature, q.dtype)
    kp, vp = _prep(k_prefix, v_prefix, k_scale, v_scale, row_index, dtype)
    p0 = kp.shape[1]
    k = jnp.concatenate([kp, k_suffix.astype(dtype)], axis=1)
    v = jnp.concatenate([vp, v_suffix.astype(dtype)], axis=1)
    return A.reference_attention(q, k, v, "causal", q_offset=p0)
