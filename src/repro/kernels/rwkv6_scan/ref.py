"""Pure-jnp oracle for the RWKV-6 wkv scan: token-by-token recurrence.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference(r, k, v, w_log, u, state=None):
    """r,k,v,w_log [BH,S,D]; u [BH,D]; state [BH,D,D] -> (o [BH,S,D], state)."""
    bh, s, d = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(w_log.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((bh, d, d), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp          # [BH,D] each
        o = jnp.einsum("bd,bde->be", rt, S) + \
            jnp.einsum("bd,bd->b", rt * uf, kt)[:, None] * vt
        S = wt[..., None] * S + jnp.einsum("bd,be->bde", kt, vt)
        return S, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, w))
    state, o = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(o, 0, 1).astype(r.dtype), state
