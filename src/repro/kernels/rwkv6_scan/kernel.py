"""RWKV-6 chunked wkv scan — Pallas TPU kernel.

The FKE insight (fuse the hot recurrence into one VMEM-resident kernel)
applied to the attention-free architecture: per (batch x head) the
data-dependent-decay linear-attention recurrence is processed in chunks —
intra-chunk contributions via pairwise log-space decays (always <= 1, so
numerically stable), inter-chunk via a [D, D] state carried in VMEM scratch
across the sequential chunk axis.  MXU work per chunk: [c,D]x[D,D] and
[c,c]x[c,D] GEMMs.

Grid = (BH, n_chunks); chunk axis is innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, wl_ref, u_ref, s0_ref, o_ref, sf_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    r = r_ref[0].astype(jnp.float32)           # [c, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    wl = wl_ref[0].astype(jnp.float32)         # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)           # [1, D] -> broadcast
    S = state_ref[...]                         # [D, D]

    la = jnp.cumsum(wl, axis=0)                # inclusive cumulative log decay
    la_prev = la - wl                          # exclusive

    # inter-chunk: o_inter[t] = (r_t * exp(la_prev_t)) @ S
    r_dec = r * jnp.exp(la_prev)
    o_inter = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())))

    # intra-chunk: scores[t,s] = sum_d r[t,d] k[s,d] exp(la_prev[t,d]-la[s,d]), s<t
    diff = la_prev[:, None, :] - la[None, :, :]              # [c,c,D] <= 0 for s<t
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.where(tri[..., None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("td,sd,tsd->ts", r, k, dec)
    o_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))

    # current-token bonus: (r_t . (u*k_t)) v_t
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True) * v

    o_ref[0] = (o_inter + o_intra + bonus).astype(o_ref.dtype)

    # state update: S' = diag(exp(la_last)) S + sum_s (k_s exp(la_last-la_s)) v_s^T
    la_c = la[-1:]
    k_dec = k * jnp.exp(la_c - la)
    S_new = jnp.exp(la_c[0])[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())))
    state_ref[...] = S_new

    @pl.when(ci == n_chunks - 1)
    def _final():
        sf_ref[0] = S_new


def rwkv6_scan_kernel(r, k, v, w_log, u, s0, *, chunk: int = 64,
                      interpret: bool = True):
    """r,k,v,w_log [BH, S, D] (S % chunk == 0); u [BH, 1, D]; s0 [BH, D, D].

    Returns (o [BH, S, D], final_state [BH, D, D])."""
    bh, s, d = r.shape
    n_chunks = s // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),    # u
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),    # s0
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), r.dtype),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w_log, u, s0)
