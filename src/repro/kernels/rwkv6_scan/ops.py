"""Public jit'd wrapper for the RWKV-6 chunked wkv scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w_log, u, state=None, *, chunk: int = 64,
               interpret: bool | None = None):
    """r,k,v,w_log [B,S,H,D]; u [H,D]; state [B,H,D,D] (optional).

    Returns (o [B,S,H,D], final_state [B,H,D,D])."""
    if interpret is None:
        interpret = default_interpret()
    b, s, h, d = r.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    sp = s + pad

    def to_bh(x):
        x = jnp.moveaxis(x, 2, 1).reshape(b * h, s, d)
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0)))

    rb, kb, vb = to_bh(r), to_bh(k), to_bh(v)
    # pad decay with log(1)=0 so padded steps don't decay the state
    wb = to_bh(w_log)
    ub = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)
    s0 = (state.reshape(b * h, d, d).astype(jnp.float32) if state is not None
          else jnp.zeros((b * h, d, d), jnp.float32))
    o, sf = rwkv6_scan_kernel(rb, kb, vb, wb, ub, s0, chunk=chunk,
                              interpret=interpret)
    o = jnp.moveaxis(o[:, :s].reshape(b, h, s, d), 1, 2)
    return o, sf.reshape(b, h, d, d)
