"""Pallas TPU kernels — the Fused Kernel Engine (FKE) compute layer.

The paper's FKE fuses (a) mask-aware Flash-Attention (SUMI candidate mask)
and (b) LayerNorm+FFN into TensorRT plug-ins.  Here each hot-spot is a Pallas
kernel in its own subpackage with the framework triple:

  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public wrapper (padding, layout, interpret-mode fallback)
  ref.py     pure-jnp oracle used by the allclose test sweeps

Kernels: flash_attention (causal/sliding/full/SUMI masks with block skipping),
fused_ffn (norm + W1(+gate) + act + W2, f32 VMEM accumulator), fused_score
(the FKE cached-candidate scoring engine: two-segment SUMI/extend attention
reading quantized pool KV and the dedup row index in-kernel), rwkv6_scan
(chunked data-dependent-decay linear attention for the attention-free arch).

On this CPU container kernels execute under ``interpret=True``; on TPU the
same BlockSpecs drive the real pipeline emitter (HBM->VMEM double buffering
against the MXU — the TPU analogue of the paper's cp_async GEMM pipelining).
"""

import jax


def default_interpret() -> bool:
    """interpret=True unless running on real TPU hardware."""
    return jax.default_backend() != "tpu"
