"""Fused RMSNorm + FFN — Pallas TPU kernel (the FKE "fused-FFN plug-in").

One kernel computes  out = act(norm(x) @ W_up [, * silu(norm(x) @ W_gate)]) @ W_down
without round-tripping the normalized activations or the [T, d_ff] hidden
through HBM.  Grid = (token blocks, d_ff blocks); the d_ff axis is the
sequential inner axis:

  fj == 0   : normalize the x block once into VMEM scratch
  every fj  : [bt, d] x [d, bf] up/gate GEMMs on the MXU, activation,
              [bt, bf] x [bf, d] partial down GEMM accumulated in f32 scratch
  fj == last: cast + write the output block

VMEM working set per step: x/xn blocks (bt x d), W slices (d x bf + bf x d),
f32 accumulator (bt x d) — block sizes chosen in ops.py so this fits ~16MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ffn_kernel(x_ref, scale_ref, wu_ref, wg_ref, wd_ref, o_ref,
                xn_ref, acc_ref, *, activation: str, nf: int, eps: float,
                has_norm: bool):
    fj = pl.program_id(1)

    @pl.when(fj == 0)
    def _init():
        x = x_ref[...].astype(jnp.float32)
        if has_norm:
            var = jnp.mean(x * x, axis=-1, keepdims=True)
            x = x * jax.lax.rsqrt(var + eps) * \
                (1.0 + scale_ref[0].astype(jnp.float32))
        xn_ref[...] = x
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xn = xn_ref[...]
    up = jax.lax.dot_general(xn, wu_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ())))
    if activation == "swiglu":
        gate = jax.lax.dot_general(xn, wg_ref[...].astype(jnp.float32),
                                   (((1,), (0,)), ((), ())))
        act = jax.nn.silu(gate) * up
    elif activation == "gelu":
        act = jax.nn.gelu(up)
    else:
        act = jax.nn.relu(up)
    acc_ref[...] += jax.lax.dot_general(act, wd_ref[...].astype(jnp.float32),
                                        (((1,), (0,)), ((), ())))

    @pl.when(fj == nf - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_ffn_kernel(x, norm_scale, w_up, w_gate, w_down, *,
                     activation: str = "swiglu", has_norm: bool = True,
                     bt: int = 256, bf: int = 512, eps: float = 1e-6,
                     interpret: bool = True):
    """x [T, d] (T % bt == 0, f % bf == 0 — padded by ops.py)."""
    t, d = x.shape
    f = w_up.shape[1]
    nt, nf = t // bt, f // bf
    kernel = functools.partial(_ffn_kernel, activation=activation, nf=nf,
                               eps=eps, has_norm=has_norm)
    return pl.pallas_call(
        kernel,
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda ti, fj: (ti, 0)),     # x
            pl.BlockSpec((1, d), lambda ti, fj: (0, 0)),       # norm scale
            pl.BlockSpec((d, bf), lambda ti, fj: (0, fj)),     # w_up slice
            pl.BlockSpec((d, bf), lambda ti, fj: (0, fj)),     # w_gate slice
            pl.BlockSpec((bf, d), lambda ti, fj: (fj, 0)),     # w_down slice
        ],
        out_specs=pl.BlockSpec((bt, d), lambda ti, fj: (ti, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, d), jnp.float32),                  # normalized x
            pltpu.VMEM((bt, d), jnp.float32),                  # f32 accumulator
        ],
        interpret=interpret,
    )(x, norm_scale, w_up, w_gate, w_down)
