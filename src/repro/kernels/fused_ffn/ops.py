"""Public jit'd wrapper for the fused norm+FFN kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.fused_ffn.kernel import fused_ffn_kernel


@functools.partial(jax.jit, static_argnames=("activation", "bt", "bf",
                                             "interpret"))
def fused_ffn_2d(x, w_up, w_down, w_gate=None, norm_scale=None, *,
                 activation: str = "swiglu", bt: int = 256, bf: int = 512,
                 interpret: bool | None = None):
    """x [T,d] -> [T,d] fused norm+FFN."""
    if interpret is None:
        interpret = default_interpret()
    t, d = x.shape
    f = w_up.shape[1]
    bt = min(bt, max(8, t))
    bf = min(bf, f)
    pad_t = (-t) % bt
    pad_f = (-f) % bf
    xp = jnp.pad(x, ((0, pad_t), (0, 0)))
    wu = jnp.pad(w_up, ((0, 0), (0, pad_f)))
    wd = jnp.pad(w_down, ((0, pad_f), (0, 0)))
    wg = jnp.pad(w_gate, ((0, 0), (0, pad_f))) if w_gate is not None else \
        jnp.zeros_like(wu)
    has_norm = norm_scale is not None
    scale = (norm_scale if has_norm else jnp.zeros((d,), x.dtype)).reshape(1, d)
    out = fused_ffn_kernel(xp, scale, wu, wg, wd, activation=activation,
                           has_norm=has_norm, bt=bt, bf=bf,
                           interpret=interpret)
    return out[:t]


def fused_ffn(x, params, *, activation: str = "swiglu", norm_scale=None,
              interpret: bool | None = None):
    """Model entry: x [...,d] with params {w_up, w_down[, w_gate]}."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = fused_ffn_2d(x2, params["w_up"], params["w_down"],
                       params.get("w_gate"), norm_scale,
                       activation=activation, interpret=interpret)
    return out.reshape(shape)
