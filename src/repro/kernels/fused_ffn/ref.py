"""Pure-jnp oracle for the fused norm+FFN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))


def reference(x, w_up, w_down, *, w_gate=None, norm_scale=None,
              activation: str = "swiglu"):
    """x [T,d] -> [T,d].  Norm (optional RMSNorm) + W1(+gate) + act + W2,
    all in f32; returns x.dtype."""
    h = rmsnorm(x, norm_scale) if norm_scale is not None else x.astype(jnp.float32)
    up = h @ w_up.astype(jnp.float32)
    if activation == "swiglu":
        gate = h @ w_gate.astype(jnp.float32)
        a = jax.nn.silu(gate) * up
    elif activation == "gelu":
        a = jax.nn.gelu(up)
    else:
        a = jax.nn.relu(up)
    return (a @ w_down.astype(jnp.float32)).astype(x.dtype)
