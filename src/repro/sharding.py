"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

Model code annotates every parameter and activation with *logical* axis names
("batch", "heads", "mlp", "experts", ...).  A rule table maps each logical name
to one or more *mesh* axes.  ``logical_to_spec`` resolves the mapping against a
concrete mesh and array shape, silently dropping mesh axes that do not divide
the dimension — this is what guarantees that every (arch x shape x mesh)
combination lowers, even when e.g. 8 KV heads meet a 16-way model axis.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Tuple[Optional[str], ...]

# logical axis -> tuple of mesh axes (tried in order, composed when all divide)
DEFAULT_RULES = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),                       # replicated by default
    "seq_shard": ("data",),          # long-context: shard sequence over data
    "act_model": ("model",),
    # parameters
    "embed": (),                     # the d_model axis of params: replicated
    "embed_fsdp": ("data",),         # FSDP: shard d_model of big tables over data
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv_dim": (),
    "mlp": ("model",),
    "experts": ("pod", "data"),      # expert-parallel over the data/pod axes
    "expert_mlp": ("model",),
    "tokens": ("pod", "data"),      # flattened (batch*seq) token axis
    # kv-cache
    "cache_batch": ("pod", "data"),
    "cache_seq": (),
    "cache_seq_shard": ("data",),
    "cache_heads": ("model",),
    # mamba / rwkv state
    "ssm_inner": ("model",),
    "ssm_state": (),
    "stack": (),                     # stacked-layer leading axis: never sharded
}


def resolve_rules(mesh: Mesh) -> dict:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)."""
    names = set(mesh.axis_names)
    return {k: tuple(a for a in v if a in names) for k, v in DEFAULT_RULES.items()}


# Logical layout of every stored/stacked history-KV leaf in the serving stack:
# quantized values [U, L, S, Hkv, D] and int8 scales [U, L, 1, Hkv, 1] share it
# (the divisibility fallback drops cache_seq_shard on the size-1 scale dim).
SERVING_KV_LEAF: Logical = (
    "cache_batch", "stack", "cache_seq_shard", "cache_heads", None)


def serving_rules(mesh: Mesh, kv_heads: Optional[int] = None) -> dict:
    """Rule table for the serving executors (engine/pool/DSO hot path).

    Differs from the train-time defaults in two load-bearing ways:

    - ``cache_batch`` is REPLICATED.  The leading axis of stacked history KV
      is "which pooled user row", and the dedup/packed executors index it
      with ``jnp.take(k_hist, row_index)`` where ``row_index`` varies per
      candidate — sharding it over data would put a cross-shard gather on
      every cached dispatch.  Keeping it replicated (while heads ride the
      model axis) is the reshard-free hot-path invariant: encode emits rows
      in exactly the layout the pool stores and the cached executors consume.
    - ``cache_seq_shard`` maps to the *model* axis, and only as a fallback:
      when the KV heads divide the model ways, attention is tensor-parallel
      over heads and the history length stays unsharded; when they don't
      (e.g. 4 KV heads on an 8-way model axis), the history length takes the
      model axis instead — the same ``seq_axis="model"`` convention as
      ``models.attention.context_parallel_attention``, so the shard_map CP
      path (``impl="cp"``) composes with these rules.

    The request batch axis always rides ``data``.
    """
    rules = dict(resolve_rules(mesh))
    names = set(mesh.axis_names)
    rules["batch"] = tuple(a for a in ("data",) if a in names)
    rules["cache_batch"] = ()
    model_ways = mesh.shape.get("model", 1) if "model" in names else 1
    cp_fallback = (kv_heads is not None and model_ways > 1
                   and kv_heads % model_ways != 0)
    rules["cache_seq_shard"] = ("model",) if cp_fallback else ()
    return rules


def rules_for_shape(mesh: Mesh, global_batch: int, fsdp: bool = True) -> dict:
    """Workload-adapted rules.

    - ``fsdp``: shard every parameter's d_model ("embed") axis over the data
      axis (ZeRO-3) so 70B-1T models fit per-chip HBM; optimizer states
      inherit the same sharding.
    - batch-1 workloads (long_500k): the batch axis is unshardable, so the
      KV-cache sequence axis takes over the data (and model, via fallback)
      axes instead.
    """
    rules = dict(resolve_rules(mesh))
    if fsdp:
        rules["embed"] = tuple(a for a in ("data",) if a in mesh.axis_names)
    batch_ways = 1
    for a in rules.get("batch", ()):
        batch_ways *= mesh.shape[a]
    if global_batch < max(batch_ways, 2):
        names = mesh.axis_names
        rules["cache_seq"] = tuple(a for a in ("data", "model") if a in names)
        rules["seq"] = tuple(a for a in ("data",) if a in names)
    return rules


def logical_to_spec(logical: Logical, shape: Sequence[int], mesh: Mesh,
                    rules: Optional[dict] = None) -> P:
    """Resolve logical axis names to a PartitionSpec with divisibility fallback."""
    rules = rules or resolve_rules(mesh)
    used = set()
    entries = []
    for dim, name in zip(shape, logical):
        if name is None:
            entries.append(None)
            continue
        axes = rules.get(name, ())
        picked = []
        rem = dim
        for ax in axes:
            if ax in used:
                continue
            size = mesh.shape[ax]
            if rem % size == 0:
                picked.append(ax)
                used.add(ax)
                rem //= size
        if not picked:
            entries.append(None)
        elif len(picked) == 1:
            entries.append(picked[0])
        else:
            entries.append(tuple(picked))
    # PartitionSpec trailing Nones are implicit
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def logical_to_sharding(logical: Logical, shape: Sequence[int], mesh: Mesh,
                        rules: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh, rules))


def tree_shardings(spec_tree, shape_tree, mesh: Mesh, rules: Optional[dict] = None):
    """Map a pytree of logical tuples + matching ShapeDtypeStructs to shardings."""
    rules = rules or resolve_rules(mesh)
    return jax.tree.map(
        lambda logical, sds: logical_to_sharding(logical, sds.shape, mesh, rules),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def constrain(x, mesh: Mesh, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op outside a mesh context."""
    try:
        spec = logical_to_spec(tuple(logical), x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except (ValueError, RuntimeError):
        return x


class AxisSpec:
    """Tiny helper so init functions can write ``ax('stack','embed','mlp')``."""

    def __call__(self, *names: Optional[str]) -> Logical:
        return tuple(names)

ax = AxisSpec()


# ---------------------------------------------------------------------------
# tracing-time sharding context: model code calls constrain_ctx(...) which is
# a no-op unless a mesh+rules context is active (set by the dry-run/launcher).
# ---------------------------------------------------------------------------

import contextlib
import contextvars

_ACTIVE: "contextvars.ContextVar" = contextvars.ContextVar(
    "repro_active_mesh_rules", default=None)


@contextlib.contextmanager
def mesh_rules(mesh: Mesh, rules: Optional[dict] = None):
    token = _ACTIVE.set((mesh, rules or resolve_rules(mesh)))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def constrain_ctx(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names against the active context."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, rules = active
    spec = logical_to_spec(tuple(logical), x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
