"""llava-next-mistral-7b [vlm] — Mistral-7B backbone, anyres vision tiling.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/projector frontend is a stub per the assignment: ``input_specs()``
provides precomputed patch embeddings (anyres: up to 5 tiles x 576 patches =
2880 image tokens) that are prepended to the text sequence.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    activation="swiglu",
    norm="rmsnorm",
    layer_pattern=("attn",),
    modality="vision",
    frontend_tokens=2880,   # anyres: 5 tiles x 576 patches
    sub_quadratic=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
