"""rwkv6-7b (Finch) [ssm] — attention-free, data-dependent decay linear attention.

32L d_model=4096 d_ff=14336 vocab=65536  [arXiv:2404.05892]
State is O(1) in sequence length -> runs long_500k.

Arch-applicability (DESIGN.md): the paper's mask-aware flash-attention kernel
does not apply (no attention); the FKE insight maps to the chunked rwkv6_scan
Pallas kernel instead.  PDA/DSO apply unchanged.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # 4096 / head_size 64
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    activation="relu",     # channel-mix uses squared relu
    norm="layernorm",
    layer_pattern=("rwkv",),
    rwkv_head_size=64,
    sub_quadratic=True,
    source="arXiv:2404.05892",
)
