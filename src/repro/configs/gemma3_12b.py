"""gemma3-12b [dense] — 5:1 local:global attention interleave, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144  [hf:google/gemma-3-1b-pt]
head_dim = 3840/16 = 240.  Layer pattern period 6: 5 sliding-window (1024) + 1
global.  Sliding-window-dominant -> long_500k runs (global layers keep a
sequence-sharded full cache; batch=1 shards seq over the data axis).
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    activation="gelu",
    norm="rmsnorm",
    sliding_window=1024,
    layer_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
    sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt",
)
